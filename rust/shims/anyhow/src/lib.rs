//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The real crate is not in the offline vendor set, so this shim implements
//! exactly the surface the repo uses: `Error` (message + context chain),
//! `Result<T>`, the `Context` extension trait for `Result` and `Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros.  Semantics match anyhow
//! where it matters:
//!
//! * any `std::error::Error` converts into `Error` via `?`,
//! * `Error` itself does **not** implement `std::error::Error` (that is
//!   what makes the blanket `From` impl coherent — same trick as anyhow),
//! * `{e}` prints the outermost message, `{e:#}` the full context chain,
//!   `{e:?}` an anyhow-style "Caused by:" report.

use std::fmt;

/// Error type: a message plus an optional chain of underlying causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow behaviour)
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Like anyhow: every std error converts; coherent because `Error` itself is
// not a `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(msg),
                Some(inner) => inner.context(msg),
            });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option` (mirrors `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/zzz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("reading config: "), "{alt}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        let got: Result<i32> = None.context("missing value");
        assert_eq!(got.unwrap_err().to_string(), "missing value");
        let e = anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        fn check(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert!(check(true).is_ok());
        assert_eq!(check(false).unwrap_err().to_string(), "flag was false");
        fn never() -> Result<u32> {
            bail!("nope {}", 3);
        }
        assert_eq!(never().unwrap_err().to_string(), "nope 3");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<i32, std::num::ParseIntError> = "5".parse();
        let got = ok.with_context(|| {
            called = true;
            "ctx"
        });
        assert_eq!(got.unwrap(), 5);
        assert!(!called);
    }
}
