//! Stub of the PJRT `xla` crate (offline vendor set has no PJRT build).
//!
//! Exposes the exact type/method surface the coordinator uses so the whole
//! crate — runtime, trainer, server, CLI — compiles and links with zero
//! native dependencies.  `PjRtClient::cpu()` (the single entry point every
//! PJRT code path goes through) returns an error explaining the situation,
//! so artifact-backed features fail fast at `Runtime::new` with a clear
//! message while the native kernels (`holt::kernels`) remain fully usable.
//!
//! To run the real artifact path, replace this path dependency in the root
//! `Cargo.toml` with a PJRT-backed `xla` crate; no other code changes are
//! needed.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the real crate's (implements `std::error::Error`,
/// so `?` converts it into `anyhow::Error` at call sites).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend not available in this build (the vendored stub \
         `xla` crate is linked). The native O(n) kernels in `holt::kernels` \
         work without it; for the artifact path swap in a real PJRT `xla` \
         crate — see README.md."
    ))
}

/// Element types the coordinator distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    S32,
}

/// Supported host element types for literal construction.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host literal. The stub only ever holds nothing: literals can be built
/// (parameter caching does that ahead of execution) but any attempt to
/// execute or read one reports the backend as unavailable.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Array shape (dims + element type) of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. `cpu()` is the single constructor the coordinator calls;
/// in the stub it always fails, which makes `Runtime::new` the one place
/// users see the (actionable) error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT backend not available"), "{msg}");
        assert!(msg.contains("holt::kernels"), "{msg}");
    }

    #[test]
    fn literals_can_be_built_but_not_read() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::scalar(3i32).array_shape().is_err());
    }
}
