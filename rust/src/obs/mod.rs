//! obs — the one observability layer: a zero-dependency, lock-free
//! [`Registry`] of named counters, gauges and log2-bucket histograms,
//! RAII [`Span`] timers for per-stage latency, and a bounded
//! [`FlightRecorder`] ring of per-request lifecycle events.
//!
//! Design constraints (all pinned by tests):
//!
//! * **Hot path is allocation-free and lock-free.** Handles are `Arc`s
//!   of plain `AtomicU64`s handed out at registration; `inc`/`set`/
//!   `record`/`span` touch only Relaxed atomics (and `Instant::now`),
//!   never the registry lock.  The registry's `Mutex` is taken only to
//!   register a metric or to export a snapshot — both cold.
//!   `alloc_decode.rs` pins an instrumented decode step at zero
//!   allocations after warm-up.
//! * **Histograms are fixed-shape** — 64 log2 buckets (bucket *i*
//!   counts values in `[2^i, 2^(i+1))`; bucket 0 holds 0 and 1) plus
//!   count/sum/min/max — so merging shard snapshots is elementwise and
//!   associative, and quantile reads never sort anything.  Quantiles
//!   are nearest-rank over buckets, reported as the bucket's upper
//!   bound clamped to the observed max (exact for single samples), and
//!   `None` — never a fake 0 — on an empty histogram.
//! * **One process epoch.** Flight-recorder timestamps are micros since
//!   [`epoch`], shared by every shard, so events for a trace that
//!   crossed shards sort into one coherent timeline.
//!
//! Consumers: the serve engine (per-stage spans `prefill_us` /
//! `decode_step_us` / `sample_us` / `park_us` / `migrate_us`, lifecycle
//! flight events, `{"stats": true}` / `{"metrics": true}` wire probes),
//! the trainer (`grad_capture_us` / `reverse_sweep_us` /
//! `tree_reduce_us` spans and the step log), and the kernels'
//! attention-forward counter — all reading the same registry.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::json::{obj, Json};

/// Number of log2 buckets; covers the full `u64` range.
pub const BUCKETS: usize = 64;

/// Bucket index for a value: `floor(log2(max(v, 1)))`, so bucket `i`
/// covers `[2^i, 2^(i+1))` and bucket 0 additionally holds 0.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (63 - (v | 1).leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`2^(i+1) - 1`; saturates at
/// `u64::MAX` for the last bucket).
#[inline]
pub fn bucket_upper(i: usize) -> u64 {
    if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

// ---------------------------------------------------------------------------
// handles
// ---------------------------------------------------------------------------

/// Monotonic counter handle.  Clone freely: clones share the cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge handle (stored as bits in an `AtomicU64`).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistoCore {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl HistoCore {
    fn new() -> Self {
        HistoCore {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

/// Log2-bucket histogram handle.  `record` is five Relaxed atomic RMWs,
/// no branches on the bucket walk, no allocation.
#[derive(Clone)]
pub struct Histo(Arc<HistoCore>);

impl Histo {
    pub fn record(&self, v: u64) {
        let c = &self.0;
        c.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.min.fetch_min(v, Ordering::Relaxed);
        c.max.fetch_max(v, Ordering::Relaxed);
    }

    /// RAII span timer: records elapsed **microseconds** into the
    /// histogram when the guard drops.
    #[must_use = "the span records on drop; binding it to _ measures nothing"]
    pub fn span(&self) -> Span<'_> {
        Span { h: self, start: Instant::now() }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough snapshot for export: concurrent writers may
    /// land between field reads, which skews a quantile by at most the
    /// in-flight samples — fine for monitoring, free of locks.
    pub fn snapshot(&self) -> HistoSnapshot {
        let c = &self.0;
        HistoSnapshot {
            buckets: std::array::from_fn(|i| c.buckets[i].load(Ordering::Relaxed)),
            count: c.count.load(Ordering::Relaxed),
            sum: c.sum.load(Ordering::Relaxed),
            min: c.min.load(Ordering::Relaxed),
            max: c.max.load(Ordering::Relaxed),
        }
    }
}

/// RAII timer returned by [`Histo::span`].
pub struct Span<'a> {
    h: &'a Histo,
    start: Instant,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        self.h.record(self.start.elapsed().as_micros() as u64);
    }
}

// ---------------------------------------------------------------------------
// snapshots — plain data, mergeable across shards
// ---------------------------------------------------------------------------

/// Owned histogram state: what [`Histo::snapshot`] exports and what
/// shard aggregation merges.  All fields are sums/mins/maxes, so
/// [`HistoSnapshot::merge`] is associative and commutative — pooled
/// quantiles across shards are computed over the merged buckets, never
/// by averaging per-shard quantiles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistoSnapshot {
    pub buckets: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
    /// `u64::MAX` when empty (so `merge` is `min`).
    pub min: u64,
    pub max: u64,
}

impl Default for HistoSnapshot {
    fn default() -> Self {
        HistoSnapshot { buckets: [0; BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl HistoSnapshot {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record into an owned snapshot (single-threaded accumulation —
    /// e.g. building expected values in tests).
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn merge(&mut self, other: &HistoSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile (`p` in `[0, 100]`): the upper bound of the
    /// bucket holding the rank-th sample, clamped to the observed max.
    /// `None` when empty — an empty histogram has no p99, and reporting
    /// 0 would read as "0µs p99".
    pub fn quantile(&self, p: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((p / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return Some(bucket_upper(i).min(self.max));
            }
        }
        Some(self.max)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum as f64 / self.count as f64)
        }
    }

    /// Human line for console reports; explicit about emptiness.
    pub fn summary(&self) -> String {
        match (self.quantile(50.0), self.quantile(95.0), self.quantile(99.0)) {
            (Some(p50), Some(p95), Some(p99)) => format!(
                "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
                self.count,
                self.mean().unwrap_or(0.0),
                p50,
                p95,
                p99,
                self.max
            ),
            _ => "n=0 (no samples)".into(),
        }
    }

    /// Structured export: explicit `samples` plus null quantiles when
    /// empty.
    pub fn to_json(&self) -> Json {
        let q = |p: f64| self.quantile(p).map_or(Json::Null, |v| ((v as i64).into()));
        obj(vec![
            ("samples", ((self.count as i64).into())),
            ("mean", self.mean().map_or(Json::Null, Json::from)),
            ("p50", q(50.0)),
            ("p95", q(95.0)),
            ("p99", q(99.0)),
            ("min", if self.count == 0 { Json::Null } else { (self.min as i64).into() }),
            ("max", if self.count == 0 { Json::Null } else { (self.max as i64).into() }),
        ])
    }

    /// Emit the bench-style `<prefix>_p50_ms`… fields (plus an explicit
    /// `<prefix>_samples`) into a JSON field list.  The single place
    /// `ServeStats::to_json` and the overload report share, fixing the
    /// old `Latencies` behavior where an empty set exported `0.0` for
    /// every percentile.
    pub fn push_ms_fields(&self, prefix: &str, fields: &mut Vec<(String, Json)>) {
        fields.push((format!("{prefix}_samples"), (self.count as i64).into()));
        for (name, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
            let v = self.quantile(p).map_or(Json::Null, |us| (us as f64 / 1e3).into());
            fields.push((format!("{prefix}_{name}_ms"), v));
        }
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histo(Histo),
}

/// Named metric registry.  Registration (find-or-insert by name) takes
/// the lock once and returns a shared handle; every subsequent
/// operation on the handle is lock-free.  Same name ⇒ same cell, so
/// independently-registered handles aggregate.
pub struct Registry {
    inner: Mutex<Vec<(String, Metric)>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry { inner: Mutex::new(Vec::new()) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<(String, Metric)>> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Find-or-register a counter.  Panics if `name` is already a
    /// different metric kind — a registration-time programming error.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.lock();
        if let Some((_, metric)) = m.iter().find(|(k, _)| k == name) {
            match metric {
                Metric::Counter(c) => return c.clone(),
                _ => panic!("metric '{name}' is registered as a non-counter"),
            }
        }
        let c = Counter(Arc::new(AtomicU64::new(0)));
        m.push((name.to_string(), Metric::Counter(c.clone())));
        c
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.lock();
        if let Some((_, metric)) = m.iter().find(|(k, _)| k == name) {
            match metric {
                Metric::Gauge(g) => return g.clone(),
                _ => panic!("metric '{name}' is registered as a non-gauge"),
            }
        }
        let g = Gauge(Arc::new(AtomicU64::new(0)));
        m.push((name.to_string(), Metric::Gauge(g.clone())));
        g
    }

    pub fn histo(&self, name: &str) -> Histo {
        let mut m = self.lock();
        if let Some((_, metric)) = m.iter().find(|(k, _)| k == name) {
            match metric {
                Metric::Histo(h) => return h.clone(),
                _ => panic!("metric '{name}' is registered as a non-histogram"),
            }
        }
        let h = Histo(Arc::new(HistoCore::new()));
        m.push((name.to_string(), Metric::Histo(h.clone())));
        h
    }

    /// Snapshot of a histogram by name, if registered.
    pub fn histo_snapshot(&self, name: &str) -> Option<HistoSnapshot> {
        let m = self.lock();
        m.iter().find(|(k, _)| k == name).and_then(|(_, metric)| match metric {
            Metric::Histo(h) => Some(h.snapshot()),
            _ => None,
        })
    }

    /// Value of a counter by name, if registered.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        let m = self.lock();
        m.iter().find(|(k, _)| k == name).and_then(|(_, metric)| match metric {
            Metric::Counter(c) => Some(c.get()),
            _ => None,
        })
    }

    /// One flat JSON object: counters as integers, gauges as floats,
    /// histograms as `{samples, mean, p50, p95, p99, min, max}` objects
    /// (null quantiles when empty).  Registration order preserved.
    pub fn to_json(&self) -> Json {
        let m = self.lock();
        Json::Obj(
            m.iter()
                .map(|(k, metric)| {
                    let v = match metric {
                        Metric::Counter(c) => (c.get() as i64).into(),
                        Metric::Gauge(g) => g.get().into(),
                        Metric::Histo(h) => h.snapshot().to_json(),
                    };
                    (k.clone(), v)
                })
                .collect(),
        )
    }

    /// Prometheus text exposition: `holt_<name>` with `# TYPE` lines;
    /// histograms expand to cumulative `_bucket{le="…"}` series plus
    /// `_sum` and `_count`.
    pub fn render_prometheus(&self) -> String {
        let m = self.lock();
        let mut out = String::new();
        for (name, metric) in m.iter() {
            let n = sanitize(name);
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE holt_{n} counter\nholt_{n} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE holt_{n} gauge\nholt_{n} {}\n", g.get()));
                }
                Metric::Histo(h) => {
                    let s = h.snapshot();
                    out.push_str(&format!("# TYPE holt_{n} histogram\n"));
                    let top = s
                        .buckets
                        .iter()
                        .rposition(|&b| b > 0)
                        .map_or(0, |i| (i + 1).min(BUCKETS - 1));
                    let mut cum = 0u64;
                    for i in 0..=top {
                        cum += s.buckets[i];
                        out.push_str(&format!(
                            "holt_{n}_bucket{{le=\"{}\"}} {cum}\n",
                            bucket_upper(i)
                        ));
                    }
                    out.push_str(&format!("holt_{n}_bucket{{le=\"+Inf\"}} {}\n", s.count));
                    out.push_str(&format!("holt_{n}_sum {}\n", s.sum));
                    out.push_str(&format!("holt_{n}_count {}\n", s.count));
                }
            }
        }
        out
    }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' }).collect()
}

// ---------------------------------------------------------------------------
// process globals
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<Registry> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide registry: kernels and the trainer record here, and
/// single-engine servers use it as their shard registry too.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// One shared time origin for the whole process.  Flight-recorder
/// timestamps are micros since this instant, so events recorded on
/// different shard threads sort into one timeline.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since [`epoch`].
pub fn since_epoch_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Request lifecycle event kinds recorded by the serve engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlightEvent {
    Admit,
    Park,
    Resume,
    MigrateIn,
    MigrateOut,
    Reject,
    Finish,
}

impl FlightEvent {
    pub fn name(self) -> &'static str {
        match self {
            FlightEvent::Admit => "admit",
            FlightEvent::Park => "park",
            FlightEvent::Resume => "resume",
            FlightEvent::MigrateIn => "migrate_in",
            FlightEvent::MigrateOut => "migrate_out",
            FlightEvent::Reject => "reject",
            FlightEvent::Finish => "finish",
        }
    }
}

/// One flight-recorder entry.  `Copy` and string-free on purpose: the
/// ring never allocates per event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightRecord {
    /// Per-recorder monotonic sequence number (1-based).
    pub seq: u64,
    /// Micros since the shared process [`epoch`].
    pub t_us: u64,
    /// Shard that recorded the event.
    pub shard: usize,
    /// Router-minted trace id (0 = never routed).
    pub trace: u64,
    /// Request id (0 for events without one).
    pub req_id: u64,
    pub event: FlightEvent,
}

impl FlightRecord {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seq", (self.seq as i64).into()),
            ("t_us", (self.t_us as i64).into()),
            ("shard", self.shard.into()),
            ("trace", (self.trace as i64).into()),
            ("req_id", (self.req_id as i64).into()),
            ("event", self.event.name().into()),
        ])
    }
}

/// Bounded ring of the last `cap` lifecycle events on one shard.
/// Owned by the engine thread — no locks; once the ring has filled,
/// recording is pop-front/push-back with no allocation.
pub struct FlightRecorder {
    cap: usize,
    seq: u64,
    shard: usize,
    ring: VecDeque<FlightRecord>,
}

impl FlightRecorder {
    pub fn new(shard: usize, cap: usize) -> Self {
        let cap = cap.max(1);
        FlightRecorder { cap, seq: 0, shard, ring: VecDeque::with_capacity(cap) }
    }

    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn record(&mut self, event: FlightEvent, trace: u64, req_id: u64) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
        }
        self.seq += 1;
        self.ring.push_back(FlightRecord {
            seq: self.seq,
            t_us: since_epoch_us(),
            shard: self.shard,
            trace,
            req_id,
            event,
        });
    }

    /// Events for one trace id, oldest first.
    pub fn for_trace(&self, trace: u64) -> Vec<FlightRecord> {
        self.ring.iter().filter(|r| r.trace == trace).copied().collect()
    }

    /// Full dump, oldest first — written to the metrics log on overload.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.ring.iter().map(|r| r.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        for i in 1..BUCKETS - 1 {
            let lo = 1u64 << i;
            assert_eq!(bucket_of(lo), i, "lower edge of bucket {i}");
            assert_eq!(bucket_of(lo * 2 - 1), i, "upper edge of bucket {i}");
            assert_eq!(bucket_of(lo * 2), i + 1, "first value past bucket {i}");
        }
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper(BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn empty_histogram_reads_none_not_zero() {
        let s = HistoSnapshot::new();
        assert!(s.is_empty());
        assert_eq!(s.quantile(50.0), None);
        assert_eq!(s.quantile(99.0), None);
        assert_eq!(s.mean(), None);
        assert_eq!(s.summary(), "n=0 (no samples)");
        let j = s.to_json();
        assert_eq!(j.get("samples").unwrap().as_i64(), Some(0));
        assert_eq!(j.get("p99"), Some(&Json::Null));
    }

    #[test]
    fn single_sample_quantiles_are_exact() {
        // the observed-max clamp makes one-sample quantiles exact even
        // though the bucket upper bound is coarse
        let mut s = HistoSnapshot::new();
        s.record(100);
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.quantile(p), Some(100));
        }
        assert_eq!(s.min, 100);
        assert_eq!(s.max, 100);
    }

    #[test]
    fn quantiles_are_nearest_rank_over_buckets() {
        let mut s = HistoSnapshot::new();
        // 90 fast samples in [2^4, 2^5), 10 slow in [2^10, 2^11)
        for i in 0..90u64 {
            s.record(16 + i % 16);
        }
        for _ in 0..10 {
            s.record(1500);
        }
        assert_eq!(s.quantile(50.0), Some(31), "p50 lands in the fast bucket");
        assert_eq!(s.quantile(99.0), Some(1500), "p99 lands in the slow bucket, max-clamped");
    }

    #[test]
    fn registry_find_or_insert_shares_cells() {
        let r = Registry::new();
        let a = r.counter("x");
        let b = r.counter("x");
        a.inc();
        b.add(2);
        assert_eq!(r.counter_value("x"), Some(3));
        let h1 = r.histo("lat_us");
        let h2 = r.histo("lat_us");
        h1.record(10);
        h2.record(20);
        assert_eq!(r.histo_snapshot("lat_us").unwrap().count, 2);
        let g = r.gauge("load");
        g.set(0.75);
        assert_eq!(r.gauge("load").get(), 0.75);
    }

    #[test]
    #[should_panic(expected = "registered as a non-counter")]
    fn registry_kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.gauge("x");
        let _ = r.counter("x");
    }

    #[test]
    fn span_records_on_drop() {
        let r = Registry::new();
        let h = r.histo("t_us");
        {
            let _s = h.span();
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn json_and_prometheus_exports() {
        let r = Registry::new();
        r.counter("reqs").add(5);
        r.gauge("load").set(1.5);
        r.histo("lat_us").record(100);
        let j = r.to_json();
        assert_eq!(j.get("reqs").unwrap().as_i64(), Some(5));
        assert_eq!(j.get("load").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("lat_us").unwrap().get("samples").unwrap().as_i64(), Some(1));
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE holt_reqs counter"));
        assert!(text.contains("holt_reqs 5"));
        assert!(text.contains("# TYPE holt_lat_us histogram"));
        assert!(text.contains("holt_lat_us_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("holt_lat_us_count 1"));
    }

    #[test]
    fn merge_matches_union() {
        let mut a = HistoSnapshot::new();
        let mut b = HistoSnapshot::new();
        let mut union = HistoSnapshot::new();
        for v in [1u64, 7, 100, 4000] {
            a.record(v);
            union.record(v);
        }
        for v in [2u64, 900, 65_000] {
            b.record(v);
            union.record(v);
        }
        a.merge(&b);
        assert_eq!(a, union);
        // merging an empty snapshot is the identity
        let before = a.clone();
        a.merge(&HistoSnapshot::new());
        assert_eq!(a, before);
    }

    #[test]
    fn flight_ring_wraps_without_growing() {
        let mut fr = FlightRecorder::new(3, 4);
        for i in 0..10u64 {
            fr.record(FlightEvent::Admit, i, i);
        }
        assert_eq!(fr.len(), 4);
        let all = fr.for_trace(9);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].seq, 10);
        assert_eq!(all[0].shard, 3);
        // oldest retained is seq 7
        let dump = fr.to_json();
        assert_eq!(dump.as_arr().unwrap()[0].get("seq").unwrap().as_i64(), Some(7));
    }

    #[test]
    fn epoch_is_shared_and_monotonic() {
        let a = since_epoch_us();
        let b = since_epoch_us();
        assert!(b >= a);
    }
}
