//! Synthetic training workloads.
//!
//! The paper trains/tests "on random data" only; we keep that experiment
//! (E1 uses random tensors) and add three structured tasks so the training
//! claim is exercised end-to-end:
//!
//! * `copy`   — copy a random span after a separator (pure recall; linear
//!   attention models are known to find this harder than softmax).
//! * `assoc`  — associative recall: key/value pairs, then a query key
//!   (the induction-head workload).
//! * `charlm` — byte-level language modelling over an embedded
//!   public-domain corpus.
//!
//! Every generator is seeded and deterministic; batches carry per-position
//! loss weights so only answer spans are scored where that's meaningful.

pub mod assoc;
pub mod charlm;
pub mod copy;
pub mod reverse;

use crate::runtime::Tensor;

/// Separator token used inside synthetic tasks (inside model vocab,
/// above the python-side specials PAD/BOS/EOS = 256/257/258).
pub const SEP: i32 = 259;

/// One training batch in the shape the train artifacts expect.
#[derive(Debug, Clone)]
pub struct Batch {
    /// (B, T) i32
    pub tokens: Tensor,
    /// (B, T) i32 — next-token targets
    pub targets: Tensor,
    /// (B, T) f32 — per-position loss weights
    pub weights: Tensor,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.tokens.shape[0]
    }

    pub fn seq_len(&self) -> usize {
        self.tokens.shape[1]
    }

    /// Sequences `[s, e)` as an owned sub-batch — the per-sequence unit
    /// of the trainer's micro-batch / data-parallel gradient loop.
    pub fn slice_rows(&self, s: usize, e: usize) -> anyhow::Result<Batch> {
        let (b, t) = (self.batch_size(), self.seq_len());
        anyhow::ensure!(s < e && e <= b, "slice_rows [{s}, {e}) of batch size {b}");
        Ok(Batch {
            tokens: Tensor::i32(vec![e - s, t], self.tokens.as_i32()?[s * t..e * t].to_vec()),
            targets: Tensor::i32(vec![e - s, t], self.targets.as_i32()?[s * t..e * t].to_vec()),
            weights: Tensor::f32(vec![e - s, t], self.weights.as_f32()?[s * t..e * t].to_vec()),
        })
    }

    /// Weighted mean cross-entropy from logits (B, T, V) — must agree with
    /// the in-graph loss (checked in the integration tests).
    pub fn cross_entropy(&self, logits: &Tensor) -> anyhow::Result<f64> {
        let (b, t) = (self.batch_size(), self.seq_len());
        let v = logits.shape[2];
        let lf = logits.as_f32()?;
        let tg = self.targets.as_i32()?;
        let w = self.weights.as_f32()?;
        let mut total = 0.0f64;
        let mut wsum = 0.0f64;
        for i in 0..b * t {
            if w[i] > 0.0 {
                let row = &lf[i * v..(i + 1) * v];
                let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
                let logz = maxv as f64
                    + row.iter().map(|&x| ((x - maxv) as f64).exp()).sum::<f64>().ln();
                total += (logz - row[tg[i] as usize] as f64) * w[i] as f64;
                wsum += w[i] as f64;
            }
        }
        Ok(if wsum == 0.0 { 0.0 } else { total / wsum })
    }

    /// Fraction of weighted positions where `argmax(logits) == target`.
    /// `logits` is (B, T, V) row-major.
    pub fn accuracy(&self, logits: &Tensor) -> anyhow::Result<f64> {
        let (b, t) = (self.batch_size(), self.seq_len());
        let v = logits.shape[2];
        let lf = logits.as_f32()?;
        let tg = self.targets.as_i32()?;
        let w = self.weights.as_f32()?;
        let mut correct = 0u64;
        let mut total = 0u64;
        for i in 0..b * t {
            if w[i] > 0.0 {
                total += 1;
                let row = &lf[i * v..(i + 1) * v];
                if crate::rng::argmax(row) as i32 == tg[i] {
                    correct += 1;
                }
            }
        }
        Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
    }
}

/// A seeded batch source.
pub trait DataGen: Send {
    /// Task name (for logs).
    fn name(&self) -> &'static str;
    /// Next training batch of shape (batch, t).
    fn batch(&mut self, batch: usize, t: usize) -> Batch;
}

/// Instantiate a generator by task name.
pub fn make(task: &str, seed: u64) -> anyhow::Result<Box<dyn DataGen>> {
    match task {
        "copy" => Ok(Box::new(copy::CopyTask::new(seed))),
        "assoc" => Ok(Box::new(assoc::AssocRecall::new(seed))),
        "charlm" => Ok(Box::new(charlm::CharLm::new(seed))),
        "reverse" => Ok(Box::new(reverse::ReverseTask::new(seed))),
        _ => anyhow::bail!(
            "unknown task '{task}' (have: copy, assoc, charlm, reverse)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_produce_valid_batches() {
        for task in ["copy", "assoc", "charlm", "reverse"] {
            let mut g = make(task, 42).unwrap();
            let b = g.batch(4, 64);
            assert_eq!(b.tokens.shape, vec![4, 64], "{task}");
            assert_eq!(b.targets.shape, vec![4, 64], "{task}");
            assert_eq!(b.weights.shape, vec![4, 64], "{task}");
            let toks = b.tokens.as_i32().unwrap();
            assert!(
                toks.iter().all(|&t| (0..272).contains(&t)),
                "{task}: token out of vocab"
            );
            let w = b.weights.as_f32().unwrap();
            assert!(w.iter().any(|&x| x > 0.0), "{task}: no scored positions");
        }
    }

    #[test]
    fn generators_are_deterministic() {
        for task in ["copy", "assoc", "charlm", "reverse"] {
            let mut a = make(task, 7).unwrap();
            let mut b = make(task, 7).unwrap();
            assert_eq!(
                a.batch(2, 32).tokens,
                b.batch(2, 32).tokens,
                "{task} not deterministic"
            );
        }
    }

    #[test]
    fn accuracy_counts_weighted_positions_only() {
        let tokens = Tensor::i32(vec![1, 4], vec![0, 1, 2, 3]);
        let targets = Tensor::i32(vec![1, 4], vec![1, 2, 3, 0]);
        let weights = Tensor::f32(vec![1, 4], vec![0.0, 1.0, 1.0, 0.0]);
        let b = Batch { tokens, targets, weights };
        // logits (1,4,5): predict target correctly at pos 1 only
        let mut lf = vec![0f32; 4 * 5];
        lf[1 * 5 + 2] = 9.0; // pos1 -> 2 == target ✓
        lf[2 * 5 + 1] = 9.0; // pos2 -> 1 != 3 ✗
        let logits = Tensor::f32(vec![1, 4, 5], lf);
        assert!((b.accuracy(&logits).unwrap() - 0.5).abs() < 1e-9);
    }
}
