//! Associative recall: `[BOS, k1, v1, ..., kP, vP, SEP, kq]` and the model
//! must emit the value bound to the queried key — the induction-head
//! workload from the linear-attention literature.  Only the answer
//! position is scored.
//!
//! Keys and values are drawn from disjoint alphabets so the model cannot
//! confuse roles; the queried key is always one of the presented keys.

use super::{Batch, DataGen, SEP};
use crate::rng::Rng;
use crate::runtime::Tensor;
use crate::tokenizer::{BOS, PAD};

pub struct AssocRecall {
    rng: Rng,
    pub n_keys: i32,
    pub n_vals: i32,
}

impl AssocRecall {
    pub fn new(seed: u64) -> Self {
        AssocRecall { rng: Rng::new(seed), n_keys: 32, n_vals: 32 }
    }
}

impl DataGen for AssocRecall {
    fn name(&self) -> &'static str {
        "assoc"
    }

    fn batch(&mut self, batch: usize, t: usize) -> Batch {
        let mut tokens = vec![PAD; batch * t];
        let mut targets = vec![PAD; batch * t];
        let mut weights = vec![0f32; batch * t];
        // pairs occupy 2P tokens, plus BOS, SEP, query, answer
        let max_pairs = ((t - 4) / 2).min(self.n_keys as usize);
        for b in 0..batch {
            let pairs = self.rng.uniform_int(2, max_pairs as u64 + 1) as usize;
            // distinct keys (partial Fisher–Yates over the key alphabet)
            let mut keys: Vec<i32> = (0..self.n_keys).collect();
            self.rng.shuffle(&mut keys);
            keys.truncate(pairs);
            let vals: Vec<i32> = (0..pairs)
                .map(|_| 64 + self.rng.uniform_int(0, self.n_vals as u64) as i32)
                .collect();

            let row = &mut tokens[b * t..(b + 1) * t];
            row[0] = BOS;
            for i in 0..pairs {
                row[1 + 2 * i] = keys[i];
                row[2 + 2 * i] = vals[i];
            }
            let qi = self.rng.uniform_int(0, pairs as u64) as usize;
            row[1 + 2 * pairs] = SEP;
            row[2 + 2 * pairs] = keys[qi];
            row[3 + 2 * pairs] = vals[qi]; // present so targets line up

            let trow = &mut targets[b * t..(b + 1) * t];
            for i in 0..t - 1 {
                trow[i] = row[i + 1];
            }
            // score only the position that predicts the answer (the query
            // key predicts its value)
            weights[b * t + 2 + 2 * pairs] = 1.0;
        }
        Batch {
            tokens: Tensor::i32(vec![batch, t], tokens),
            targets: Tensor::i32(vec![batch, t], targets),
            weights: Tensor::f32(vec![batch, t], weights),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_answer_consistent() {
        let mut g = AssocRecall::new(0);
        let b = g.batch(16, 48);
        let toks = b.tokens.as_i32().unwrap();
        let tgts = b.targets.as_i32().unwrap();
        let w = b.weights.as_f32().unwrap();
        for row in 0..16 {
            let r = &toks[row * 48..(row + 1) * 48];
            let sep = r.iter().position(|&x| x == SEP).unwrap();
            let pairs = (sep - 1) / 2;
            let qkey = r[sep + 1];
            // find the bound value
            let mut bound = None;
            for i in 0..pairs {
                if r[1 + 2 * i] == qkey {
                    bound = Some(r[2 + 2 * i]);
                }
            }
            let answer = r[sep + 2];
            assert_eq!(Some(answer), bound, "answer must be the bound value");
            // exactly one scored position, and it predicts the answer
            let wrow = &w[row * 48..(row + 1) * 48];
            assert_eq!(wrow.iter().filter(|&&x| x > 0.0).count(), 1);
            let pos = wrow.iter().position(|&x| x > 0.0).unwrap();
            assert_eq!(pos, sep + 1);
            assert_eq!(tgts[row * 48 + pos], answer);
        }
    }

    #[test]
    fn keys_distinct_within_sequence() {
        let mut g = AssocRecall::new(3);
        let b = g.batch(8, 64);
        let toks = b.tokens.as_i32().unwrap();
        for row in 0..8 {
            let r = &toks[row * 64..(row + 1) * 64];
            let sep = r.iter().position(|&x| x == SEP).unwrap();
            let pairs = (sep - 1) / 2;
            let keys: Vec<i32> = (0..pairs).map(|i| r[1 + 2 * i]).collect();
            let uniq: std::collections::HashSet<_> = keys.iter().collect();
            assert_eq!(uniq.len(), keys.len());
        }
    }
}
