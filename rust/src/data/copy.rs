//! Copy task: `[BOS, x1..xL, SEP, x1..xL, PAD...]` — the model must
//! reproduce the span after the separator.  Only the reproduction span is
//! scored.  Span length is sampled per sequence so models can't latch onto
//! a fixed offset.

use super::{Batch, DataGen, SEP};
use crate::rng::Rng;
use crate::runtime::Tensor;
use crate::tokenizer::{BOS, PAD};

pub struct CopyTask {
    rng: Rng,
    /// Alphabet size for the random spans.  Small on purpose: a model
    /// that only learns the task *format* (answers come from this
    /// alphabet) reaches loss ln(alphabet), so with 8 symbols the loss
    /// visibly collapses from ln(vocab) ≈ 5.6 to ≈ 2.1 within a couple
    /// hundred steps — the train-smoke signal — while actually *solving*
    /// the task (accuracy ≫ 1/alphabet) still requires copying from
    /// context, which is what the E6 ablation measures.
    pub alphabet: i32,
}

impl CopyTask {
    pub fn new(seed: u64) -> Self {
        CopyTask { rng: Rng::new(seed), alphabet: 8 }
    }
}

impl DataGen for CopyTask {
    fn name(&self) -> &'static str {
        "copy"
    }

    fn batch(&mut self, batch: usize, t: usize) -> Batch {
        let mut tokens = vec![PAD; batch * t];
        let mut targets = vec![PAD; batch * t];
        let mut weights = vec![0f32; batch * t];
        // span must fit twice plus BOS and SEP
        let max_span = (t - 2) / 2;
        for b in 0..batch {
            let span = self.rng.uniform_int(1, max_span as u64 + 1) as usize;
            let row = &mut tokens[b * t..(b + 1) * t];
            row[0] = BOS;
            for i in 0..span {
                row[1 + i] = self.rng.uniform_int(0, self.alphabet as u64) as i32;
            }
            row[1 + span] = SEP;
            for i in 0..span {
                row[2 + span + i] = row[1 + i];
            }
            // next-token targets; score only the copy span (positions that
            // *predict* the copied tokens: SEP predicts x1, x_i predicts
            // x_{i+1})
            let trow = &mut targets[b * t..(b + 1) * t];
            let wrow = &mut weights[b * t..(b + 1) * t];
            for i in 0..t - 1 {
                trow[i] = row[i + 1];
            }
            for i in (1 + span)..(1 + 2 * span) {
                wrow[i] = 1.0;
            }
        }
        Batch {
            tokens: Tensor::i32(vec![batch, t], tokens),
            targets: Tensor::i32(vec![batch, t], targets),
            weights: Tensor::f32(vec![batch, t], weights),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_is_copyable() {
        let mut g = CopyTask::new(0);
        let b = g.batch(8, 32);
        let toks = b.tokens.as_i32().unwrap();
        let tgts = b.targets.as_i32().unwrap();
        let w = b.weights.as_f32().unwrap();
        for row in 0..8 {
            let r = &toks[row * 32..(row + 1) * 32];
            assert_eq!(r[0], BOS);
            let sep_pos = r.iter().position(|&x| x == SEP).unwrap();
            let span = sep_pos - 1;
            // the copy: r[2+span..2+2span] == r[1..1+span]
            assert_eq!(&r[sep_pos + 1..sep_pos + 1 + span], &r[1..1 + span]);
            // weighted positions all predict copied tokens correctly
            for i in 0..31 {
                if w[row * 32 + i] > 0.0 {
                    assert_eq!(tgts[row * 32 + i], r[i + 1]);
                    assert!((sep_pos..sep_pos + span).contains(&i));
                }
            }
            // exactly span positions scored
            let scored: usize =
                w[row * 32..(row + 1) * 32].iter().filter(|&&x| x > 0.0).count();
            assert_eq!(scored, span);
        }
    }

    #[test]
    fn spans_vary() {
        let mut g = CopyTask::new(1);
        let b = g.batch(16, 64);
        let toks = b.tokens.as_i32().unwrap();
        let spans: std::collections::HashSet<usize> = (0..16)
            .map(|row| {
                toks[row * 64..(row + 1) * 64]
                    .iter()
                    .position(|&x| x == SEP)
                    .unwrap()
                    - 1
            })
            .collect();
        assert!(spans.len() > 3, "span lengths should vary, got {spans:?}");
    }
}
