//! Reverse task: `[BOS, x1..xL, SEP, xL..x1]` — reproduce the span
//! *backwards*.  A strictly harder routing pattern than copy: the
//! induction offset is different at every answer position (position i
//! must attend to position `2+2*span-i` instead of a constant shift), so
//! it stresses whether the attention approximation can express
//! position-dependent routing rather than a single induction head.

use super::{Batch, DataGen, SEP};
use crate::rng::Rng;
use crate::runtime::Tensor;
use crate::tokenizer::{BOS, PAD};

pub struct ReverseTask {
    rng: Rng,
    pub alphabet: i32,
}

impl ReverseTask {
    pub fn new(seed: u64) -> Self {
        ReverseTask { rng: Rng::new(seed), alphabet: 64 }
    }
}

impl DataGen for ReverseTask {
    fn name(&self) -> &'static str {
        "reverse"
    }

    fn batch(&mut self, batch: usize, t: usize) -> Batch {
        let mut tokens = vec![PAD; batch * t];
        let mut targets = vec![PAD; batch * t];
        let mut weights = vec![0f32; batch * t];
        let max_span = (t - 2) / 2;
        for b in 0..batch {
            let span = self.rng.uniform_int(1, max_span as u64 + 1) as usize;
            let row = &mut tokens[b * t..(b + 1) * t];
            row[0] = BOS;
            for i in 0..span {
                row[1 + i] = self.rng.uniform_int(0, self.alphabet as u64) as i32;
            }
            row[1 + span] = SEP;
            for i in 0..span {
                row[2 + span + i] = row[span - i]; // reversed
            }
            let trow = &mut targets[b * t..(b + 1) * t];
            let wrow = &mut weights[b * t..(b + 1) * t];
            for i in 0..t - 1 {
                trow[i] = row[i + 1];
            }
            for i in (1 + span)..(1 + 2 * span) {
                wrow[i] = 1.0;
            }
        }
        Batch {
            tokens: Tensor::i32(vec![batch, t], tokens),
            targets: Tensor::i32(vec![batch, t], targets),
            weights: Tensor::f32(vec![batch, t], weights),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answer_is_reversed_span() {
        let mut g = ReverseTask::new(0);
        let b = g.batch(8, 40);
        let toks = b.tokens.as_i32().unwrap();
        let w = b.weights.as_f32().unwrap();
        for row in 0..8 {
            let r = &toks[row * 40..(row + 1) * 40];
            let sep = r.iter().position(|&x| x == SEP).unwrap();
            let span = sep - 1;
            for i in 0..span {
                assert_eq!(r[sep + 1 + i], r[span - i], "row {row} pos {i}");
            }
            let scored: usize =
                w[row * 40..(row + 1) * 40].iter().filter(|&&x| x > 0.0).count();
            assert_eq!(scored, span);
        }
    }

    #[test]
    fn deterministic() {
        let a = ReverseTask::new(4).batch(2, 24);
        let b = ReverseTask::new(4).batch(2, 24);
        assert_eq!(a.tokens, b.tokens);
    }
}
