//! Byte-level language modelling over an embedded public-domain corpus.
//!
//! The paper has no dataset ("only tested on random data"), so the char-LM
//! workload uses a small embedded corpus of public-domain English prose and
//! verse — enough structure (word statistics, punctuation, rhythm) for a
//! few-million-parameter model to show a meaningful loss curve in a few
//! hundred steps, with zero external files.  Windows are sampled uniformly;
//! every position is scored.

use super::{Batch, DataGen};
use crate::rng::Rng;
use crate::runtime::Tensor;

/// Public-domain text (US founding documents, Shakespeare, Carroll,
/// Melville, Austen — all long out of copyright), concatenated.
pub const CORPUS: &str = "\
When in the Course of human events, it becomes necessary for one people to \
dissolve the political bands which have connected them with another, and to \
assume among the powers of the earth, the separate and equal station to \
which the Laws of Nature and of Nature's God entitle them, a decent respect \
to the opinions of mankind requires that they should declare the causes \
which impel them to the separation. We hold these truths to be self-evident, \
that all men are created equal, that they are endowed by their Creator with \
certain unalienable Rights, that among these are Life, Liberty and the \
pursuit of Happiness. That to secure these rights, Governments are \
instituted among Men, deriving their just powers from the consent of the \
governed. \
Shall I compare thee to a summer's day? Thou art more lovely and more \
temperate: Rough winds do shake the darling buds of May, And summer's lease \
hath all too short a date: Sometime too hot the eye of heaven shines, And \
often is his gold complexion dimm'd; And every fair from fair sometime \
declines, By chance, or nature's changing course, untrimm'd; But thy eternal \
summer shall not fade Nor lose possession of that fair thou ow'st; Nor shall \
Death brag thou wander'st in his shade, When in eternal lines to time thou \
grow'st; So long as men can breathe or eyes can see, So long lives this, and \
this gives life to thee. \
Alice was beginning to get very tired of sitting by her sister on the bank, \
and of having nothing to do: once or twice she had peeped into the book her \
sister was reading, but it had no pictures or conversations in it, 'and what \
is the use of a book,' thought Alice, 'without pictures or conversations?' \
So she was considering in her own mind (as well as she could, for the hot \
day made her feel very sleepy and stupid), whether the pleasure of making a \
daisy-chain would be worth the trouble of getting up and picking the \
daisies, when suddenly a White Rabbit with pink eyes ran close by her. \
Call me Ishmael. Some years ago - never mind how long precisely - having \
little or no money in my purse, and nothing particular to interest me on \
shore, I thought I would sail about a little and see the watery part of the \
world. It is a way I have of driving off the spleen and regulating the \
circulation. Whenever I find myself growing grim about the mouth; whenever \
it is a damp, drizzly November in my soul; whenever I find myself \
involuntarily pausing before coffin warehouses, and bringing up the rear of \
every funeral I meet; and especially whenever my hypos get such an upper \
hand of me, that it requires a strong moral principle to prevent me from \
deliberately stepping into the street, and methodically knocking people's \
hats off - then, I account it high time to get to sea as soon as I can. \
It is a truth universally acknowledged, that a single man in possession of \
a good fortune, must be in want of a wife. However little known the feelings \
or views of such a man may be on his first entering a neighbourhood, this \
truth is so well fixed in the minds of the surrounding families, that he is \
considered the rightful property of some one or other of their daughters. \
'My dear Mr. Bennet,' said his lady to him one day, 'have you heard that \
Netherfield Park is let at last?' Mr. Bennet replied that he had not. \
Four score and seven years ago our fathers brought forth on this continent, \
a new nation, conceived in Liberty, and dedicated to the proposition that \
all men are created equal. Now we are engaged in a great civil war, testing \
whether that nation, or any nation so conceived and so dedicated, can long \
endure. We are met on a great battle-field of that war. We have come to \
dedicate a portion of that field, as a final resting place for those who \
here gave their lives that that nation might live. It is altogether fitting \
and proper that we should do this. \
To be, or not to be, that is the question: Whether 'tis nobler in the mind \
to suffer The slings and arrows of outrageous fortune, Or to take arms \
against a sea of troubles And by opposing end them. To die - to sleep, No \
more; and by a sleep to say we end The heart-ache and the thousand natural \
shocks That flesh is heir to: 'tis a consummation Devoutly to be wish'd. \
";

pub struct CharLm {
    rng: Rng,
    corpus: Vec<u8>,
}

impl CharLm {
    pub fn new(seed: u64) -> Self {
        CharLm { rng: Rng::new(seed), corpus: CORPUS.as_bytes().to_vec() }
    }

    /// Corpus length in bytes (for sizing expectations in tests/docs).
    pub fn corpus_len(&self) -> usize {
        self.corpus.len()
    }
}

impl DataGen for CharLm {
    fn name(&self) -> &'static str {
        "charlm"
    }

    fn batch(&mut self, batch: usize, t: usize) -> Batch {
        assert!(self.corpus.len() > t + 1, "corpus shorter than window");
        let mut tokens = Vec::with_capacity(batch * t);
        let mut targets = Vec::with_capacity(batch * t);
        for _ in 0..batch {
            let start =
                self.rng.uniform_int(0, (self.corpus.len() - t - 1) as u64) as usize;
            tokens.extend(self.corpus[start..start + t].iter().map(|&b| b as i32));
            targets
                .extend(self.corpus[start + 1..start + t + 1].iter().map(|&b| b as i32));
        }
        Batch {
            tokens: Tensor::i32(vec![batch, t], tokens),
            targets: Tensor::i32(vec![batch, t], targets),
            weights: Tensor::f32(vec![batch, t], vec![1.0; batch * t]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_nontrivial() {
        let g = CharLm::new(0);
        assert!(g.corpus_len() > 4000, "corpus {} bytes", g.corpus_len());
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut g = CharLm::new(0);
        let b = g.batch(4, 32);
        let toks = b.tokens.as_i32().unwrap();
        let tgts = b.targets.as_i32().unwrap();
        for row in 0..4 {
            for i in 0..31 {
                assert_eq!(tgts[row * 32 + i], toks[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn bytes_only() {
        let mut g = CharLm::new(1);
        let b = g.batch(2, 64);
        assert!(b.tokens.as_i32().unwrap().iter().all(|&t| (0..256).contains(&t)));
    }
}
