//! Parameter / optimizer-state store.
//!
//! Rust owns model state end-to-end: initialization (from the manifest's
//! per-leaf init specs, with the coordinator's deterministic PRNG),
//! train-step plumbing (flat leaf lists in manifest order — the calling
//! convention of every AOT entry point), and checkpointing.

use anyhow::{anyhow, bail, Result};

use crate::rng::Rng;
use crate::runtime::{Init, LeafSpec, Tensor};

/// A named, ordered set of tensors matching a manifest leaf spec.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub leaves: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize from leaf specs (normal/zeros/ones as the manifest says).
    pub fn init(spec: &[LeafSpec], rng: &mut Rng) -> ParamStore {
        let mut names = Vec::with_capacity(spec.len());
        let mut leaves = Vec::with_capacity(spec.len());
        for s in spec {
            let n: usize = s.shape.iter().product();
            let t = match s.init {
                Init::Zeros => Tensor::f32(s.shape.clone(), vec![0.0; n]),
                Init::Ones => Tensor::f32(s.shape.clone(), vec![1.0; n]),
                Init::Normal { std } => {
                    Tensor::f32(s.shape.clone(), rng.normal_vec_f32(n, std))
                }
            };
            names.push(s.name.clone());
            leaves.push(t);
        }
        ParamStore { names, leaves }
    }

    /// All-zeros store with the same shapes (optimizer moments m, v).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            names: self.names.clone(),
            leaves: self
                .leaves
                .iter()
                .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.len()]))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.leaves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaves.is_empty()
    }

    pub fn total_elements(&self) -> usize {
        self.leaves.iter().map(|t| t.len()).sum()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("no leaf named '{name}'"))?;
        Ok(&self.leaves[i])
    }

    /// Replace all leaves from a drained slice (train-step outputs).
    pub fn replace_from(&mut self, new_leaves: Vec<Tensor>) -> Result<()> {
        if new_leaves.len() != self.leaves.len() {
            bail!(
                "leaf count mismatch: {} vs {}",
                new_leaves.len(),
                self.leaves.len()
            );
        }
        for (old, new) in self.leaves.iter().zip(&new_leaves) {
            if old.shape != new.shape {
                bail!("leaf shape changed: {:?} -> {:?}", old.shape, new.shape);
            }
        }
        self.leaves = new_leaves;
        Ok(())
    }

    /// Validate shapes against a spec (checkpoint-load safety).
    pub fn check_spec(&self, spec: &[LeafSpec]) -> Result<()> {
        if spec.len() != self.leaves.len() {
            bail!("spec has {} leaves, store has {}", spec.len(), self.leaves.len());
        }
        for (s, (n, t)) in spec.iter().zip(self.names.iter().zip(&self.leaves)) {
            if &s.name != n {
                bail!("leaf name mismatch: '{}' vs '{}'", s.name, n);
            }
            if s.shape != t.shape {
                bail!("leaf '{}' shape {:?} vs spec {:?}", n, t.shape, s.shape);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<LeafSpec> {
        vec![
            LeafSpec { name: "w".into(), shape: vec![4, 8], init: Init::Normal { std: 0.5 } },
            LeafSpec { name: "g".into(), shape: vec![8], init: Init::Ones },
            LeafSpec { name: "b".into(), shape: vec![8], init: Init::Zeros },
        ]
    }

    #[test]
    fn init_follows_spec() {
        let mut rng = Rng::new(0);
        let p = ParamStore::init(&spec(), &mut rng);
        assert_eq!(p.total_elements(), 32 + 8 + 8);
        assert!(p.get("g").unwrap().as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(p.get("b").unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
        let w = p.get("w").unwrap().as_f32().unwrap();
        assert!(w.iter().any(|&x| x != 0.0));
        // std scaling roughly holds
        let var = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        assert!(var > 0.05 && var < 1.0, "var {var}");
        p.check_spec(&spec()).unwrap();
    }

    #[test]
    fn init_is_deterministic() {
        let a = ParamStore::init(&spec(), &mut Rng::new(7));
        let b = ParamStore::init(&spec(), &mut Rng::new(7));
        assert_eq!(a.leaves, b.leaves);
    }

    #[test]
    fn replace_guards_shapes() {
        let mut p = ParamStore::init(&spec(), &mut Rng::new(0));
        let bad = vec![Tensor::f32(vec![2], vec![0.0; 2]); 3];
        assert!(p.replace_from(bad).is_err());
        let good = p.leaves.clone();
        p.replace_from(good).unwrap();
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let p = ParamStore::init(&spec(), &mut Rng::new(0));
        let z = p.zeros_like();
        assert_eq!(z.total_elements(), p.total_elements());
        assert!(z.leaves.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
    }
}
