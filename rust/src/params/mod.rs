//! Parameter / optimizer-state store.
//!
//! Rust owns model state end-to-end: initialization (from the manifest's
//! per-leaf init specs, with the coordinator's deterministic PRNG),
//! train-step plumbing (flat leaf lists in manifest order — the calling
//! convention of every AOT entry point), and checkpointing.

use anyhow::{anyhow, bail, Result};

use crate::rng::Rng;
use crate::runtime::{Init, LeafSpec, Tensor};

/// A named, ordered set of tensors matching a manifest leaf spec.
#[derive(Debug, Clone)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub leaves: Vec<Tensor>,
}

impl ParamStore {
    /// Initialize from leaf specs (normal/zeros/ones as the manifest says).
    pub fn init(spec: &[LeafSpec], rng: &mut Rng) -> ParamStore {
        let mut names = Vec::with_capacity(spec.len());
        let mut leaves = Vec::with_capacity(spec.len());
        for s in spec {
            let n: usize = s.shape.iter().product();
            let t = match s.init {
                Init::Zeros => Tensor::f32(s.shape.clone(), vec![0.0; n]),
                Init::Ones => Tensor::f32(s.shape.clone(), vec![1.0; n]),
                Init::Normal { std } => {
                    Tensor::f32(s.shape.clone(), rng.normal_vec_f32(n, std))
                }
            };
            names.push(s.name.clone());
            leaves.push(t);
        }
        ParamStore { names, leaves }
    }

    /// All-zeros store with the same shapes (optimizer moments m, v).
    pub fn zeros_like(&self) -> ParamStore {
        ParamStore {
            names: self.names.clone(),
            leaves: self
                .leaves
                .iter()
                .map(|t| Tensor::f32(t.shape.clone(), vec![0.0; t.len()]))
                .collect(),
        }
    }

    /// Elementwise `self += other` over every f32 leaf — the merge step
    /// of the trainer's deterministic gradient tree reduction, so the
    /// accumulation order is fixed by the tree shape, never by thread
    /// timing.
    pub fn add_assign(&mut self, other: &ParamStore) -> Result<()> {
        if self.leaves.len() != other.leaves.len() {
            bail!(
                "add_assign leaf count mismatch: {} vs {}",
                self.leaves.len(),
                other.leaves.len()
            );
        }
        for (a, b) in self.leaves.iter_mut().zip(&other.leaves) {
            if a.shape != b.shape {
                bail!("add_assign shape mismatch: {:?} vs {:?}", a.shape, b.shape);
            }
            let src = b.as_f32()?;
            for (x, &y) in a.as_f32_mut()?.iter_mut().zip(src) {
                *x += y;
            }
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.leaves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leaves.is_empty()
    }

    pub fn total_elements(&self) -> usize {
        self.leaves.iter().map(|t| t.len()).sum()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        let i = self
            .names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("no leaf named '{name}'"))?;
        Ok(&self.leaves[i])
    }

    /// Replace all leaves from a drained slice (train-step outputs).
    pub fn replace_from(&mut self, new_leaves: Vec<Tensor>) -> Result<()> {
        if new_leaves.len() != self.leaves.len() {
            bail!(
                "leaf count mismatch: {} vs {}",
                new_leaves.len(),
                self.leaves.len()
            );
        }
        for (old, new) in self.leaves.iter().zip(&new_leaves) {
            if old.shape != new.shape {
                bail!("leaf shape changed: {:?} -> {:?}", old.shape, new.shape);
            }
        }
        self.leaves = new_leaves;
        Ok(())
    }

    /// Validate shapes against a spec (checkpoint-load safety).
    pub fn check_spec(&self, spec: &[LeafSpec]) -> Result<()> {
        if spec.len() != self.leaves.len() {
            bail!("spec has {} leaves, store has {}", spec.len(), self.leaves.len());
        }
        for (s, (n, t)) in spec.iter().zip(self.names.iter().zip(&self.leaves)) {
            if &s.name != n {
                bail!("leaf name mismatch: '{}' vs '{}'", s.name, n);
            }
            if s.shape != t.shape {
                bail!("leaf '{}' shape {:?} vs spec {:?}", n, t.shape, s.shape);
            }
        }
        Ok(())
    }
}

/// AdamW hyper-parameters — exactly the constants the fused train
/// artifact was lowered with (`python/compile/model.py`), so the native
/// and artifact paths walk the same optimizer trajectory.
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.01;

/// Per-leaf weight-decay coefficients from a param spec: decay applies
/// to matrix leaves only, with the (tied) embedding and the learned
/// positions exempt — the GPT-2 convention, mirror of python
/// `train_step`'s `decays` list.
pub fn adamw_decay_mask(spec: &[LeafSpec]) -> Vec<f32> {
    spec.iter()
        .map(|s| {
            if s.shape.len() == 2 && s.name != "embed" && s.name != "pos" {
                WEIGHT_DECAY
            } else {
                0.0
            }
        })
        .collect()
}

/// One AdamW update over every leaf, in place.  `step` is the
/// *incremented* step count (≥ 1, used for bias correction), matching
/// python `train_step` which bumps the counter before correcting.  All
/// arithmetic in f32, like the lowered artifact.
pub fn adamw_step(
    params: &mut ParamStore,
    grads: &ParamStore,
    m: &mut ParamStore,
    v: &mut ParamStore,
    step: u64,
    lr: f32,
    decay: &[f32],
) -> Result<()> {
    let np = params.len();
    if grads.len() != np || m.len() != np || v.len() != np || decay.len() != np {
        bail!(
            "adamw state mismatch: {np} params, {} grads, {} m, {} v, {} decay",
            grads.len(),
            m.len(),
            v.len(),
            decay.len()
        );
    }
    if step == 0 {
        bail!("adamw_step takes the incremented step count (>= 1)");
    }
    let b1t = ADAM_B1.powi(step.min(i32::MAX as u64) as i32);
    let b2t = ADAM_B2.powi(step.min(i32::MAX as u64) as i32);
    for i in 0..np {
        let g = grads.leaves[i].as_f32()?;
        let n = params.leaves[i].len();
        if g.len() != n || m.leaves[i].len() != n || v.leaves[i].len() != n {
            bail!(
                "leaf '{}' size mismatch: {} params, {} grad, {} m, {} v",
                params.names[i],
                n,
                g.len(),
                m.leaves[i].len(),
                v.leaves[i].len()
            );
        }
        let wd = decay[i];
        let p = params.leaves[i].as_f32_mut()?;
        let mm = m.leaves[i].as_f32_mut()?;
        let vv = v.leaves[i].as_f32_mut()?;
        for j in 0..p.len() {
            mm[j] = ADAM_B1 * mm[j] + (1.0 - ADAM_B1) * g[j];
            vv[j] = ADAM_B2 * vv[j] + (1.0 - ADAM_B2) * g[j] * g[j];
            let mhat = mm[j] / (1.0 - b1t);
            let vhat = vv[j] / (1.0 - b2t);
            p[j] -= lr * (mhat / (vhat.sqrt() + ADAM_EPS) + wd * p[j]);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<LeafSpec> {
        vec![
            LeafSpec { name: "w".into(), shape: vec![4, 8], init: Init::Normal { std: 0.5 } },
            LeafSpec { name: "g".into(), shape: vec![8], init: Init::Ones },
            LeafSpec { name: "b".into(), shape: vec![8], init: Init::Zeros },
        ]
    }

    #[test]
    fn init_follows_spec() {
        let mut rng = Rng::new(0);
        let p = ParamStore::init(&spec(), &mut rng);
        assert_eq!(p.total_elements(), 32 + 8 + 8);
        assert!(p.get("g").unwrap().as_f32().unwrap().iter().all(|&x| x == 1.0));
        assert!(p.get("b").unwrap().as_f32().unwrap().iter().all(|&x| x == 0.0));
        let w = p.get("w").unwrap().as_f32().unwrap();
        assert!(w.iter().any(|&x| x != 0.0));
        // std scaling roughly holds
        let var = w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        assert!(var > 0.05 && var < 1.0, "var {var}");
        p.check_spec(&spec()).unwrap();
    }

    #[test]
    fn init_is_deterministic() {
        let a = ParamStore::init(&spec(), &mut Rng::new(7));
        let b = ParamStore::init(&spec(), &mut Rng::new(7));
        assert_eq!(a.leaves, b.leaves);
    }

    #[test]
    fn replace_guards_shapes() {
        let mut p = ParamStore::init(&spec(), &mut Rng::new(0));
        let bad = vec![Tensor::f32(vec![2], vec![0.0; 2]); 3];
        assert!(p.replace_from(bad).is_err());
        let good = p.leaves.clone();
        p.replace_from(good).unwrap();
    }

    #[test]
    fn adamw_first_step_is_signed_unit_step() {
        // with zero moments, step 1 gives p -= lr * sign(g) (bias
        // correction cancels the (1-β) factors; eps is negligible here)
        let mut p = ParamStore::init(&spec(), &mut Rng::new(1));
        let before = p.leaves[0].as_f32().unwrap().to_vec();
        let mut g = p.zeros_like();
        g.leaves[0].as_f32_mut().unwrap().fill(0.5);
        let mut m = p.zeros_like();
        let mut v = p.zeros_like();
        let decay = vec![0.0; p.len()];
        adamw_step(&mut p, &g, &mut m, &mut v, 1, 0.1, &decay).unwrap();
        for (a, b) in p.leaves[0].as_f32().unwrap().iter().zip(&before) {
            assert!((a - (b - 0.1)).abs() < 1e-5, "{a} vs {b}");
        }
        // leaves with zero grad are untouched when decay is off
        assert_eq!(p.leaves[1].as_f32().unwrap(), &vec![1.0f32; 8][..]);
    }

    #[test]
    fn adamw_decay_mask_follows_gpt2_convention() {
        let mut s = spec();
        s.push(LeafSpec {
            name: "embed".into(),
            shape: vec![4, 8],
            init: Init::Normal { std: 0.5 },
        });
        let mask = adamw_decay_mask(&s);
        assert_eq!(mask[0], WEIGHT_DECAY, "matrix leaf decays");
        assert_eq!(mask[1], 0.0, "vector leaf exempt");
        assert_eq!(mask[3], 0.0, "embedding exempt despite rank 2");
    }

    #[test]
    fn adamw_rejects_mismatched_state() {
        let mut p = ParamStore::init(&spec(), &mut Rng::new(2));
        let g = p.zeros_like();
        let mut m = p.zeros_like();
        let mut v = p.zeros_like();
        assert!(adamw_step(&mut p, &g, &mut m, &mut v, 1, 0.1, &[0.0]).is_err());
        let decay = vec![0.0; p.len()];
        assert!(adamw_step(&mut p, &g, &mut m, &mut v, 0, 0.1, &decay).is_err());
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let p = ParamStore::init(&spec(), &mut Rng::new(0));
        let z = p.zeros_like();
        assert_eq!(z.total_elements(), p.total_elements());
        assert!(z.leaves.iter().all(|t| t.as_f32().unwrap().iter().all(|&x| x == 0.0)));
    }
}
