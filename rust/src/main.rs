//! `holt` — the CLI front end of the coordinator.
//!
//! Subcommands:
//!   info                     list models (+ artifacts when present)
//!   train                    run a training job (E3 / E6)
//!   generate                 sample a completion (native or artifact)
//!   serve                    continuous-batching server (TCP or synthetic)
//!   client                   load generator against a running server
//!   approx                   E1 approximation-quality table
//!   fig1                     regenerate the paper's Figure 1 data
//!
//! `train`, `ablation`, `generate`, `serve` and `eval` take `--backend
//! native|artifact` (default: native).  The native backend is the
//! pure-Rust model executor + trainer (`holt::model`, hand-derived O(n)
//! backward) — no artifacts, no PJRT, no Python, works on a clean
//! checkout.  The artifact backend is the original PJRT path and needs
//! `make artifacts` plus a real `xla` crate.
//!
//! Argument parsing is hand-rolled (clap is not in the offline vendor
//! set): `--key value` flags after the subcommand, `--help` anywhere.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use holt::checkpoint::Checkpoint;
use holt::config::{ServeConfig, Toml, TrainConfig};
use holt::coordinator::generation::{Generator, SampleOpts};
use holt::coordinator::server;
use holt::coordinator::trainer::{
    run_training, ArtifactTrainer, NativeTrainer, TrainBackend,
};
use holt::experiments;
use holt::json::{obj, Json};
use holt::model::{native_model_entry, ArtifactExecutor, Executor, NativeExecutor};
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{ModelEntry, Runtime};
use holt::serve::{Policy, ServeOpts};
use holt::state::StateDtype;

/// Parsed `--key value` flags (plus bare `--flag` booleans).
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let bare_bool = i + 1 >= argv.len() || argv[i + 1].starts_with("--");
                if bare_bool {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                }
            } else {
                bail!("unexpected positional argument '{a}' (flags are --key value)");
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "\
holt — Higher Order Linear Transformer coordinator

USAGE: holt <command> [--key value ...]

ARTIFACT-FREE QUICKSTART (pure-Rust executor; no artifacts, no Python):
  holt train    --backend native --model ho2_tiny --task copy --steps 200
  holt ablation --backend native --steps 120        # E6 alpha/order grid
  holt generate --backend native --prompt \"Call me \"
  holt serve    --backend native --synthetic --requests 8
  holt serve    --backend native --model ho2_tiny       # TCP on :8490
  holt eval     --backend native --model ho2_tiny --task charlm
  holt crosscheck --native                # Taylor orders 0-3 vs the oracle

ORDER-3 QUICKSTART (beyond the paper: same kernel, one more Taylor term —
`ho[_oR]` makes the order a config value, `ho_tiny_o3` = order 3):
  holt crosscheck --native
  holt train    --backend native --model ho_tiny_o3 --task copy --steps 40
  holt generate --backend native --model ho_tiny_o3 --max-tokens 16
  holt serve    --backend native --model ho_tiny_o3 --synthetic --requests 4

COMMANDS
  info       [--backend native|artifact] list models (and artifacts)
  train      --model M --task T --steps N [--backend native|artifact
             --lr X --seed S --warmup W --log-every K --eval-every K
             --ckpt-every K --out DIR --config FILE --resume CKPT
             --min-loss-ratio R]
             (native: one-forward backward — the vjp consumes the
              forward's captured tape, no replay — + AdamW, no
              artifacts; --min-loss-ratio fails the run unless
              final/first <= R)
             [--accum N --grad-workers W]    (native only)
             (micro-batch gradient accumulation over W data-parallel
              workers, 0 = whole pool; deterministic tree reduction —
              the loss curve is bit-identical for every N and W)
  generate   --model M [--backend native|artifact --ckpt FILE --prompt STR
             --max-tokens N --temperature X --top-k K --seed S]
  serve      --model M [--backend native|artifact --ckpt FILE
             --addr HOST:PORT --seed S]
             [--policy fifo|priority|fair --prefill-chunk N
              --session-cache-mb N --state-dtype f64|f32|f16|bf16|int8
              --preempt-tokens N --queue-cap N --stream]
             (scheduler: chunked prefill, O(1)-state preemption when
              waiters queue, byte-budgeted LRU session cache, streamed
              deltas; --state-dtype picks the wire encoding for cached
              snapshots — f64 is bit-lossless, f16/bf16/int8 trade
              bounded logit drift for 4-8x more resident sessions;
              restore always rehydrates full-precision state)
             [--shards N --global-queue N]
             (TCP serving runs N engine shards — default one per core;
              --shards 1 restores the single engine — behind a session
              router: session_id hash affinity, few-KiB snapshot
              migration off saturated shards, global load shedding with
              explicit `overloaded` errors; `{\"stats\": true}` on the
              wire returns per-shard + aggregate stats as one JSON line)
             [--metrics-log PATH --flight-recorder N]
             (observability: `{\"metrics\": true}` on the wire dumps every
              shard's metric registry — counters, gauges, per-stage span
              histograms like prefill_us/decode_step_us/migrate_us;
              `{\"trace\": ID}` replays one request's flight-recorder
              lifecycle (admit/park/resume/migrate/finish) across shards
              in time order, using the trace id the router mints per
              request; --flight-recorder N bounds the per-shard event
              ring (default 256); --metrics-log PATH appends router
              JSONL: periodic load lines, overload flight dumps, final
              per-shard registry dumps)
             [--synthetic --requests N --prompt-len L --max-tokens N
              --gap-ms MS --turns K --out DIR]
             (synthetic benches chunked vs token-at-a-time prefill plus
              session reuse -> bench_serve.json)
             [--synthetic --shards N --sessions N --zipf S]
             (multi-shard overload bench: Zipf-skewed session reuse and
              mixed priorities offered to 1 shard then N; per-shard +
              aggregate p50/p95/p99, tok/s, migrations and rejections
              -> bench_serve.json `shard_overload` record)
  client     --addr HOST:PORT [--requests N --concurrency C
             --prompt STR --max-tokens N]
  approx     [--seed S --out DIR --native] E1 approximation table
                                           (--native: O(n) kernels, no artifacts)
  fig1       [--points N --out DIR]        Figure 1 data
  crosscheck [--artifact NAME | --native]  artifact (or native O(n) kernel)
                                           vs the O(n^2) rust reference
  ablation   [--backend native|artifact --steps N --task T]
                                           E6 alpha/order training grid
  eval       --model M [--backend native|artifact --ckpt FILE --task T
             --batches N]                 held-out loss/ppl/accuracy
  plot       --files a.jsonl,b.jsonl [--y loss --event step --x step]
                                           terminal chart of metric curves
  ckpt-info  --ckpt FILE                   inspect a checkpoint

Native model names: {attn}_{preset}[_aA][_oR][_sD] with attn in {ho,
ho2, linear, softmax} and preset in {tiny, small, base, large}, e.g.
ho2_small, linear_tiny, ho2_tiny_a1_o1.  `ho` is the Taylor kernel at
any order R (default 2) — ho_tiny_o3 runs the order-3 experiment the
paper never did; `ho2` stays as the historic alias.  `_sD` with D in
{f64, f32, f16, bf16, int8} sets the model's default snapshot dtype
(e.g. ho2_tiny_sf16; serve --state-dtype overrides).  The artifact
path locates artifacts via $HOLT_ARTIFACTS or ./artifacts.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.has("help") {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => cmd_info(args),
        "train" => cmd_train(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "approx" => cmd_approx(args),
        "fig1" => cmd_fig1(args),
        "crosscheck" => cmd_crosscheck(args),
        "ablation" => cmd_ablation(args),
        "eval" => cmd_eval(args),
        "plot" => cmd_plot(args),
        "ckpt-info" => cmd_ckpt_info(args),
        _ => bail!("unknown command '{cmd}'\n\n{USAGE}"),
    }
}

fn runtime() -> Result<Runtime> {
    Runtime::new(&holt::default_artifacts_dir()?)
}

/// Which executor family a command should build.
fn backend_of<'a>(args: &'a Args) -> Result<&'a str> {
    let b = args.get("backend").unwrap_or("native");
    if b == "native" || b == "artifact" {
        Ok(b)
    } else {
        bail!("--backend must be 'native' or 'artifact', got '{b}'")
    }
}

/// Parameters for a native model entry: checkpoint if given, else init.
fn load_params_native(entry: &ModelEntry, ckpt: Option<&str>, seed: u64) -> Result<ParamStore> {
    match ckpt {
        Some(path) => {
            let ck = Checkpoint::load(std::path::Path::new(path))?;
            let p = ck.section("params")?.clone();
            p.check_spec(&entry.param_spec)
                .context("checkpoint does not match this model")?;
            println!("loaded checkpoint at step {}", ck.step);
            Ok(p)
        }
        None => {
            eprintln!("note: no --ckpt given, using random init");
            Ok(ParamStore::init(&entry.param_spec, &mut Rng::new(seed)))
        }
    }
}

fn load_params(rt: &Runtime, model: &str, ckpt: Option<&str>, seed: u64) -> Result<ParamStore> {
    load_params_native(rt.manifest.model(model)?, ckpt, seed)
}

/// One executor construction path for every backend-aware command
/// (generate / serve / eval).  Both executor types own their resources,
/// so the boxed trait object is `'static` and the artifact `Runtime` can
/// be dropped here.
fn build_executor(
    backend: &str,
    model: &str,
    ckpt: Option<&str>,
    seed: u64,
) -> Result<Box<dyn Executor + Send>> {
    match backend {
        "native" => {
            let entry = native_model_entry(model)?;
            let params = load_params_native(&entry, ckpt, seed)?;
            Ok(Box::new(NativeExecutor::new(entry, params)?))
        }
        _ => {
            let rt = runtime()?;
            let params = load_params(&rt, model, ckpt, seed)?;
            Ok(Box::new(ArtifactExecutor::new(&rt, model, params)?))
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    if backend_of(args)? == "native" {
        println!("native backend (pure-Rust executor, no artifacts)\n\nmodels:");
        for preset in holt::model::PRESET_NAMES {
            for attn in holt::model::ATTN_KINDS {
                let m = native_model_entry(&format!("{attn}_{preset}"))?;
                println!(
                    "  {:<28} {:>10} params  attn={} order={} alpha={} d={} L={} ctx={}{}",
                    m.name,
                    m.n_params,
                    m.config.attn,
                    m.config.order,
                    m.config.alpha,
                    m.config.d_model,
                    m.config.n_layers,
                    m.config.max_len,
                    if attn == "softmax" { "  (forward/eval only)" } else { "" },
                );
            }
        }
        println!(
            "\n(+ ablation variants like ho2_tiny_a1_o1 and higher Taylor orders \
             via ho_{{preset}}_oR, e.g. ho_tiny_o3; \
             `holt info --backend artifact` lists lowered artifacts)"
        );
        return Ok(());
    }
    let rt = runtime()?;
    println!("platform: {}", rt.platform());
    println!("\nmodels:");
    let mut models: Vec<_> = rt.manifest.models.values().collect();
    models.sort_by(|a, b| a.name.cmp(&b.name));
    for m in models {
        println!(
            "  {:<28} {:>10} params  attn={} order={} alpha={} d={} L={} ctx={}",
            m.name,
            m.n_params,
            m.config.attn,
            m.config.order,
            m.config.alpha,
            m.config.d_model,
            m.config.n_layers,
            m.config.max_len,
        );
    }
    println!("\nartifacts: {}", rt.manifest.artifacts.len());
    for name in rt.manifest.artifact_names() {
        let a = &rt.manifest.artifacts[&name];
        println!("  {:<32} {} in / {} out", name, a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

/// One trainer construction path for both training backends, with
/// optional checkpoint resume.  Both trainer types own their resources,
/// so the boxed trait object is `'static` (the artifact `Runtime` is
/// dropped here, its executables `Arc`-shared).
fn build_trainer(
    backend: &str,
    model: &str,
    seed: u64,
    resume: Option<&str>,
    cfg: &TrainConfig,
) -> Result<Box<dyn TrainBackend>> {
    let ckpt = match resume {
        Some(path) => {
            let ck = Checkpoint::load(std::path::Path::new(path))?;
            println!("resuming from checkpoint at step {}", ck.step);
            Some(ck)
        }
        None => None,
    };
    match backend {
        "native" => {
            let mut t = match ckpt {
                Some(ck) => NativeTrainer::from_checkpoint(model, &ck)?,
                None => NativeTrainer::new(model, seed)?,
            };
            t.accum = cfg.accum.max(1);
            t.grad_workers = cfg.grad_workers;
            Ok(Box::new(t))
        }
        _ => {
            // the fused train artifact is a single whole-batch step;
            // accumulation knobs are native-only
            if cfg.accum > 1 || cfg.grad_workers != 0 {
                bail!("--accum/--grad-workers require --backend native");
            }
            let rt = runtime()?;
            Ok(match ckpt {
                Some(ck) => Box::new(ArtifactTrainer::from_checkpoint(&rt, model, &ck)?),
                None => Box::new(ArtifactTrainer::new(&rt, model, seed)?),
            })
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        cfg.apply_toml(&Toml::load(std::path::Path::new(path))?)?;
    }
    if let Some(m) = args.get("model") {
        cfg.model = m.into();
    }
    if let Some(t) = args.get("task") {
        cfg.task = t.into();
    }
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.lr = args.get_f64("lr", cfg.lr)?;
    cfg.warmup = args.get_usize("warmup", cfg.warmup)?;
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.log_every = args.get_usize("log-every", cfg.log_every)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.ckpt_every = args.get_usize("ckpt-every", cfg.ckpt_every)?;
    if let Some(o) = args.get("out") {
        cfg.out_dir = o.into();
    }
    cfg.accum = args.get_usize("accum", cfg.accum)?;
    cfg.grad_workers = args.get_usize("grad-workers", cfg.grad_workers)?;
    if cfg.accum == 0 {
        bail!("--accum must be >= 1");
    }

    let backend = backend_of(args)?;
    let mut trainer = build_trainer(backend, &cfg.model, cfg.seed, args.get("resume"), &cfg)?;
    println!(
        "training {} [{}] on task '{}' for {} steps (lr {:.2e}, seed {})",
        cfg.model, backend, cfg.task, cfg.steps, cfg.lr, cfg.seed
    );
    let t0 = Instant::now();
    let history = run_training(trainer.as_mut(), &cfg, false)?;
    let first_loss = history.first().map(|s| s.loss).unwrap_or(f32::NAN);
    let final_loss = history.last().map(|s| s.loss).unwrap_or(f32::NAN);
    println!(
        "done: {} steps in {:.1}s, loss {:.4} -> {:.4} (ratio {:.3})",
        history.len(),
        t0.elapsed().as_secs_f64(),
        first_loss,
        final_loss,
        final_loss / first_loss
    );
    // CI / acceptance hook: fail loudly when training didn't train
    if let Some(max_ratio) = args.get("min-loss-ratio") {
        let max_ratio: f32 = max_ratio
            .parse()
            .context("--min-loss-ratio must be a number in (0, 1]")?;
        if max_ratio <= 0.0 || max_ratio > 1.0 || max_ratio.is_nan() {
            bail!("--min-loss-ratio must be in (0, 1], got {max_ratio}");
        }
        let ratio = final_loss / first_loss;
        if !ratio.is_finite() || ratio > max_ratio {
            bail!(
                "loss ratio {ratio:.3} exceeds --min-loss-ratio {max_ratio} \
                 (loss {first_loss:.4} -> {final_loss:.4})"
            );
        }
    }
    Ok(())
}

fn run_generate(exec: Box<dyn Executor + '_>, args: &Args, seed: u64) -> Result<()> {
    let opts = SampleOpts {
        temperature: args.get_f64("temperature", 0.8)? as f32,
        top_k: args.get_usize("top-k", 40)?,
        max_tokens: args.get_usize("max-tokens", 64)?,
    };
    let prompt = args.get("prompt").unwrap_or("The ").to_string();
    let mut gen = Generator::new(exec)?;
    let mut rng = Rng::new(seed ^ 0x9e37);
    let t0 = Instant::now();
    let (ids, text) = gen.generate(&prompt, opts, &mut rng)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{prompt}{text}");
    eprintln!(
        "[{} backend: {} tokens in {:.2}s = {:.1} tok/s, {:.1} KiB O(1) state/slot]",
        gen.backend_name(),
        ids.len(),
        dt,
        ids.len() as f64 / dt,
        gen.state_bytes_per_slot() as f64 / 1024.0,
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("ho2_small").to_string();
    let seed = args.get_usize("seed", 0)? as u64;
    let exec = build_executor(backend_of(args)?, &model, args.get("ckpt"), seed)?;
    run_generate(exec, args, seed)
}

/// `holt serve` scheduler flags → [`ServeOpts`] (defaults come from
/// `ServeOpts::default()` so the flag defaults can't drift from it).
/// `model_default` is the model's `_s{dtype}` preset suffix, used when
/// no `--state-dtype` flag is given.
fn serve_opts(args: &Args, model_default: StateDtype) -> Result<ServeOpts> {
    let d = ServeOpts::default();
    Ok(ServeOpts {
        policy: Policy::parse(args.get("policy").unwrap_or(d.policy.name()))?,
        prefill_chunk: args.get_usize("prefill-chunk", d.prefill_chunk)?,
        session_cache_bytes: args.get_usize("session-cache-mb", d.session_cache_bytes >> 20)?
            << 20,
        state_dtype: match args.get("state-dtype") {
            Some(s) => StateDtype::parse(s)?,
            None => model_default,
        },
        preempt_tokens: args.get_usize("preempt-tokens", d.preempt_tokens)?,
        queue_capacity: args.get_usize("queue-cap", d.queue_capacity)?,
        stream_default: args.has("stream") || d.stream_default,
        flight_capacity: args.get_usize("flight-recorder", d.flight_capacity)?,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ServeConfig {
        model: args.get("model").unwrap_or("ho2_small").to_string(),
        ckpt: args.get("ckpt").map(String::from),
        addr: args.get("addr").unwrap_or("127.0.0.1:8490").to_string(),
        seed: args.get_usize("seed", 0)? as u64,
        ..Default::default()
    };
    // the `_s{dtype}` preset suffix is the model's snapshot-dtype
    // default; artifact-manifest names that don't parse natively fall
    // back to lossless f64 (the `--state-dtype` flag overrides either)
    let model_default = native_model_entry(&cfg.model)
        .map(|e| e.config.state_dtype)
        .unwrap_or_default();
    let opts = serve_opts(args, model_default)?;
    let backend = backend_of(args)?;
    let build = || build_executor(backend, &cfg.model, cfg.ckpt.as_deref(), cfg.seed);
    // --shards N: N engine shards behind the session router; N = 0 (or
    // the bare flag) means one shard per core
    let shards_flag = args.has("shards");
    let shards = match args.get_usize("shards", 0)? {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };
    let ropts = server::RouterOpts {
        global_queue: args
            .get_usize("global-queue", server::RouterOpts::default().global_queue)?,
        metrics_log: args.get("metrics-log").map(PathBuf::from),
    };
    if !args.has("synthetic") {
        // TCP serving is sharded by default (one engine per core); pass
        // --shards 1 for the single-engine PR-4 behavior
        let mut execs = Vec::with_capacity(shards);
        for _ in 0..shards {
            execs.push(build()?);
        }
        return server::serve_tcp_sharded(execs, &cfg.addr, cfg.seed, opts, ropts);
    }

    let requests = args.get_usize("requests", 32)?;
    let prompt_len = args.get_usize("prompt-len", 32)?;
    let max_tokens = args.get_usize("max-tokens", 32)?;
    let gap_ms = args.get_usize("gap-ms", 0)? as u64;
    let turns = args.get_usize("turns", 2)?;

    if shards_flag {
        // --synthetic --shards N: the multi-shard overload bench — the
        // same Zipf-skewed session load offered to 1 shard and to N, so
        // the speedup and the migration/shedding counters land in one
        // record of results/bench_serve.json
        let bench = server::OverloadOpts {
            requests,
            sessions: args.get_usize("sessions", 64)?,
            prompt_len,
            max_tokens,
            zipf_s: args.get_f64("zipf", 1.1)?,
            gap_ms,
        };
        let single = server::run_overload_sharded(
            vec![build()?],
            cfg.seed,
            opts.clone(),
            ropts.clone(),
            bench.clone(),
        )?;
        println!("--- overload, 1 shard (baseline) ---\n{}\n", single.report());
        let mut execs = Vec::with_capacity(shards);
        for _ in 0..shards {
            execs.push(build()?);
        }
        let sharded = server::run_overload_sharded(execs, cfg.seed, opts, ropts, bench)?;
        println!("--- overload, {shards} shards ---\n{}\n", sharded.report());
        let speedup = if single.tokens_per_sec() > 0.0 {
            sharded.tokens_per_sec() / single.tokens_per_sec()
        } else {
            0.0
        };
        println!(
            "aggregate decode throughput: {:.1} -> {:.1} tok/s ({:.2}x with {} shards)",
            single.tokens_per_sec(),
            sharded.tokens_per_sec(),
            speedup,
            shards,
        );
        let record = obj(vec![(
            "shard_overload",
            obj(vec![
                ("single_shard", single.to_json()),
                ("sharded", sharded.to_json()),
                ("speedup_vs_single", speedup.into()),
            ]),
        )]);
        let out = PathBuf::from(args.get("out").unwrap_or("results"));
        let path = experiments::write_results(&out, "bench_serve.json", &format!("{record}\n"))?;
        println!("wrote {path:?}");
        return Ok(());
    }

    // synthetic mode without --shards is the single-engine serving
    // bench: the same load with chunked prefill on vs off, plus a
    // multi-turn pass through the session cache — all three records
    // land in results/bench_serve.json

    let chunked = server::run_synthetic_opts(
        build()?, requests, prompt_len, max_tokens, gap_ms, cfg.seed, opts.clone(),
    )?;
    println!("--- prefill chunked ({}/step) ---\n{}\n", chunked.prefill_chunk, chunked.report());
    let token_at_a_time = server::run_synthetic_opts(
        build()?,
        requests,
        prompt_len,
        max_tokens,
        gap_ms,
        cfg.seed,
        ServeOpts { prefill_chunk: 1, ..opts.clone() },
    )?;
    println!("--- prefill token-at-a-time ---\n{}\n", token_at_a_time.report());
    let sessions = server::run_synthetic_sessions(
        build()?,
        4,
        turns.max(1),
        prompt_len.min(16),
        max_tokens.min(8),
        cfg.seed,
        opts,
    )?;
    println!("--- session reuse ({turns} turns x 4 sessions) ---\n{}\n", sessions.report());
    println!(
        "prefill chunking: {:.1} -> {:.1} tok/s ({} -> {} engine steps); \
         session cache: {} hits / {} misses",
        token_at_a_time.tokens_per_sec(),
        chunked.tokens_per_sec(),
        token_at_a_time.engine_steps,
        chunked.engine_steps,
        sessions.session_hits,
        sessions.session_misses,
    );

    let record = obj(vec![
        ("prefill_chunked", chunked.to_json()),
        ("token_at_a_time", token_at_a_time.to_json()),
        ("session_reuse", sessions.to_json()),
    ]);
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let path = experiments::write_results(&out, "bench_serve.json", &format!("{record}\n"))?;
    println!("wrote {path:?}");
    // --metrics-log PATH: single-engine synthetic runs have no router
    // writing the JSONL, so dump each bench's final registry here
    if let Some(mpath) = args.get("metrics-log") {
        let mut w = holt::json::JsonlWriter::create(mpath)?;
        for (name, s) in [
            ("prefill_chunked", &chunked),
            ("token_at_a_time", &token_at_a_time),
            ("session_reuse", &sessions),
        ] {
            w.write(&obj(vec![
                ("event", "synthetic_final".into()),
                ("bench", name.into()),
                ("metrics", s.metrics.clone()),
            ]))?;
        }
        w.flush()?;
        println!("wrote {mpath}");
    }
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:8490").to_string();
    let n = args.get_usize("requests", 8)?;
    let conc = args.get_usize("concurrency", 4)?.max(1);
    let max_tokens = args.get_usize("max-tokens", 32)?;
    let prompt = args.get("prompt").unwrap_or("Call me ").to_string();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..conc {
        let reqs = n / conc + usize::from(w < n % conc);
        if reqs == 0 {
            // more workers than requests: an idle worker would still open
            // a connection and fold a bogus 0-latency sample into the mean
            continue;
        }
        let addr = addr.clone();
        let prompt = prompt.clone();
        handles.push(std::thread::spawn(move || -> Result<(u64, f64)> {
            let mut tokens = 0u64;
            let mut lat = 0.0;
            let stream = std::net::TcpStream::connect(&addr)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            for _ in 0..reqs {
                // one final line per request: opt out explicitly in case
                // the server runs with --stream as its default
                let req = obj(vec![
                    ("prompt", prompt.as_str().into()),
                    ("max_tokens", max_tokens.into()),
                    ("stream", false.into()),
                ]);
                let t = Instant::now();
                writeln!(writer, "{req}")?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                lat += t.elapsed().as_secs_f64();
                let resp = Json::parse(&line)?;
                tokens += resp.get("n_tokens").and_then(|j| j.as_i64()).unwrap_or(0) as u64;
            }
            Ok((tokens, lat / reqs as f64))
        }));
    }
    let active = handles.len().max(1);
    let mut total_tokens = 0u64;
    let mut lat_sum = 0.0;
    for h in handles {
        let (t, l) = h.join().unwrap()?;
        total_tokens += t;
        lat_sum += l;
    }
    let mean_lat = lat_sum / active as f64;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} requests over {} workers, {} tokens in {:.2}s — {:.1} tok/s, \
         mean request latency {:.3}s",
        n,
        active,
        total_tokens,
        wall,
        total_tokens as f64 / wall,
        mean_lat
    );
    Ok(())
}

fn cmd_approx(args: &Args) -> Result<()> {
    let seed = args.get_usize("seed", 0)? as u64;
    let rows = if args.has("native") {
        experiments::approx_quality_native(seed, 256, 64)?
    } else {
        experiments::approx_quality(&runtime()?, seed)?
    };
    println!("E1 — approximation quality (rel L2 error vs its softmax target)");
    println!("{:>6} {:>6} {:>16} {:>16}", "alpha", "order", "err_vs_target", "err_vs_std");
    for r in &rows {
        println!(
            "{:>6} {:>6} {:>16.4} {:>16.4}",
            r.alpha, r.order, r.rel_err_vs_target, r.rel_err_vs_std
        );
    }
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let path =
        experiments::write_results(&out, "e1_approx.csv", &experiments::approx_rows_csv(&rows))?;
    println!("wrote {path:?}");
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    let points = args.get_usize("points", 121)?;
    let csv = experiments::fig1_taylor_csv(points);
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let path = experiments::write_results(&out, "fig1_taylor.csv", &csv)?;
    println!("wrote {path:?} ({points} points on [-3, 3])");
    Ok(())
}

fn cmd_crosscheck(args: &Args) -> Result<()> {
    if args.has("native") {
        for kind in ["ho", "linear"] {
            let err = experiments::crosscheck_native(kind, 7, 1e-4)?;
            let scope = if kind == "ho" { "orders 0-3, " } else { "" };
            println!(
                "native {kind:<10} ({scope}streaming + chunked, causal + non-causal) \
                 max|diff| vs O(n^2) oracle = {err:.2e}  OK"
            );
        }
        return Ok(());
    }
    let rt = runtime()?;
    let names: Vec<String> = match args.get("artifact") {
        Some(a) => vec![a.to_string()],
        None => vec![
            "attn_softmax_n256".into(),
            "attn_linear_n256".into(),
            "attn_ho2_n256".into(),
            "attn_softmax_n256_pallas".into(),
            "attn_linear_n256_pallas".into(),
            "attn_ho2_n256_pallas".into(),
        ],
    };
    for name in names {
        let err = experiments::crosscheck_attention(&rt, &name, 7, 5e-4)?;
        println!("{name:<32} max|diff| vs rust reference = {err:.2e}  OK");
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let steps = args.get_usize("steps", 120)?;
    let lr = args.get_f64("lr", 2e-3)?;
    let task = args.get("task").unwrap_or("copy").to_string();
    let backend = backend_of(args)?;
    // the artifact runtime is shared across the grid (executable cache);
    // the native path needs nothing
    let rt = if backend == "native" { None } else { Some(runtime()?) };
    // the ho2 (alpha, order) grid, plus both baselines — the E6
    // experiment: does order 2 close the gap to softmax that order 1
    // leaves open (Mercat 2020)?
    let models = [
        "ho2_tiny",        // alpha=3, order=2 (the paper's setting)
        "ho2_tiny_a1_o2",
        "ho2_tiny_a6_o2",
        "ho2_tiny_a3_o1",
        "ho2_tiny_a1_o1",
        "ho2_tiny_a3_o0",
        "linear_tiny",
        "softmax_tiny",
    ];
    println!("E6 — alpha/order ablation [{backend}]: task '{task}', {steps} steps each\n");
    println!(
        "{:<16} {:>6} {:>6} {:>12} {:>12} {:>10}",
        "model", "alpha", "order", "final loss", "eval acc", "wall (s)"
    );
    let mut csv = String::from("model,alpha,order,final_loss,eval_acc,wall_s\n");
    for model in models {
        let mut trainer: Box<dyn TrainBackend> = match &rt {
            None => Box::new(NativeTrainer::new(model, 42)?),
            Some(rt) => Box::new(ArtifactTrainer::new(rt, model, 42)?),
        };
        let (b, t) = trainer.train_shape();
        let mut gen = holt::data::make(&task, 42)?;
        let mut eval_gen = holt::data::make(&task, 77)?;
        let t0 = Instant::now();
        let mut last = f32::NAN;
        for i in 0..steps {
            let lr_i = if i < 20 { lr * (i + 1) as f64 / 20.0 } else { lr };
            last = trainer.train_step(&gen.batch(b, t), lr_i as f32)?.loss;
        }
        let acc = if trainer.supports_eval() {
            trainer.eval_accuracy(&eval_gen.batch(b, t))?
        } else {
            f64::NAN
        };
        let wall = t0.elapsed().as_secs_f64();
        let cfg = &trainer.model().config;
        let (alpha, order) = (cfg.alpha, cfg.order);
        println!(
            "{model:<16} {alpha:>6} {order:>6} {last:>12.4} {acc:>12.3} {wall:>10.1}"
        );
        csv.push_str(&format!("{model},{alpha},{order},{last},{acc},{wall}\n"));
    }
    let path = experiments::write_results(
        std::path::Path::new(args.get("out").unwrap_or("results")),
        "e6_ablation.csv",
        &csv,
    )?;
    println!("\nwrote {path:?}");
    Ok(())
}

fn run_eval(exec: &dyn Executor, task: &str, batches: usize, seed: u64) -> Result<()> {
    let cfg = &exec.model().config;
    let (b, t) = (cfg.train_batch, cfg.train_len);
    let mut gen = holt::data::make(task, seed)?;
    let mut loss_sum = 0.0;
    let mut acc_sum = 0.0;
    for _ in 0..batches {
        let batch = gen.batch(b, t);
        let logits = exec.forward_logits(&batch.tokens)?;
        loss_sum += batch.cross_entropy(&logits)?;
        acc_sum += batch.accuracy(&logits)?;
    }
    let loss = loss_sum / batches as f64;
    let acc = acc_sum / batches as f64;
    println!(
        "{} [{}] on {task}: loss {loss:.4}  ppl {:.2}  accuracy {acc:.3}  \
         ({batches} batches of {b}x{t})",
        exec.model().name,
        exec.backend_name(),
        loss.exp()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("ho2_small").to_string();
    let task = args.get("task").unwrap_or("charlm").to_string();
    let batches = args.get_usize("batches", 8)?.max(1);
    let seed = args.get_usize("seed", 1234)? as u64;
    let exec = build_executor(backend_of(args)?, &model, args.get("ckpt"), seed)?;
    run_eval(&*exec, &task, batches, seed)
}

fn cmd_plot(args: &Args) -> Result<()> {
    let files = args
        .get("files")
        .ok_or_else(|| anyhow::anyhow!("--files a.jsonl,b.jsonl required"))?;
    let event = args.get("event").unwrap_or("step");
    let x = args.get("x").unwrap_or("step");
    let y = args.get("y").unwrap_or("loss");
    let series: Result<Vec<_>> = files
        .split(',')
        .map(|f| holt::plot::Series::from_jsonl(std::path::Path::new(f), event, x, y))
        .collect();
    let chart = holt::plot::render(&series?, 72, 18)?;
    println!("{y} vs {x} ({event} events)\n{chart}");
    Ok(())
}

fn cmd_ckpt_info(args: &Args) -> Result<()> {
    let path = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("--ckpt FILE required"))?;
    let version = holt::checkpoint::container_version(std::path::Path::new(path))?;
    let ck = Checkpoint::load(std::path::Path::new(path))?;
    println!(
        "{path}: step {} (container v{version}{})",
        ck.step,
        if version >= 2 { ", mmap-indexable" } else { "" }
    );
    for (name, store) in &ck.sections {
        println!(
            "  section '{}': {} leaves, {} elements ({:.1} MiB)",
            name,
            store.len(),
            store.total_elements(),
            store.total_elements() as f64 * 4.0 / (1024.0 * 1024.0)
        );
    }
    let params = ck.section("params")?;
    for (n, t) in params.names.iter().zip(&params.leaves).take(6) {
        let d = t.as_f32()?;
        let rms = (d.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
            / d.len().max(1) as f64)
            .sqrt();
        println!("    {n:<24} {:?} rms {rms:.4}", t.shape);
    }
    if params.len() > 6 {
        println!("    ... {} more leaves", params.len() - 6);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn flags_with_values() {
        let a = parse(&["--model", "ho2_small", "--steps", "300"]);
        assert_eq!(a.get("model"), Some("ho2_small"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 300);
        assert_eq!(a.get_usize("absent", 7).unwrap(), 7);
    }

    #[test]
    fn bare_boolean_flags() {
        let a = parse(&["--synthetic", "--requests", "8"]);
        assert!(a.has("synthetic"));
        assert_eq!(a.get_usize("requests", 0).unwrap(), 8);
        let b = parse(&["--requests", "8", "--synthetic"]);
        assert!(b.has("synthetic"));
    }

    #[test]
    fn rejects_positional_and_bad_numbers() {
        assert!(Args::parse(&["oops".to_string()]).is_err());
        let a = parse(&["--steps", "abc"]);
        assert!(a.get_usize("steps", 0).is_err());
        assert!(a.get_f64("steps", 0.0).is_err());
    }

    #[test]
    fn serve_state_flags_resolve() {
        use holt::state::StateDtype;
        // flag wins over the model's preset-suffix default
        let a = parse(&["--state-dtype", "f16"]);
        assert_eq!(super::serve_opts(&a, StateDtype::Int8).unwrap().state_dtype, StateDtype::F16);
        // no flag: the model default flows through
        let b = parse(&[]);
        let o = super::serve_opts(&b, StateDtype::Int8).unwrap();
        assert_eq!(o.state_dtype, StateDtype::Int8);
        assert_eq!(o.session_cache_bytes, holt::serve::ServeOpts::default().session_cache_bytes);
        // --session-cache-mb is MiB on the wire, bytes in ServeOpts
        let c = parse(&["--session-cache-mb", "4"]);
        assert_eq!(super::serve_opts(&c, StateDtype::F64).unwrap().session_cache_bytes, 4 << 20);
        let z = parse(&["--session-cache-mb", "0"]);
        assert_eq!(super::serve_opts(&z, StateDtype::F64).unwrap().session_cache_bytes, 0);
        // unknown dtypes fail loudly at flag-parse time, not mid-serve
        let d = parse(&["--state-dtype", "q4"]);
        assert!(super::serve_opts(&d, StateDtype::F64).is_err());
    }

    #[test]
    fn backend_flag_is_validated() {
        let a = parse(&["--backend", "native"]);
        assert_eq!(super::backend_of(&a).unwrap(), "native");
        let b = parse(&["--backend", "artifact"]);
        assert_eq!(super::backend_of(&b).unwrap(), "artifact");
        let c = parse(&["--backend", "tpu"]);
        assert!(super::backend_of(&c).is_err());
        let d = parse(&[]);
        assert_eq!(super::backend_of(&d).unwrap(), "native");
    }
}
