//! Compact state: the snapshot codec behind parked / cached / migrated
//! session state.
//!
//! The paper's serving story is that attention collapses to an O(1)
//! recurrent state per sequence, which makes *resident* sessions cheap —
//! until the resident form itself is wasteful.  The live kernel state
//! ([`PhiState`](crate::kernels::PhiState)) accumulates in f64 (the Z/M
//! sums genuinely need the headroom while they are being *updated*), but
//! a parked snapshot is read-only: nothing accumulates into it again
//! until it is rehydrated.  A read-only copy can afford a narrower
//! dtype, so the session cache's binding constraint — bytes per resident
//! session — drops by 2–8× depending on how much drift the deployment
//! tolerates.
//!
//! [`SnapshotCodec`] encodes a `&[f64]` state vector into one of:
//!
//! * **f64** — bit-lossless passthrough: today's park format, byte for
//!   byte.  The default, so every bit-exactness pin (preempt/resume,
//!   cache hit, migration) holds with certainty.
//! * **f32** — the canonical *compact* baseline: 2× smaller, round-trip
//!   error below the oracle tolerance the kernels are pinned to, and
//!   idempotent (re-encoding a decoded snapshot is bit-identical).
//! * **f16 / bf16** — 4× smaller.  Manual bit conversion (the vendor
//!   set has no `half` crate): round-to-nearest-even, subnormals and
//!   infinities handled.
//! * **int8** — ~7.5× smaller: per-block scales ([`INT8_BLOCK`] = 64
//!   elements share one f32 scale = max|x|/127), symmetric round-to-
//!   nearest quantization.
//!
//! Restore always rehydrates the full-precision f64 live state; lossy
//! dtypes trade bounded logit drift (measured against the `mathref`
//! crosscheck oracle in `rust/tests/proptests.rs`) for density.  The
//! drift shows up once per park/restore, not per token — the rehydrated
//! state then evolves in f64 again.
//!
//! Every codec is *idempotent*: `encode(decode(encode(x))) ==
//! encode(x)`, so a snapshot that shuttles between shards any number of
//! times degrades exactly once, at first encode.

use anyhow::{bail, ensure, Result};

/// Elements per int8 quantization block (one shared f32 scale each).
pub const INT8_BLOCK: usize = 64;

/// Wire dtype for encoded state snapshots.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StateDtype {
    /// Bit-lossless passthrough — today's park format (the default).
    F64,
    /// Canonical compact baseline: 2× smaller, sub-oracle-tolerance drift.
    F32,
    /// IEEE 754 binary16: 4× smaller.
    F16,
    /// bfloat16 (f32 with the bottom 16 mantissa bits dropped): 4× smaller.
    Bf16,
    /// Symmetric int8 with one f32 scale per [`INT8_BLOCK`] elements.
    Int8,
}

impl StateDtype {
    /// All dtypes, widest first — the order bench reports sweep.
    pub const ALL: [StateDtype; 5] = [
        StateDtype::F64,
        StateDtype::F32,
        StateDtype::F16,
        StateDtype::Bf16,
        StateDtype::Int8,
    ];

    /// Parse a CLI / preset-suffix spelling.
    pub fn parse(s: &str) -> Result<StateDtype> {
        Ok(match s {
            "f64" => StateDtype::F64,
            "f32" => StateDtype::F32,
            "f16" => StateDtype::F16,
            "bf16" => StateDtype::Bf16,
            "int8" => StateDtype::Int8,
            _ => bail!(
                "unknown state dtype '{s}' (expected f64, f32, f16, bf16 or int8)"
            ),
        })
    }

    /// The canonical spelling ([`StateDtype::parse`] inverse).
    pub fn name(&self) -> &'static str {
        match self {
            StateDtype::F64 => "f64",
            StateDtype::F32 => "f32",
            StateDtype::F16 => "f16",
            StateDtype::Bf16 => "bf16",
            StateDtype::Int8 => "int8",
        }
    }

    /// Encoded payload size for `n` state elements — analytic, so byte
    /// budgets and sessions-per-GiB projections need no trial encode.
    pub fn encoded_len(&self, n: usize) -> usize {
        match self {
            StateDtype::F64 => n * 8,
            StateDtype::F32 => n * 4,
            StateDtype::F16 | StateDtype::Bf16 => n * 2,
            StateDtype::Int8 => n + 4 * n.div_ceil(INT8_BLOCK),
        }
    }
}

impl Default for StateDtype {
    fn default() -> Self {
        StateDtype::F64
    }
}

impl std::fmt::Display for StateDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Encoder/decoder for one [`StateDtype`].  Stateless — the struct
/// exists so call sites read `codec.encode(..)` against a fixed dtype
/// instead of threading the enum through every helper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnapshotCodec {
    dtype: StateDtype,
}

impl SnapshotCodec {
    pub fn new(dtype: StateDtype) -> SnapshotCodec {
        SnapshotCodec { dtype }
    }

    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Payload bytes for `n` elements (see [`StateDtype::encoded_len`]).
    pub fn encoded_len(&self, n: usize) -> usize {
        self.dtype.encoded_len(n)
    }

    /// Encode a full-precision state vector into the wire payload.
    pub fn encode(&self, state: &[f64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len(state.len()));
        match self.dtype {
            StateDtype::F64 => {
                for &x in state {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            StateDtype::F32 => {
                for &x in state {
                    out.extend_from_slice(&(x as f32).to_le_bytes());
                }
            }
            StateDtype::F16 => {
                for &x in state {
                    out.extend_from_slice(&f32_to_f16_bits(x as f32).to_le_bytes());
                }
            }
            StateDtype::Bf16 => {
                for &x in state {
                    out.extend_from_slice(&f32_to_bf16_bits(x as f32).to_le_bytes());
                }
            }
            StateDtype::Int8 => {
                for block in state.chunks(INT8_BLOCK) {
                    let max_abs = block.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
                    // the scale ships as f32; quantize against the value
                    // the decoder will actually multiply by, so the
                    // codec is idempotent
                    let scale = (max_abs / 127.0) as f32;
                    if scale == 0.0 || !scale.is_finite() {
                        // all-zero block, or a state with inf/NaN (the
                        // kernels never produce one; quantizing it is
                        // meaningless) — ship scale 0 + zero bytes so the
                        // block decodes to exact zeros (a non-finite
                        // scale would decode 0·inf = NaN)
                        out.extend_from_slice(&0.0f32.to_le_bytes());
                        out.resize(out.len() + block.len(), 0u8);
                    } else {
                        out.extend_from_slice(&scale.to_le_bytes());
                        let s = scale as f64;
                        for &x in block {
                            let q = (x / s).round().clamp(-127.0, 127.0) as i8;
                            out.push(q as u8);
                        }
                    }
                }
            }
        }
        out
    }

    /// Decode a payload produced by [`SnapshotCodec::encode`] back into
    /// `n_elems` f64 values (the live-state rehydration).
    pub fn decode(&self, bytes: &[u8], n_elems: usize) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(n_elems);
        self.decode_into(bytes, n_elems, &mut out)?;
        Ok(out)
    }

    /// [`SnapshotCodec::decode`] into a caller-owned buffer (cleared
    /// first) — the restore hot path reuses one buffer per engine.
    pub fn decode_into(&self, bytes: &[u8], n_elems: usize, out: &mut Vec<f64>) -> Result<()> {
        ensure!(
            bytes.len() == self.encoded_len(n_elems),
            "encoded {} snapshot has {} bytes, expected {} for {} elements",
            self.dtype,
            bytes.len(),
            self.encoded_len(n_elems),
            n_elems
        );
        out.clear();
        out.reserve(n_elems);
        match self.dtype {
            StateDtype::F64 => {
                for b in bytes.chunks_exact(8) {
                    out.push(f64::from_le_bytes(b.try_into().unwrap()));
                }
            }
            StateDtype::F32 => {
                for b in bytes.chunks_exact(4) {
                    out.push(f32::from_le_bytes(b.try_into().unwrap()) as f64);
                }
            }
            StateDtype::F16 => {
                for b in bytes.chunks_exact(2) {
                    out.push(f16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap())) as f64);
                }
            }
            StateDtype::Bf16 => {
                for b in bytes.chunks_exact(2) {
                    out.push(bf16_bits_to_f32(u16::from_le_bytes(b.try_into().unwrap())) as f64);
                }
            }
            StateDtype::Int8 => {
                let mut remaining = n_elems;
                let mut off = 0;
                while remaining > 0 {
                    let blk = remaining.min(INT8_BLOCK);
                    let scale =
                        f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as f64;
                    off += 4;
                    for &b in &bytes[off..off + blk] {
                        out.push((b as i8) as f64 * scale);
                    }
                    off += blk;
                    remaining -= blk;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// half-precision bit conversion (no `half` crate in the vendor set)
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even.  Overflow saturates
/// to ±inf, underflow denormalizes then flushes to ±0, NaN stays NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN — keep the top mantissa bits, but never collapse a
        // NaN to inf
        let m = (man >> 13) as u16;
        return sign | 0x7c00 | if man != 0 && m == 0 { 1 } else { m };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below the smallest subnormal → ±0
        }
        // subnormal: add the implicit bit, then shift out 14 - e bits
        // (13 mantissa-width difference + 1 - e for the lost exponent
        // range) with round-to-nearest-even
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = (man >> shift) as u16;
        let round = 1u32 << (shift - 1);
        let rem = man & ((1 << shift) - 1);
        if rem > round || (rem == round && (half & 1) == 1) {
            // a carry out of the subnormal range lands on the smallest
            // normal (0x0400) — exactly right
            return sign | (half + 1);
        }
        return sign | half;
    }
    // normal: drop 13 mantissa bits with round-to-nearest-even; a
    // mantissa carry correctly overflows into the exponent (and a carry
    // out of e = 30 correctly produces inf)
    let half = sign | ((e as u16) << 10) | ((man >> 13) as u16);
    let rem = man & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        half + 1
    } else {
        half
    }
}

/// IEEE binary16 bits → f32 (exact — every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // subnormal: normalize into f32's larger exponent range
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits, round-to-nearest-even.  NaN is forced quiet so
/// rounding can never collapse it to inf.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7fff;
    ((bits + round) >> 16) as u16
}

/// bfloat16 bits → f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_state(rng: &mut Rng, n: usize, scale: f64) -> Vec<f64> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for d in StateDtype::ALL {
            assert_eq!(StateDtype::parse(d.name()).unwrap(), d);
        }
        assert!(StateDtype::parse("f8").is_err());
        assert!(StateDtype::parse("").is_err());
        assert_eq!(StateDtype::default(), StateDtype::F64);
    }

    #[test]
    fn encoded_len_matches_actual_encode() {
        let mut rng = Rng::new(0x57a7e);
        for n in [0usize, 1, 7, 63, 64, 65, 128, 1000] {
            let state = random_state(&mut rng, n, 3.0);
            for d in StateDtype::ALL {
                let codec = SnapshotCodec::new(d);
                assert_eq!(
                    codec.encode(&state).len(),
                    codec.encoded_len(n),
                    "{d} n={n}"
                );
            }
        }
    }

    #[test]
    fn f64_roundtrip_is_bit_lossless() {
        let mut rng = Rng::new(0x57a7e + 1);
        let codec = SnapshotCodec::new(StateDtype::F64);
        for case in 0..20 {
            let mut state = random_state(&mut rng, 97, 1e3);
            // adversarial values a float codec could plausibly mangle
            state.extend([0.0, -0.0, f64::MIN_POSITIVE, 1e-300, -1e300, f64::NAN]);
            let back = codec.decode(&codec.encode(&state), state.len()).unwrap();
            for (i, (&a, &b)) in state.iter().zip(&back).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "case {case} elem {i}");
            }
        }
    }

    #[test]
    fn f32_roundtrip_is_exactly_the_f32_cast() {
        let mut rng = Rng::new(0x57a7e + 2);
        let codec = SnapshotCodec::new(StateDtype::F32);
        let state = random_state(&mut rng, 300, 50.0);
        let back = codec.decode(&codec.encode(&state), state.len()).unwrap();
        for (&a, &b) in state.iter().zip(&back) {
            assert_eq!((a as f32).to_bits(), (b as f32).to_bits());
            assert_eq!(b, (a as f32) as f64, "decode must rehydrate the exact cast");
        }
    }

    #[test]
    fn every_codec_is_idempotent() {
        // one lossy step at first encode, then a fixed point: shuttling a
        // snapshot between shards any number of times loses nothing more
        let mut rng = Rng::new(0x57a7e + 3);
        for case in 0..10 {
            let state = random_state(&mut rng, 130, [1e-3, 1.0, 1e4][case % 3]);
            for d in StateDtype::ALL {
                let codec = SnapshotCodec::new(d);
                let once = codec.encode(&state);
                let back = codec.decode(&once, state.len()).unwrap();
                let twice = codec.encode(&back);
                assert_eq!(once, twice, "case {case} {d} not idempotent");
            }
        }
    }

    #[test]
    fn decode_rejects_wrong_length() {
        let codec = SnapshotCodec::new(StateDtype::F16);
        let bytes = codec.encode(&[1.0, 2.0, 3.0]);
        assert!(codec.decode(&bytes, 4).is_err());
        assert!(codec.decode(&bytes[..4], 3).is_err());
    }

    #[test]
    fn f16_known_values() {
        for (x, want) in [
            (0.0f32, 0x0000u16),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (65504.0, 0x7bff),  // largest finite f16
            (65536.0, 0x7c00),  // overflow → inf
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
            (6.103_515_6e-5, 0x0400),  // smallest normal 2^-14
            (5.960_464_5e-8, 0x0001),  // smallest subnormal 2^-24
            (2.980_232_2e-8, 0x0000),  // 2^-25: tie, rounds to even (0)
        ] {
            assert_eq!(f32_to_f16_bits(x), want, "encode {x}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // decode side: every encoded value above rehydrates exactly
        for (x, bits) in [(1.0f32, 0x3c00u16), (65504.0, 0x7bff), (5.960_464_5e-8, 0x0001)] {
            assert_eq!(f16_bits_to_f32(bits), x, "decode {bits:#06x}");
        }
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next f16 (1 + 2^-10):
        // ties go to the even mantissa, i.e. 1.0
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3c00);
        // 1 + 3·2^-11 ties between 1+2^-10 and 1+2^-9 → even → 1+2^-9
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 0.000_488_281_25), 0x3c02);
        // just above a tie rounds up
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_489), 0x3c01);
    }

    #[test]
    fn bf16_known_values_and_rne() {
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        assert_eq!(f32_to_bf16_bits(-1.0), 0xbf80);
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        assert_eq!(bf16_bits_to_f32(0x3f80), 1.0);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        // tie at the dropped half-bit: 0x3f80_8000 → even (0x3f80),
        // 0x3f81_8000 → even is up (0x3f82)
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f80_8000)), 0x3f80);
        assert_eq!(f32_to_bf16_bits(f32::from_bits(0x3f81_8000)), 0x3f82);
    }

    #[test]
    fn half_roundtrips_are_exact_for_representable_values() {
        // decode(bits) then encode must give the bits back for every
        // finite f16 / bf16 value — the codec idempotence base case
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp != 0x1f {
                assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "f16 {h:#06x}");
            }
            let x = bf16_bits_to_f32(h);
            if x.is_finite() {
                assert_eq!(f32_to_bf16_bits(x), h, "bf16 {h:#06x}");
            }
        }
    }

    #[test]
    fn quantization_error_is_bounded() {
        // float dtypes: relative error ≤ half an ulp at that precision;
        // int8: absolute error ≤ half a quantization step per block
        let mut rng = Rng::new(0x57a7e + 4);
        for case in 0..10 {
            let state = random_state(&mut rng, 256, [1e-2, 1.0, 1e3][case % 3]);
            for (d, rel) in [
                (StateDtype::F32, 2f64.powi(-24)),
                (StateDtype::F16, 2f64.powi(-11)),
                (StateDtype::Bf16, 2f64.powi(-8)),
            ] {
                let codec = SnapshotCodec::new(d);
                let back = codec.decode(&codec.encode(&state), state.len()).unwrap();
                for (&a, &b) in state.iter().zip(&back) {
                    assert!(
                        (a - b).abs() <= rel * a.abs() + 1e-300,
                        "case {case} {d}: {a} -> {b}"
                    );
                }
            }
            let codec = SnapshotCodec::new(StateDtype::Int8);
            let back = codec.decode(&codec.encode(&state), state.len()).unwrap();
            for (blk, (orig, dec)) in state
                .chunks(INT8_BLOCK)
                .zip(back.chunks(INT8_BLOCK))
                .enumerate()
            {
                let max_abs = orig.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
                let step = (max_abs / 127.0) as f32 as f64;
                for (&a, &b) in orig.iter().zip(dec) {
                    assert!(
                        (a - b).abs() <= 0.5 * step + 1e-12 * max_abs,
                        "case {case} int8 block {blk}: {a} -> {b} (step {step})"
                    );
                }
            }
        }
    }

    #[test]
    fn int8_zero_block_and_tail() {
        // an all-zero block ships scale 0 and decodes to exact zeros; a
        // ragged tail block (n % 64 != 0) round-trips
        let mut state = vec![0.0f64; INT8_BLOCK];
        state.extend([1.0, -2.0, 3.0]);
        let codec = SnapshotCodec::new(StateDtype::Int8);
        let bytes = codec.encode(&state);
        assert_eq!(bytes.len(), codec.encoded_len(state.len()));
        let back = codec.decode(&bytes, state.len()).unwrap();
        assert!(back[..INT8_BLOCK].iter().all(|&x| x == 0.0));
        for (&a, &b) in state[INT8_BLOCK..].iter().zip(&back[INT8_BLOCK..]) {
            assert!((a - b).abs() <= 3.0 / 127.0 * 0.5 + 1e-9);
        }
    }

    #[test]
    fn int8_nonfinite_block_ships_zeros_and_stays_idempotent() {
        // the kernels never emit inf/NaN state, but if one arrives the
        // block must decode to exact zeros (never 0·inf = NaN) and the
        // codec must stay a fixed point after one encode
        let mut state = vec![f64::INFINITY; 3];
        state.extend([f64::NAN, -1.0, 2.0]);
        state.resize(INT8_BLOCK, 0.5); // still one block: scale is non-finite
        state.extend([4.0, -8.0]); // finite tail block round-trips normally
        let codec = SnapshotCodec::new(StateDtype::Int8);
        let once = codec.encode(&state);
        let back = codec.decode(&once, state.len()).unwrap();
        assert!(back[..INT8_BLOCK].iter().all(|&x| x == 0.0), "{:?}", &back[..4]);
        assert!((back[INT8_BLOCK] - 4.0).abs() <= 8.0 / 127.0 * 0.5 + 1e-9);
        // fixed point: re-encoding the decoded snapshot is bit-identical
        assert_eq!(codec.encode(&back), once);
    }

    #[test]
    fn compression_ratios_hold() {
        // the acceptance numbers: f16 is 4× denser than the f64 baseline
        // (≥ 3× required), int8 ≥ 7×
        let n = 4096;
        let f64_len = StateDtype::F64.encoded_len(n) as f64;
        assert!(f64_len / StateDtype::F16.encoded_len(n) as f64 >= 3.0);
        assert!(f64_len / StateDtype::Bf16.encoded_len(n) as f64 >= 3.0);
        assert!(f64_len / StateDtype::F32.encoded_len(n) as f64 >= 2.0);
        assert!(f64_len / StateDtype::Int8.encoded_len(n) as f64 >= 7.0);
    }
}
