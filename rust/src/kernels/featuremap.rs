//! The [`FeatureMap`] abstraction — *the* choice the paper's O(n) trick
//! parameterizes over.
//!
//! Kernelized attention with weight `w(q, k) = φ_q(q)·φ_k(k)` admits an
//! O(1)-per-token recurrence over `Σφ_k(k)` and `Σφ_k(k)⊗v` regardless of
//! what φ is.  This module owns the φs; [`crate::kernels::PhiState`] owns
//! the (single) recurrence.  Two maps ship:
//!
//! * [`TaylorMap`] — the paper's kernel at **any** Taylor order r:
//!   `w = Σ_{j≤r} (u·k)ʲ/j!` with `u = q/(α√d)` after optional q/k
//!   LayerNorm.  Degree-j monomials are symmetric in their j indices, so
//!   the features are packed multisets `a₁ ≤ … ≤ aⱼ`: `C(d+j−1, j)`
//!   entries per degree instead of dʲ, with the multinomial weight
//!   `1/Πₐ(αₐ!)` folded into the *query-side* feature only — the key-side
//!   feature stays the plain monomial `Πₐ kₐ^{αₐ}`, so the state remains
//!   an exact plain sum of per-key products and absorb stays cheap.
//!   Total feature dim `Σ_{j≤r} C(d+j−1, j)` — the reason order 3 is
//!   affordable (e.g. d = 32: 6 545 features, not 32³ = 32 768 for the
//!   cubic moment alone).
//! * [`EluMap`] — Katharopoulos et al. 2020's elu(x)+1 baseline: φ is
//!   applied in the per-row prep stage, the map itself is the identity
//!   and the pair weight is a plain dot product.
//!
//! The q/k asymmetry (scale and multinomial coefficients on the query
//! side) is why the trait exposes `map_q`/`map_k` rather than the single
//! `map` a symmetric kernel would need.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::mathref::{elu1, layernorm_noaffine, layernorm_noaffine_vjp, taylor_exp};

/// LayerNorm epsilon — must match `mathref::ho_attention` exactly for the
/// oracle cross-checks to be meaningful.
pub(crate) const LN_EPS: f32 = 1e-5;

/// Guard on [`taylor_feature_dim`]: beyond this the per-head state
/// (`feature_dim · (1 + dv)` f64s) stops being "a few MiB per slot" and
/// the O(1)-state serving story no longer holds in practice.
pub const MAX_TAYLOR_FEATURES: usize = 1 << 21;

/// Packed feature count of [`TaylorMap`]: `Σ_{j=0..=order} C(d+j−1, j)`.
/// `None` when the intermediate binomials overflow `usize` — callers
/// treat that the same as exceeding [`MAX_TAYLOR_FEATURES`].
pub fn taylor_feature_dim(d: usize, order: usize) -> Option<usize> {
    let mut total = 0usize;
    let mut block = 1usize; // C(d−1, 0) = 1, the degree-0 block
    for j in 0..=order {
        if j > 0 {
            // C(d+j−1, j) = C(d+j−2, j−1) · (d+j−1) / j  (exact division)
            block = block.checked_mul(d.checked_add(j - 1)?)? / j;
        }
        total = total.checked_add(block)?;
    }
    Some(total)
}

/// A feature map φ with everything the generic recurrence
/// ([`crate::kernels::PhiState`]) needs to run forward *and* backward:
///
/// * `prep_rows` — per-row preprocessing shared by q and k (LayerNorm for
///   Taylor, elu+1 for the linear baseline), paid once per row by blocked
///   paths instead of once per pair;
/// * `map_q` / `map_k` — the features of a *prepped* row, query and key
///   side (asymmetric: scale and symmetry coefficients live on the query
///   side so the key-side state stays a plain sum);
/// * the matching VJPs for training;
/// * `pair_weight_from_dot` — `w(q, k)` as a function of the prepped-row
///   dot product, the direct form blocked paths use inside a chunk (for
///   every map here `φ_q(q)·φ_k(k)` collapses to such a function; the
///   identity is pinned by tests in this module).
///
/// Implementing these ~9 methods (most of them one-liners for a pointwise
/// φ — see [`EluMap`]) is all a new kernel needs: state, decode, chunked
/// training forward, the hand-derived backward, snapshotting and the
/// serve scheduler all come from `PhiState` unchanged.
pub trait FeatureMap: Send {
    /// Input (head) dimension d.
    fn d(&self) -> usize;

    /// Number of features per row — the recurrent state is
    /// `feature_dim · (1 + dv)` f64s.
    fn feature_dim(&self) -> usize;

    /// Per-row preprocessing of `n` raw q/k rows (LayerNorm / pointwise
    /// φ).  Blocked paths call this once per row and feed the result to
    /// `map_*` / `pair_weight_from_dot`.
    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32>;

    /// [`FeatureMap::prep_rows`] into a caller-owned buffer, reusing its
    /// capacity — what the zero-alloc hot paths call.  Default delegates
    /// to `prep_rows` (correct but allocating; the shipped maps
    /// override it).
    fn prep_rows_into(&self, rows: &[f32], n: usize, out: &mut Vec<f32>) {
        *out = self.prep_rows(rows, n);
    }

    /// VJP of [`FeatureMap::prep_rows`]: `rows` are the raw rows, `g` the
    /// gradient w.r.t. the prepped rows; returns the gradient w.r.t.
    /// `rows`.
    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64>;

    /// Query-side features of one prepped row into `out`
    /// (length [`FeatureMap::feature_dim`]).
    fn map_q(&self, xp: &[f32], out: &mut [f64]);

    /// Key-side features of one prepped row into `out`.
    fn map_k(&self, xp: &[f32], out: &mut [f64]);

    /// VJP of [`FeatureMap::map_q`]: accumulate `(∂φ_q/∂xp)ᵀ · dphi`
    /// into `dxp` (length d).
    fn map_q_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]);

    /// VJP of [`FeatureMap::map_k`].
    fn map_k_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]);

    /// `w(q, k) = f(qp·kp)` evaluated from the prepped-row dot product —
    /// must equal `φ_q(qp)·φ_k(kp)` up to float reassociation.
    fn pair_weight_from_dot(&self, dot: f64) -> f64;

    /// `df/d(dot)` at the given dot product.
    fn pair_weight_dot_grad(&self, dot: f64) -> f64;
}

/// One packed monomial of degree ≥ 2, defined recursively: feature
/// `base + i` extends feature `parent` (one degree lower) by index
/// `last`, where `last` now appears `mult` times in the multiset.
struct Ext {
    parent: u32,
    last: u32,
    mult: u32,
}

/// The extension table depends only on `(d, order)` but a `TaylorMap` is
/// constructed per (layer, head) kernel state, per decode slot, per
/// request — so the table is built once per configuration and shared.
/// The cache is unbounded but keyed by the handful of `(d, order)` pairs
/// a process actually serves.
fn ext_table(d: usize, order: usize) -> Arc<[Ext]> {
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize), Arc<[Ext]>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(t) = cache.lock().unwrap().get(&(d, order)) {
        return Arc::clone(t);
    }
    // degree-(j−1) block as (global feature index, last index, count of
    // last in the multiset), extended index-nondecreasingly
    let mut ext = Vec::new();
    let mut prev: Vec<(u32, u32, u32)> = (0..d).map(|a| ((1 + a) as u32, a as u32, 1)).collect();
    for _ in 2..=order {
        let mut next = Vec::new();
        for &(pidx, last, cnt) in &prev {
            for b in last as usize..d {
                let mult = if b as u32 == last { cnt + 1 } else { 1 };
                let idx = (1 + d + ext.len()) as u32;
                ext.push(Ext { parent: pidx, last: b as u32, mult });
                next.push((idx, b as u32, mult));
            }
        }
        prev = next;
    }
    let table: Arc<[Ext]> = ext.into();
    Arc::clone(
        cache
            .lock()
            .unwrap()
            .entry((d, order))
            .or_insert(table),
    )
}

/// The paper's Taylor feature map at arbitrary order (see module docs).
///
/// Feature layout (the packed degree-≤2 prefix is exactly the historic
/// `s0/s1/s2` layout, which keeps order ≤ 2 results bit-identical to the
/// pre-`FeatureMap` kernels — pinned in `rust/tests/golden_order2.rs`):
///
/// ```text
/// [ 1 | x₀ … x_{d−1} | deg-2 multisets lex | deg-3 multisets lex | … ]
/// ```
pub struct TaylorMap {
    d: usize,
    order: usize,
    /// 1 / (α √d): folded into the query features, never into the state.
    scale: f64,
    normalize_qk: bool,
    /// recursive construction of every feature of degree ≥ 2 — shared
    /// across all states of the same (d, order), see [`ext_table`]
    ext: Arc<[Ext]>,
    feature_dim: usize,
    /// Reverse-mode transient buffers: the map vjps run once per token
    /// per train step and must not allocate (the same zero-heap-traffic
    /// contract as [`crate::kernels::Scratch`]; pinned by
    /// `rust/tests/alloc_decode.rs`).  `RefCell` because the vjps take
    /// `&self`; a map is owned by one kernel state and never shared
    /// across threads (`Send`, not `Sync`).
    vjp: RefCell<VjpScratch>,
}

/// See [`TaylorMap::vjp`].
struct VjpScratch {
    /// Forward features recomputed for the reverse sweep (len `feature_dim`).
    phi: Vec<f64>,
    /// Gradient being pushed down the recursive construction (len `feature_dim`).
    g: Vec<f64>,
    /// Accumulated gradient on the scaled input row (len `d`).
    du: Vec<f64>,
}

impl TaylorMap {
    /// `order` is unbounded in principle; in practice the packed feature
    /// dim `Σ_{j≤order} C(d+j−1, j)` must stay under
    /// [`MAX_TAYLOR_FEATURES`] (the panic reports the computed dim —
    /// config-level paths validate the same bound with a proper error
    /// via [`crate::model::native_model_entry`]).
    pub fn new(d: usize, order: usize, alpha: f64, normalize_qk: bool) -> TaylorMap {
        assert!(d > 0, "empty head dim");
        assert!(alpha > 0.0, "alpha must be positive");
        let feature_dim = match taylor_feature_dim(d, order) {
            Some(f) if f <= MAX_TAYLOR_FEATURES => f,
            computed => panic!(
                "TaylorMap order {order} at d = {d} needs {} packed features \
                 (Σ_j C(d+j−1, j)); the cap is {MAX_TAYLOR_FEATURES}",
                computed.map_or("> usize::MAX".to_string(), |f| f.to_string()),
            ),
        };
        let ext = ext_table(d, order);
        debug_assert_eq!(if order == 0 { 1 } else { 1 + d + ext.len() }, feature_dim);
        let vjp = RefCell::new(VjpScratch {
            phi: vec![0.0; feature_dim],
            g: vec![0.0; feature_dim],
            du: vec![0.0; d],
        });
        TaylorMap { d, order, scale: 1.0 / (alpha * (d as f64).sqrt()), normalize_qk, ext, feature_dim, vjp }
    }

    pub fn order(&self) -> usize {
        self.order
    }

    /// Features of degree 1..=order read the prepped row; shared by both
    /// map directions (query side additionally scales and weights).
    fn check(&self, xp: &[f32], out: &[f64]) {
        assert_eq!(xp.len(), self.d, "row length");
        assert_eq!(out.len(), self.feature_dim, "feature buffer length");
    }
}

impl FeatureMap for TaylorMap {
    fn d(&self) -> usize {
        self.d
    }

    fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        let mut out = rows.to_vec();
        if self.normalize_qk {
            layernorm_noaffine(&mut out, n, self.d, LN_EPS);
        }
        out
    }

    fn prep_rows_into(&self, rows: &[f32], n: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(rows);
        if self.normalize_qk {
            layernorm_noaffine(out, n, self.d, LN_EPS);
        }
    }

    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64> {
        if self.normalize_qk {
            layernorm_noaffine_vjp(rows, n, self.d, LN_EPS, g)
        } else {
            g.to_vec()
        }
    }

    fn map_q(&self, xp: &[f32], out: &mut [f64]) {
        self.check(xp, out);
        out[0] = 1.0;
        if self.order == 0 {
            return;
        }
        // u = scaled query; higher degrees multiply scaled factors, so
        // dot·scale-per-factor matches taylor_exp((qp·kp)·scale, order)
        for a in 0..self.d {
            out[1 + a] = self.scale * xp[a] as f64;
        }
        let base = 1 + self.d;
        for (i, e) in self.ext.iter().enumerate() {
            // multinomial weight 1/Πα! built incrementally: dividing by
            // the multiplicity of the appended index is exact for the
            // degree-2 (÷2 = ×0.5) case the goldens pin
            let f = out[e.parent as usize] * out[1 + e.last as usize];
            out[base + i] = if e.mult > 1 { f / e.mult as f64 } else { f };
        }
    }

    fn map_k(&self, xp: &[f32], out: &mut [f64]) {
        self.check(xp, out);
        out[0] = 1.0;
        if self.order == 0 {
            return;
        }
        for a in 0..self.d {
            out[1 + a] = xp[a] as f64;
        }
        let base = 1 + self.d;
        for (i, e) in self.ext.iter().enumerate() {
            out[base + i] = out[e.parent as usize] * out[1 + e.last as usize];
        }
    }

    fn map_q_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
        if self.order == 0 {
            return; // φ_q ≡ [1]: no input dependence
        }
        assert_eq!(dphi.len(), self.feature_dim, "dphi length");
        let mut sc = self.vjp.borrow_mut();
        let VjpScratch { phi, g, du } = &mut *sc;
        self.map_q(xp, phi);
        // reverse-mode through the recursive construction: every feature
        // feeds gradient to its parent and to its appended factor
        g.copy_from_slice(dphi);
        du.fill(0.0);
        let base = 1 + self.d;
        for i in (0..self.ext.len()).rev() {
            let e = &self.ext[i];
            let gf = if e.mult > 1 { g[base + i] / e.mult as f64 } else { g[base + i] };
            g[e.parent as usize] += gf * phi[1 + e.last as usize];
            du[e.last as usize] += gf * phi[e.parent as usize];
        }
        for a in 0..self.d {
            du[a] += g[1 + a];
        }
        for (o, &x) in dxp.iter_mut().zip(du.iter()) {
            *o += self.scale * x;
        }
    }

    fn map_k_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
        if self.order == 0 {
            return;
        }
        assert_eq!(dphi.len(), self.feature_dim, "dphi length");
        let mut sc = self.vjp.borrow_mut();
        let VjpScratch { phi, g, .. } = &mut *sc;
        self.map_k(xp, phi);
        g.copy_from_slice(dphi);
        let base = 1 + self.d;
        for i in (0..self.ext.len()).rev() {
            let e = &self.ext[i];
            let gf = g[base + i];
            g[e.parent as usize] += gf * phi[1 + e.last as usize];
            dxp[e.last as usize] += gf * phi[e.parent as usize];
        }
        for a in 0..self.d {
            dxp[a] += g[1 + a];
        }
    }

    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        taylor_exp(dot * self.scale, self.order)
    }

    fn pair_weight_dot_grad(&self, dot: f64) -> f64 {
        // d/ds Tᵣ(s·scale) = scale · Tᵣ₋₁(s·scale); order 0 is constant
        if self.order == 0 {
            0.0
        } else {
            self.scale * taylor_exp(dot * self.scale, self.order - 1)
        }
    }
}

/// elu(x)+1 linear attention (Katharopoulos et al. 2020): the pointwise φ
/// happens in `prep_rows`, so the map is the identity and the pair weight
/// is the plain dot product of prepped rows.
pub struct EluMap {
    d: usize,
}

impl EluMap {
    pub fn new(d: usize) -> EluMap {
        assert!(d > 0, "empty head dim");
        EluMap { d }
    }
}

impl FeatureMap for EluMap {
    fn d(&self) -> usize {
        self.d
    }

    fn feature_dim(&self) -> usize {
        self.d
    }

    fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
        rows.iter().map(|&x| elu1(x)).collect()
    }

    fn prep_rows_into(&self, rows: &[f32], _n: usize, out: &mut Vec<f32>) {
        out.clear();
        out.extend(rows.iter().map(|&x| elu1(x)));
    }

    fn prep_rows_vjp(&self, rows: &[f32], _n: usize, g: &[f64]) -> Vec<f64> {
        // φ = elu+1: φ'(x) = 1 for x > 0, eˣ otherwise
        rows.iter()
            .zip(g)
            .map(|(&x, &gp)| gp * if x > 0.0 { 1.0 } else { (x as f64).exp() })
            .collect()
    }

    fn map_q(&self, xp: &[f32], out: &mut [f64]) {
        assert_eq!(xp.len(), self.d, "row length");
        for (o, &x) in out.iter_mut().zip(xp) {
            *o = x as f64;
        }
    }

    fn map_k(&self, xp: &[f32], out: &mut [f64]) {
        self.map_q(xp, out);
    }

    fn map_q_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
        let _ = xp;
        for (o, &g) in dxp.iter_mut().zip(dphi) {
            *o += g;
        }
    }

    fn map_k_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
        self.map_q_vjp(xp, dphi, dxp);
    }

    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        dot
    }

    fn pair_weight_dot_grad(&self, _dot: f64) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[test]
    fn feature_dim_closed_form() {
        // Σ_{j≤r} C(d+j−1, j) against hand-expanded small cases
        assert_eq!(taylor_feature_dim(5, 0), Some(1));
        assert_eq!(taylor_feature_dim(5, 1), Some(6));
        assert_eq!(taylor_feature_dim(5, 2), Some(1 + 5 + 15));
        assert_eq!(taylor_feature_dim(5, 3), Some(1 + 5 + 15 + 35));
        assert_eq!(taylor_feature_dim(32, 3), Some(1 + 32 + 528 + 5984));
        // the packed degree-2 block is d(d+1)/2, the historic layout
        for d in 1..20 {
            assert_eq!(taylor_feature_dim(d, 2), Some(1 + d + d * (d + 1) / 2));
        }
        // absurd orders overflow into None instead of panicking
        assert_eq!(taylor_feature_dim(64, 200), None);
    }

    #[test]
    fn factorization_identity_every_order() {
        // THE identity the whole module rests on:
        // φ_q(q)·φ_k(k) == Σ_{j≤r} (u·k)ʲ/j! == pair_weight_from_dot(q·k)
        let mut rng = Rng::new(71);
        let d = 7;
        for order in 0..=4 {
            let map = TaylorMap::new(d, order, 3.0, false);
            for _ in 0..10 {
                let q = rng.normal_vec_f32(d, 1.0);
                let k = rng.normal_vec_f32(d, 1.0);
                let mut pq = vec![0.0f64; map.feature_dim()];
                let mut pk = vec![0.0f64; map.feature_dim()];
                map.map_q(&q, &mut pq);
                map.map_k(&k, &mut pk);
                let raw: f64 = q.iter().zip(&k).map(|(&a, &b)| a as f64 * b as f64).sum();
                let want = map.pair_weight_from_dot(raw);
                let got = dot(&pq, &pk);
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "order {order}: φq·φk {got} vs taylor {want}"
                );
            }
        }
    }

    #[test]
    fn map_vjps_match_finite_differences() {
        let mut rng = Rng::new(72);
        let d = 5;
        for order in 1..=3 {
            let map = TaylorMap::new(d, order, 2.0, false);
            let x = rng.normal_vec_f32(d, 1.0);
            let dphi = (0..map.feature_dim())
                .map(|_| rng.normal())
                .collect::<Vec<f64>>();
            for q_side in [true, false] {
                let f = |x_: &[f32]| -> f64 {
                    let mut phi = vec![0.0f64; map.feature_dim()];
                    if q_side {
                        map.map_q(x_, &mut phi);
                    } else {
                        map.map_k(x_, &mut phi);
                    }
                    dot(&phi, &dphi)
                };
                let mut g = vec![0.0f64; d];
                if q_side {
                    map.map_q_vjp(&x, &dphi, &mut g);
                } else {
                    map.map_k_vjp(&x, &dphi, &mut g);
                }
                let eps = 1e-4f32;
                for a in 0..d {
                    let mut xp = x.clone();
                    let mut xm = x.clone();
                    xp[a] += eps;
                    xm[a] -= eps;
                    // divide by the *realized* f32 step, not the nominal
                    // one — ±eps quantizes when added to an O(1) value
                    let fd = (f(&xp) - f(&xm)) / (xp[a] as f64 - xm[a] as f64);
                    assert!(
                        (g[a] - fd).abs() <= 1e-3 * fd.abs().max(1.0),
                        "order {order} q={q_side} coord {a}: vjp {} vs fd {fd}",
                        g[a]
                    );
                }
            }
        }
    }

    #[test]
    fn elu_map_is_identity_after_prep() {
        let map = EluMap::new(4);
        let raw = [1.5f32, -0.5, 0.0, 2.0];
        let prepped = map.prep_rows(&raw, 1);
        for (p, &r) in prepped.iter().zip(&raw) {
            assert_eq!(*p, elu1(r));
        }
        let mut phi = vec![0.0f64; 4];
        map.map_q(&prepped, &mut phi);
        for (f, &p) in phi.iter().zip(&prepped) {
            assert_eq!(*f, p as f64);
        }
    }

    #[test]
    #[should_panic(expected = "packed features")]
    fn absurd_order_reports_feature_dim() {
        TaylorMap::new(32, 64, 3.0, true);
    }

    #[test]
    fn prep_rows_into_matches_prep_rows() {
        let mut rng = Rng::new(73);
        let (n, d) = (3, 6);
        let rows = rng.normal_vec_f32(n * d, 1.0);
        let mut buf = Vec::new();
        for normalize in [true, false] {
            let map = TaylorMap::new(d, 2, 3.0, normalize);
            map.prep_rows_into(&rows, n, &mut buf);
            assert_eq!(buf, map.prep_rows(&rows, n), "taylor ln={normalize}");
        }
        let map = EluMap::new(d);
        map.prep_rows_into(&rows, n, &mut buf);
        assert_eq!(buf, map.prep_rows(&rows, n), "elu");
    }
}
