//! Native O(n) attention kernels — the paper's factorized recurrent form.
//!
//! `mathref` holds the direct O(n²) oracles; this module holds the thing
//! the paper is actually about: the same attention computed from running
//! prefix-sum state, so cost is linear in sequence length and decoding is
//! O(1) per token.  For the order-2 Taylor kernel
//!
//! ```text
//! w(q, k) = 1 + u·k + ½(u·k)²          with u = q / (α√d)   (after LN)
//! ```
//!
//! the weighted sums over history factorize through the moment states
//!
//! ```text
//! Σ1 (scalar)   Σk (d)   Σk⊗v (d×dv)   Σk⊗k (d²)   Σ(k⊗k)⊗v (d²×dv)
//! ```
//!
//! where the second-order tensors are symmetric in the two k indices and
//! are stored in packed d(d+1)/2 form (off-diagonal entries weighted 2×
//! on the query side).  Three evaluation strategies share one state type:
//!
//! * [`RecurrentAttention::step`] — streaming: absorb one (k, v), query
//!   one q.  O(1) per token; this is the serving decode path.
//! * [`streaming_forward`] — full sequence via repeated `step` (causal)
//!   or absorb-all-then-query (non-causal).
//! * [`chunked_forward`] — cache-blocked training form: direct O(c²)
//!   weights inside each chunk, recurrent state across chunks.
//!
//! [`NativeBackend`] wraps kernel construction + head/batch loops behind
//! the same `(kind, bh, n, d)` surface as `mathref::attention_bhnd`, so
//! examples, benches and tests run end-to-end with no PJRT artifacts and
//! no Python.  Everything here is checked against the `mathref` oracles
//! in `rust/tests/proptests.rs`.
//!
//! Training runs backward through the same recurrence: [`grad`] carries
//! a state-*gradient* across chunks (mirroring the forward's prefix
//! sums) and differentiates the intra-chunk triangle directly —
//! finite-difference-checked in `rust/tests/grad_check.rs`.

pub mod backend;
pub mod chunked;
pub mod grad;
pub mod ho;
pub mod linear;

pub use self::backend::{Evaluation, NativeBackend};
pub use self::chunked::chunked_forward;
pub use self::grad::{chunked_attention_vjp, softmax_attention_vjp, AttentionGrad};
pub use self::ho::HoState;
pub use self::linear::LinearState;

/// Denominator clamp, identical to the `mathref` oracles: row weights are
/// positive by construction (order-2 Taylor ≥ ½, elu+1 > 0), so this only
/// guards the empty-history edge of step-0 decode.
pub const DEN_FLOOR: f64 = 1e-6;

/// A linear-time attention kernel kept as running prefix-sum state.
///
/// The contract tying the three forms together: after `absorb`ing keys
/// k₁..kₘ with values v₁..vₘ,
///
/// ```text
/// query_raw(q, num) == ( Σⱼ pair_weight(q, kⱼ) · vⱼ ,  Σⱼ pair_weight(q, kⱼ) )
/// ```
///
/// up to floating-point reassociation — which is exactly what lets
/// `chunked_forward` mix recurrent inter-chunk state with direct
/// intra-chunk weights, and what the property tests pin against the
/// O(n²) oracle.
pub trait RecurrentAttention {
    /// Key/query feature dimension.
    fn d(&self) -> usize;

    /// Value dimension.
    fn dv(&self) -> usize;

    /// Forget all absorbed history (state back to empty).
    fn reset(&mut self);

    /// Fold one (key, value) row into the state. `k` has length `d()`,
    /// `v` length `dv()`.
    fn absorb(&mut self, k: &[f32], v: &[f32]);

    /// [`Self::absorb`] for a key row already passed through
    /// [`Self::prep_rows`] — blocked paths reuse the prepped rows they
    /// just computed for the pairwise triangle instead of re-running the
    /// per-row preprocessing. Default assumes prep is the identity.
    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        self.absorb(kp, v);
    }

    /// Unnormalized read: writes the weighted value sum into `num`
    /// (length `dv()`) and returns the weight sum (denominator).
    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64;

    /// The pairwise weight w(q, k) this kernel's state accumulates —
    /// the direct form used for intra-chunk blocks and oracle checks.
    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64;

    /// Apply the kernel's per-row preprocessing (LayerNorm, feature map)
    /// to `n` rows at once, so blocked paths pay it once per row instead
    /// of once per pair. Default: identity copy.
    fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
        rows.to_vec()
    }

    /// [`Self::pair_weight`] over rows already passed through
    /// [`Self::prep_rows`]. Default assumes prep is the identity.
    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        self.pair_weight(q, k)
    }

    /// [`Self::query_raw`] for a query row already passed through
    /// [`Self::prep_rows`] — lets blocked paths reuse the prepped row
    /// for both the state read and the pairwise triangle instead of
    /// re-running the per-row preprocessing. Default assumes prep is
    /// the identity.
    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw(q, num)
    }

    /// Number of f64 elements in the state — constant in sequence
    /// length, which is the O(1)-decode claim in one number.
    fn state_elements(&self) -> usize;

    /// Append the full state to `out` as exactly [`Self::state_elements`]
    /// f64 values.  This is the serialization used by
    /// `model::DecodeSession::snapshot` for slot preemption; the layout is
    /// kernel-private but stable within a process.
    fn save_state(&self, out: &mut Vec<f64>);

    /// Restore state previously written by [`Self::save_state`].  `data`
    /// must be exactly [`Self::state_elements`] values long (panics
    /// otherwise — a length mismatch means the snapshot belongs to a
    /// different kernel configuration, which is a caller bug).
    fn load_state(&mut self, data: &[f64]);

    /// Normalized attention output for `q` over everything absorbed so
    /// far. `out` has length `dv()`.
    fn query(&self, q: &[f32], out: &mut [f32]) {
        let mut num = vec![0.0f64; self.dv()];
        let den = self.query_raw(q, &mut num).max(DEN_FLOOR);
        for (o, x) in out.iter_mut().zip(&num) {
            *o = (x / den) as f32;
        }
    }

    /// One autoregressive decode step: absorb (k, v), then read q —
    /// position i attends to 1..=i, matching the causal oracles.
    fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        self.absorb(k, v);
        self.query(q, out);
    }
}

/// Full-sequence forward driven one token at a time. `q`/`k` are (n, d)
/// row-major, `v` is (n, dv); resets the kernel first. Causal runs the
/// decode recurrence; non-causal absorbs everything, then queries.
pub fn streaming_forward<K: RecurrentAttention + ?Sized>(
    kernel: &mut K,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    causal: bool,
) -> Vec<f32> {
    let (d, dv) = (kernel.d(), kernel.dv());
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    kernel.reset();
    let mut out = vec![0.0f32; n * dv];
    // one numerator scratch for the whole sequence (the per-token `step`
    // convenience allocates; the bulk driver must not)
    let mut num = vec![0.0f64; dv];
    if !causal {
        for j in 0..n {
            kernel.absorb(&k[j * d..(j + 1) * d], &v[j * dv..(j + 1) * dv]);
        }
    }
    for i in 0..n {
        if causal {
            kernel.absorb(&k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv]);
        }
        let den = kernel.query_raw(&q[i * d..(i + 1) * d], &mut num).max(DEN_FLOOR);
        for (o, &x) in out[i * dv..(i + 1) * dv].iter_mut().zip(num.iter()) {
            *o = (x / den) as f32;
        }
    }
    out
}
