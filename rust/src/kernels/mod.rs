//! Native O(n) attention kernels — the paper's factorized recurrent form,
//! organized around one abstraction: the **feature map**.
//!
//! Attention with kernelized weight `w(q, k) = φ_q(q)·φ_k(k)` factorizes
//! through constant-size moment state
//!
//! ```text
//! Z = Σⱼ φ_k(kⱼ)        (feature_dim)
//! M = Σⱼ φ_k(kⱼ) ⊗ vⱼ   (feature_dim × dv)
//! out(q) = φ_q(q)·M / max(φ_q(q)·Z, DEN_FLOOR)
//! ```
//!
//! so cost is linear in sequence length and decoding is O(1) per token —
//! for *any* φ.  The layer is split accordingly:
//!
//! * [`FeatureMap`] ([`featuremap`]) — the φs: [`TaylorMap`] (the paper's
//!   kernel at **any** Taylor order r, packed symmetric features,
//!   `Σ_{j≤r} C(d+j−1, j)` per row) and [`EluMap`] (elu+1, Katharopoulos
//!   et al. 2020).
//! * [`PhiState`] ([`phi`]) — the recurrence, implemented **once**:
//!   absorb / query / snapshot ([`RecurrentAttention`]) and the
//!   state-gradient VJPs ([`AttentionGrad`]).  [`HoState`] and
//!   [`LinearState`] are type aliases instantiating it.
//! * three evaluation strategies over one state type:
//!   [`RecurrentAttention::step`] (streaming decode),
//!   [`streaming_forward`], and the cache-blocked [`chunked_forward`]
//!   (direct O(c²) pair weights inside a chunk via
//!   [`AttentionGrad::pair_weight_from_dot`], recurrent state across
//!   chunks).  [`chunked_attention_vjp`] ([`grad`]) runs the same shape
//!   backward.  [`NativeBackend`] ([`backend`]) wraps construction +
//!   head/batch loops behind the `(kind, bh, n, d)` surface.
//!
//! # Adding a feature map (~30 lines)
//!
//! Implement [`FeatureMap`] and everything above comes for free — state,
//! O(1) decode, chunked training forward, hand-derived backward,
//! snapshot/preemption and the serve scheduler.  For a pointwise φ
//! (like elu+1) that is nine mostly-one-line methods:
//!
//! ```ignore
//! struct SquaredMap { d: usize }
//! impl FeatureMap for SquaredMap {
//!     fn d(&self) -> usize { self.d }
//!     fn feature_dim(&self) -> usize { self.d }
//!     // φ(x) = x² + 1 applied row-wise in prep; map is then identity
//!     fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
//!         rows.iter().map(|&x| x * x + 1.0).collect()
//!     }
//!     fn prep_rows_vjp(&self, rows: &[f32], _n: usize, g: &[f64]) -> Vec<f64> {
//!         rows.iter().zip(g).map(|(&x, &gp)| gp * 2.0 * x as f64).collect()
//!     }
//!     fn map_q(&self, xp: &[f32], out: &mut [f64]) {
//!         for (o, &x) in out.iter_mut().zip(xp) { *o = x as f64; }
//!     }
//!     fn map_k(&self, xp: &[f32], out: &mut [f64]) { self.map_q(xp, out) }
//!     fn map_q_vjp(&self, _xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
//!         for (o, &g) in dxp.iter_mut().zip(dphi) { *o += g; }
//!     }
//!     fn map_k_vjp(&self, xp: &[f32], dphi: &[f64], dxp: &mut [f64]) {
//!         self.map_q_vjp(xp, dphi, dxp)
//!     }
//!     fn pair_weight_from_dot(&self, dot: f64) -> f64 { dot }
//!     fn pair_weight_dot_grad(&self, _dot: f64) -> f64 { 1.0 }
//! }
//! // PhiState::with_map(SquaredMap { d }, dv) now decodes, trains, serves.
//! ```
//!
//! A non-pointwise φ (e.g. a SOFT-style Gaussian random-features kernel
//! from PAPERS.md) instead does its work in `map_q`/`map_k` — see
//! [`TaylorMap`] for the full-strength example with asymmetric q/k sides.
//!
//! Everything here is checked against the independent O(n²) `mathref`
//! oracles in `rust/tests/proptests.rs` (orders 0–3), FD-checked in
//! `rust/tests/grad_check.rs`, and pinned bit-identical to the
//! pre-`FeatureMap` order-≤2 kernels in `rust/tests/golden_order2.rs`.
//!
//! # Hot path invariants
//!
//! The decode hot path is `PhiState::step` — absorb one (k, v), read one
//! q — once per token per (layer, head).  Three invariants keep it fast
//! and keep the fast paths honest:
//!
//! * **Scratch-arena ownership.**  Every transient the recurrence needs
//!   (φ features, dφ, the widened value row, the normalized-read
//!   numerator, prepped q/k rows) lives in a per-engine
//!   [`scratch::Scratch`] behind one `RefCell`.  Entry points take at
//!   most one borrow at a time; any buffer that must outlive a nested
//!   scratch-using call travels by `take_*`/`put_*` move instead of a
//!   held borrow.  After the first token, absorb / query / step and
//!   both vjps do **zero heap traffic** (pinned by
//!   `rust/tests/alloc_decode.rs`).  States are `Send`, not `Sync` —
//!   one engine per decode slot / attention unit.
//! * **Lane layout.**  The inner loops dispatch on a per-state
//!   [`simd::Isa`]: the (F, dv) moment update/read runs 4 × f64 lanes,
//!   two feature rows per pass ([`simd::matvec_accum`]), dots run
//!   4-lane partial sums + FMA.  Dispatch is chosen at runtime
//!   ([`simd::active`]: AVX2+FMA detection, `HOLT_SIMD` override) and
//!   can be pinned per state (`PhiState::set_isa`) — never via mutable
//!   globals, so parallel tests can't race it.
//! * **When reassociation is allowed.**  Never for state: the absorb
//!   update is elementwise multiply-then-add with FMA forbidden, so
//!   state bits are identical across every ISA (and snapshots /
//!   golden pins stay exact).  Query-side reductions may reassociate
//!   and contract: outputs drift ≤ 1e-6 relative vs the always-kept
//!   [`simd::Isa::Scalar`] reference path, which itself reproduces the
//!   pre-SIMD accumulation order bit for bit.  Anything asserting
//!   bit-equality must pin `Isa::Scalar`.

pub mod backend;
pub mod chunked;
pub mod featuremap;
pub mod grad;
pub mod ho;
pub mod linear;
pub mod phi;
pub mod scratch;
pub mod simd;

pub use self::backend::{Evaluation, NativeBackend};
pub use self::chunked::chunked_forward;
pub use self::featuremap::{
    taylor_feature_dim, EluMap, FeatureMap, TaylorMap, MAX_TAYLOR_FEATURES,
};
pub use self::grad::{
    chunked_attention_vjp, chunked_attention_vjp_reverse, chunked_forward_captured,
    softmax_attention_vjp, AttentionGrad, CapturedChunks,
};
pub use self::ho::HoState;
pub use self::linear::LinearState;
pub use self::phi::PhiState;
pub use self::scratch::Scratch;
pub use self::simd::Isa;

/// Process-global attention-forward counter — the instrument behind the
/// "one attention forward per train step" claim.
///
/// Every *full-sequence* forward evaluation counts exactly once:
/// [`streaming_forward`], the causal [`chunked_forward`] pass, and the
/// capturing [`grad::chunked_forward_captured`].  Per-token decode
/// ([`RecurrentAttention::step`]) does not — it is a different cost
/// class and the claim is about training.
///
/// The counter lives in the global [`crate::obs`] registry under
/// `"attn_forwards"`, so `{"metrics": true}` and the training step log
/// see the same cell the tests assert on.  It is cumulative for the
/// process; tests asserting exact deltas must serialize against each
/// other (`rust/tests/fused_train.rs` does so with a process-local
/// mutex) so concurrent tests can't interleave.
pub mod counters {
    use std::sync::OnceLock;

    use crate::obs;

    /// The registry-backed counter cell, registered on first touch.
    pub fn handle() -> &'static obs::Counter {
        static HANDLE: OnceLock<obs::Counter> = OnceLock::new();
        HANDLE.get_or_init(|| obs::global().counter("attn_forwards"))
    }

    /// Cumulative full-sequence attention forwards since process start.
    /// Shim kept for existing callers; reads the registry cell.
    pub fn attn_forwards() -> u64 {
        handle().get()
    }

    #[inline]
    pub(crate) fn count_attn_forward() {
        handle().inc();
    }
}

/// Denominator clamp, identical to the `mathref` oracles: row weights are
/// positive by construction (even-order Taylor ≥ ½ⁱˢʰ, elu+1 > 0), so in
/// practice this only guards the empty-history edge of step-0 decode and
/// pathological φ values.
pub const DEN_FLOOR: f64 = 1e-6;

/// The one shared denominator clamp used by every read path (the trait's
/// [`RecurrentAttention::query`], [`streaming_forward`],
/// [`chunked_forward`] and the backward replay in [`grad`]) — previously
/// each carried its own `max(DEN_FLOOR)` copy that could drift.
#[inline]
pub fn floor_den(den: f64) -> f64 {
    den.max(DEN_FLOOR)
}

/// Whether a raw denominator sits at/below the floor.  At the floor the
/// clamped denominator is a constant, so the backward takes the
/// subgradient `∂out/∂den = 0` — [`grad`] uses this exact predicate so
/// forward and backward cannot disagree about which side of the clamp a
/// position is on.
#[inline]
pub fn den_is_clamped(den: f64) -> bool {
    den <= DEN_FLOOR
}

/// A linear-time attention kernel kept as running prefix-sum state.
///
/// The contract tying the three evaluation forms together: after
/// `absorb`ing keys k₁..kₘ with values v₁..vₘ,
///
/// ```text
/// query_raw(q, num) == ( Σⱼ pair_weight(q, kⱼ) · vⱼ ,  Σⱼ pair_weight(q, kⱼ) )
/// ```
///
/// up to floating-point reassociation — which is exactly what lets
/// `chunked_forward` mix recurrent inter-chunk state with direct
/// intra-chunk weights, and what the property tests pin against the
/// O(n²) oracle.  The single implementation is [`PhiState`]; this trait
/// is the object-safe surface the model/serve layers consume.
pub trait RecurrentAttention {
    /// Key/query feature dimension.
    fn d(&self) -> usize;

    /// Value dimension.
    fn dv(&self) -> usize;

    /// Forget all absorbed history (state back to empty).
    fn reset(&mut self);

    /// Fold one (key, value) row into the state. `k` has length `d()`,
    /// `v` length `dv()`.
    fn absorb(&mut self, k: &[f32], v: &[f32]);

    /// [`Self::absorb`] for a key row already passed through
    /// [`Self::prep_rows`] — blocked paths reuse the prepped rows they
    /// just computed for the pairwise triangle instead of re-running the
    /// per-row preprocessing. Default assumes prep is the identity.
    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        self.absorb(kp, v);
    }

    /// Unnormalized read: writes the weighted value sum into `num`
    /// (length `dv()`) and returns the weight sum (denominator).
    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64;

    /// The pairwise weight w(q, k) this kernel's state accumulates —
    /// the direct form used for intra-chunk blocks and oracle checks.
    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64;

    /// Apply the kernel's per-row preprocessing (LayerNorm, pointwise φ)
    /// to `n` rows at once, so blocked paths pay it once per row instead
    /// of once per pair. Default: identity copy.
    fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
        rows.to_vec()
    }

    /// [`Self::prep_rows`] into a caller-owned buffer, reusing its
    /// capacity — the allocation-free variant the hot paths use.
    /// Default delegates to [`Self::prep_rows`] (correct for any
    /// override, but allocates — kernels on the hot path override this).
    fn prep_rows_into(&self, rows: &[f32], n: usize, out: &mut Vec<f32>) {
        *out = self.prep_rows(rows, n);
    }

    /// Which lane-tiled implementation this kernel's inner loops run —
    /// blocked drivers ([`chunked_forward`], the backward replay) use it
    /// for their own dots so one knob pins the whole evaluation.
    /// Default: the process-wide [`simd::active`] choice.
    fn isa(&self) -> simd::Isa {
        simd::active()
    }

    /// [`Self::pair_weight`] over rows already passed through
    /// [`Self::prep_rows`]. Default assumes prep is the identity.
    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        self.pair_weight(q, k)
    }

    /// [`Self::query_raw`] for a query row already passed through
    /// [`Self::prep_rows`] — lets blocked paths reuse the prepped row
    /// for both the state read and the pairwise triangle instead of
    /// re-running the per-row preprocessing. Default assumes prep is
    /// the identity.
    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw(q, num)
    }

    /// Number of f64 elements in the state — constant in sequence
    /// length, which is the O(1)-decode claim in one number.
    fn state_elements(&self) -> usize;

    /// Append the full state to `out` as exactly [`Self::state_elements`]
    /// f64 values.  This is the serialization used by
    /// `model::DecodeSession::snapshot` for slot preemption; the layout is
    /// kernel-private but stable within a process (for [`PhiState`]:
    /// `[Z (F), M (F·dv)]`).
    fn save_state(&self, out: &mut Vec<f64>);

    /// Restore state previously written by [`Self::save_state`].  `data`
    /// must be exactly [`Self::state_elements`] values long (panics
    /// otherwise — a length mismatch means the snapshot belongs to a
    /// different kernel configuration, which is a caller bug).
    fn load_state(&mut self, data: &[f64]);

    /// Normalized attention output for `q` over everything absorbed so
    /// far. `out` has length `dv()`.
    fn query(&self, q: &[f32], out: &mut [f32]) {
        let mut num = vec![0.0f64; self.dv()];
        let den = floor_den(self.query_raw(q, &mut num));
        for (o, x) in out.iter_mut().zip(&num) {
            *o = (x / den) as f32;
        }
    }

    /// One autoregressive decode step: absorb (k, v), then read q —
    /// position i attends to 1..=i, matching the causal oracles.
    fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        self.absorb(k, v);
        self.query(q, out);
    }
}

/// Full-sequence forward driven one token at a time. `q`/`k` are (n, d)
/// row-major, `v` is (n, dv); resets the kernel first. Causal runs the
/// decode recurrence; non-causal absorbs everything, then queries.
pub fn streaming_forward<K: RecurrentAttention + ?Sized>(
    kernel: &mut K,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    causal: bool,
) -> Vec<f32> {
    let (d, dv) = (kernel.d(), kernel.dv());
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    counters::count_attn_forward();
    kernel.reset();
    let mut out = vec![0.0f32; n * dv];
    // one numerator scratch for the whole sequence (the per-token `step`
    // convenience allocates; the bulk driver must not)
    let mut num = vec![0.0f64; dv];
    if !causal {
        for j in 0..n {
            kernel.absorb(&k[j * d..(j + 1) * d], &v[j * dv..(j + 1) * dv]);
        }
    }
    for i in 0..n {
        if causal {
            kernel.absorb(&k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv]);
        }
        let den = floor_den(kernel.query_raw(&q[i * d..(i + 1) * d], &mut num));
        for (o, &x) in out[i * dv..(i + 1) * dv].iter_mut().zip(num.iter()) {
            *o = (x / den) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floor_den_and_clamp_predicate_agree() {
        // one helper, one predicate: a denominator is clamped exactly
        // when flooring changed (or pinned) it
        for den in [-1.0, 0.0, 1e-9, DEN_FLOOR, 1e-3, 7.5] {
            assert_eq!(floor_den(den), den.max(DEN_FLOOR));
            assert_eq!(den_is_clamped(den), floor_den(den) > den || den == DEN_FLOOR);
        }
    }
}
