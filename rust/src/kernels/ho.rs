//! The paper's higher-order (Taylor) linear attention as recurrent state.
//!
//! Order r keeps the key moments 0..=r.  For r = 2 the quadratic moment
//! k⊗k is symmetric, so only the upper triangle is stored: d(d+1)/2
//! packed entries instead of d², with the factor 2 for off-diagonal terms
//! folded into the *query-side* feature (the state stays a plain sum of
//! per-key products, so absorb stays cheap and exact).
//!
//! All state is f64 — the reference oracle accumulates in f64 too, and
//! running sums live across an entire sequence, where f32 cancellation
//! would show up long before the 1e-4 cross-check tolerance.

use crate::kernels::{AttentionGrad, RecurrentAttention};
use crate::mathref::{layernorm_noaffine, layernorm_noaffine_vjp, taylor_exp};

/// LayerNorm epsilon — must match `mathref::ho_attention` exactly for the
/// oracle cross-checks to be meaningful.
const LN_EPS: f32 = 1e-5;

/// Recurrent state for order-0/1/2 Taylor attention over one head.
pub struct HoState {
    d: usize,
    dv: usize,
    order: usize,
    /// 1 / (α √d): folded into the query features, never into the state.
    scale: f64,
    normalize_qk: bool,
    /// Σ 1 — number of absorbed keys (order ≥ 0 denominator).
    s0: f64,
    /// Σ v — (dv).
    s0v: Vec<f64>,
    /// Σ k — (d), order ≥ 1.
    s1: Vec<f64>,
    /// Σ k⊗v — (d, dv) row-major, order ≥ 1.
    s1v: Vec<f64>,
    /// Σ packed(k⊗k) — (d(d+1)/2), order ≥ 2.
    s2: Vec<f64>,
    /// Σ packed(k⊗k)⊗v — (d(d+1)/2, dv) row-major, order ≥ 2.
    s2v: Vec<f64>,
}

impl HoState {
    /// New empty state. `order` ≤ 2 (the paper's range — order r would
    /// need Θ(dʳ·dv) state; r = 2 is the accuracy/cost point the paper
    /// argues for). `alpha` is the logit damping α, `normalize_qk`
    /// applies per-row LayerNorm to q and k as in the paper.
    pub fn new(d: usize, dv: usize, order: usize, alpha: f64, normalize_qk: bool) -> HoState {
        assert!(
            order <= 2,
            "HoState supports Taylor orders 0..=2, got {order} \
             (order r needs d^r-sized state; see kernels::ho docs)"
        );
        assert!(d > 0 && dv > 0, "empty head dims");
        assert!(alpha > 0.0, "alpha must be positive");
        let t = d * (d + 1) / 2;
        HoState {
            d,
            dv,
            order,
            scale: 1.0 / (alpha * (d as f64).sqrt()),
            normalize_qk,
            s0: 0.0,
            s0v: vec![0.0; dv],
            s1: vec![0.0; if order >= 1 { d } else { 0 }],
            s1v: vec![0.0; if order >= 1 { d * dv } else { 0 }],
            s2: vec![0.0; if order >= 2 { t } else { 0 }],
            s2v: vec![0.0; if order >= 2 { t * dv } else { 0 }],
        }
    }

    /// Paper defaults: order 2, α = 3, LayerNorm on q/k.
    pub fn paper(d: usize, dv: usize) -> HoState {
        HoState::new(d, dv, 2, 3.0, true)
    }

    pub fn order(&self) -> usize {
        self.order
    }

    /// Row-wise LayerNorm (when enabled) of a single q/k row — f32, same
    /// arithmetic as the oracle's whole-matrix pass.
    fn normalized(&self, row: &[f32]) -> Vec<f32> {
        let mut out = row.to_vec();
        if self.normalize_qk {
            layernorm_noaffine(&mut out, 1, self.d, LN_EPS);
        }
        out
    }

    /// State read for an already-normalized query row.
    fn query_raw_normed(&self, qn: &[f32], num: &mut [f64]) -> f64 {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(qn.len(), d, "q row");
        assert_eq!(num.len(), dv, "num row");
        // order-0 term: w ⊇ 1 for every key
        let mut den = self.s0;
        num.copy_from_slice(&self.s0v);
        // u = scaled query; dot·scale == u·k
        let u: Vec<f64> = qn.iter().map(|&x| self.scale * x as f64).collect();
        if self.order >= 1 {
            for a in 0..d {
                let ua = u[a];
                den += ua * self.s1[a];
                let row = &self.s1v[a * dv..(a + 1) * dv];
                for (acc, &x) in num.iter_mut().zip(row) {
                    *acc += ua * x;
                }
            }
        }
        if self.order >= 2 {
            // ½(u·k)² = Σ_{a≤b} f_ab · (k_a k_b), f_ab = u_a u_b (a = b)
            // or 2·½·u_a u_b (a < b) — symmetry folded into the query side
            let mut p = 0;
            for a in 0..d {
                let ua = u[a];
                for b in a..d {
                    let f = if a == b { 0.5 * ua * ua } else { ua * u[b] };
                    den += f * self.s2[p];
                    let row = &self.s2v[p * dv..(p + 1) * dv];
                    for (acc, &x) in num.iter_mut().zip(row) {
                        *acc += f * x;
                    }
                    p += 1;
                }
            }
        }
        den
    }
}

impl RecurrentAttention for HoState {
    fn d(&self) -> usize {
        self.d
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn reset(&mut self) {
        self.s0 = 0.0;
        self.s0v.fill(0.0);
        self.s1.fill(0.0);
        self.s1v.fill(0.0);
        self.s2.fill(0.0);
        self.s2v.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        let kn = self.normalized(k);
        self.absorb_prepped(&kn, v);
    }

    /// Absorb a key row that already went through [`Self::prep_rows`] —
    /// the blocked path pays the LayerNorm once per row instead of twice.
    fn absorb_prepped(&mut self, kn: &[f32], v: &[f32]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(kn.len(), d, "k row");
        assert_eq!(v.len(), dv, "v row");
        self.s0 += 1.0;
        for (acc, &x) in self.s0v.iter_mut().zip(v) {
            *acc += x as f64;
        }
        if self.order >= 1 {
            for a in 0..d {
                let ka = kn[a] as f64;
                self.s1[a] += ka;
                let row = &mut self.s1v[a * dv..(a + 1) * dv];
                for (acc, &x) in row.iter_mut().zip(v) {
                    *acc += ka * x as f64;
                }
            }
        }
        if self.order >= 2 {
            let mut p = 0;
            for a in 0..d {
                let ka = kn[a] as f64;
                for b in a..d {
                    let kk = ka * kn[b] as f64;
                    self.s2[p] += kk;
                    let row = &mut self.s2v[p * dv..(p + 1) * dv];
                    for (acc, &x) in row.iter_mut().zip(v) {
                        *acc += kk * x as f64;
                    }
                    p += 1;
                }
            }
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw_normed(&self.normalized(q), num)
    }

    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        // prep_rows already applied the LayerNorm
        self.query_raw_normed(q, num)
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        self.pair_weight_prepped(&self.normalized(q), &self.normalized(k))
    }

    /// LayerNorm a whole block of rows once — same arithmetic as
    /// `normalized` per row, paid n times instead of n·c times.
    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        let mut out = rows.to_vec();
        if self.normalize_qk {
            layernorm_noaffine(&mut out, n, self.d, LN_EPS);
        }
        out
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        let mut dot = 0.0f64;
        for (&a, &b) in q.iter().zip(k) {
            dot += a as f64 * b as f64;
        }
        taylor_exp(dot * self.scale, self.order)
    }

    fn state_elements(&self) -> usize {
        1 + self.s0v.len() + self.s1.len() + self.s1v.len() + self.s2.len() + self.s2v.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.reserve(self.state_elements());
        out.push(self.s0);
        out.extend_from_slice(&self.s0v);
        out.extend_from_slice(&self.s1);
        out.extend_from_slice(&self.s1v);
        out.extend_from_slice(&self.s2);
        out.extend_from_slice(&self.s2v);
    }

    fn load_state(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.state_elements(), "HoState snapshot size");
        let (head, rest) = data.split_at(1);
        self.s0 = head[0];
        let (a, rest) = rest.split_at(self.s0v.len());
        self.s0v.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s1.len());
        self.s1.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s1v.len());
        self.s1v.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s2.len());
        self.s2.copy_from_slice(a);
        self.s2v.copy_from_slice(rest);
    }
}

impl AttentionGrad for HoState {
    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        taylor_exp(dot * self.scale, self.order)
    }

    fn pair_weight_dot_grad(&self, dot: f64) -> f64 {
        // d/ds Tᵣ(s·scale) = scale · Tᵣ₋₁(s·scale); order 0 is constant
        if self.order == 0 {
            0.0
        } else {
            self.scale * taylor_exp(dot * self.scale, self.order - 1)
        }
    }

    fn query_vjp(&self, qp: &[f32], dnum: &[f64], dden: f64, gstate: &mut [f64], gqp: &mut [f64]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(qp.len(), d, "q row");
        assert_eq!(dnum.len(), dv, "dnum row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let u: Vec<f64> = qp.iter().map(|&x| self.scale * x as f64).collect();
        let mut du = vec![0.0f64; d];
        // gstate layout == save_state: [s0, s0v, s1, s1v, s2, s2v]
        gstate[0] += dden;
        let mut off = 1;
        for (g, &x) in gstate[off..off + dv].iter_mut().zip(dnum) {
            *g += x;
        }
        off += dv;
        if self.order >= 1 {
            for a in 0..d {
                gstate[off + a] += dden * u[a];
                du[a] += dden * self.s1[a];
            }
            off += d;
            for a in 0..d {
                let srow = &self.s1v[a * dv..(a + 1) * dv];
                let grow = &mut gstate[off + a * dv..off + (a + 1) * dv];
                let mut acc = 0.0f64;
                for ((g, &x), &s) in grow.iter_mut().zip(dnum).zip(srow) {
                    *g += u[a] * x;
                    acc += x * s;
                }
                du[a] += acc;
            }
            off += d * dv;
        }
        if self.order >= 2 {
            let off2v = off + self.s2.len();
            let mut p = 0;
            for a in 0..d {
                for b in a..d {
                    // f_p = ½u_a² (a = b) or u_a·u_b (a < b)
                    let f = if a == b { 0.5 * u[a] * u[a] } else { u[a] * u[b] };
                    gstate[off + p] += dden * f;
                    let srow = &self.s2v[p * dv..(p + 1) * dv];
                    let grow = &mut gstate[off2v + p * dv..off2v + (p + 1) * dv];
                    let mut dfp = dden * self.s2[p];
                    for ((g, &x), &s) in grow.iter_mut().zip(dnum).zip(srow) {
                        *g += f * x;
                        dfp += x * s;
                    }
                    if a == b {
                        du[a] += dfp * u[a];
                    } else {
                        du[a] += dfp * u[b];
                        du[b] += dfp * u[a];
                    }
                    p += 1;
                }
            }
        }
        for (g, &x) in gqp.iter_mut().zip(&du) {
            *g += self.scale * x;
        }
    }

    fn absorb_vjp(&self, kp: &[f32], v: &[f32], gstate: &[f64], gkp: &mut [f64], gv: &mut [f64]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(kp.len(), d, "k row");
        assert_eq!(v.len(), dv, "v row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let kn: Vec<f64> = kp.iter().map(|&x| x as f64).collect();
        // s0 += 1 carries no input gradient
        let mut off = 1;
        for (g, &gs) in gv.iter_mut().zip(&gstate[off..off + dv]) {
            *g += gs;
        }
        off += dv;
        if self.order >= 1 {
            for a in 0..d {
                gkp[a] += gstate[off + a];
            }
            off += d;
            for a in 0..d {
                let grow = &gstate[off + a * dv..off + (a + 1) * dv];
                let mut acc = 0.0f64;
                for ((gvc, &gs), &vc) in gv.iter_mut().zip(grow).zip(v) {
                    *gvc += kn[a] * gs;
                    acc += gs * vc as f64;
                }
                gkp[a] += acc;
            }
            off += d * dv;
        }
        if self.order >= 2 {
            let off2v = off + self.s2.len();
            let mut p = 0;
            for a in 0..d {
                for b in a..d {
                    let g2 = gstate[off + p];
                    let grow = &gstate[off2v + p * dv..off2v + (p + 1) * dv];
                    let kk = kn[a] * kn[b];
                    let mut gvdot = 0.0f64;
                    for ((gvc, &gs), &vc) in gv.iter_mut().zip(grow).zip(v) {
                        *gvc += kk * gs;
                        gvdot += gs * vc as f64;
                    }
                    let s = g2 + gvdot;
                    if a == b {
                        // d(k_a²)/dk_a = 2k_a
                        gkp[a] += 2.0 * kn[a] * s;
                    } else {
                        gkp[a] += kn[b] * s;
                        gkp[b] += kn[a] * s;
                    }
                    p += 1;
                }
            }
        }
    }

    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64> {
        if self.normalize_qk {
            layernorm_noaffine_vjp(rows, n, self.d, LN_EPS, g)
        } else {
            g.to_vec()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::streaming_forward;
    use crate::mathref;
    use crate::rng::Rng;

    #[test]
    fn absorb_prepped_equals_absorb_on_raw_rows() {
        // the blocked state pass reuses prepped rows; it must land on the
        // exact same state as the streaming absorb of raw rows
        let mut rng = Rng::new(6);
        let (d, dv) = (6, 5);
        let mut a = HoState::paper(d, dv);
        let mut b = HoState::paper(d, dv);
        for _ in 0..7 {
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            a.absorb(&k, &v);
            let kp = b.prep_rows(&k, 1);
            b.absorb_prepped(&kp, &v);
        }
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        a.save_state(&mut sa);
        b.save_state(&mut sb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn matches_oracle_on_small_case() {
        let mut rng = Rng::new(1);
        let (n, d, dv) = (10, 6, 5);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        for order in [0, 1, 2] {
            for causal in [true, false] {
                let oracle =
                    mathref::ho_attention(&q, &k, &v, n, n, d, dv, order, 3.0, causal, true);
                let mut st = HoState::new(d, dv, order, 3.0, true);
                let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
                for (a, b) in got.iter().zip(&oracle) {
                    assert!((a - b).abs() < 1e-5, "order {order} causal {causal}");
                }
            }
        }
    }

    #[test]
    fn constant_v_is_reproduced() {
        // row-normalized weights: constant v comes back exactly
        let mut rng = Rng::new(2);
        let (d, dv) = (8, 8);
        let mut st = HoState::paper(d, dv);
        let mut out = vec![0.0f32; dv];
        let constant_v = vec![1.5f32; dv];
        for _ in 0..20 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            st.step(&q, &k, &constant_v, &mut out);
            for &x in &out {
                assert!((x - 1.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn state_size_is_constant_in_sequence_length() {
        let (d, dv) = (16, 16);
        let mut st = HoState::paper(d, dv);
        let before = st.state_elements();
        let mut rng = Rng::new(3);
        let mut out = vec![0.0f32; dv];
        for _ in 0..500 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            st.step(&q, &k, &v, &mut out);
        }
        assert_eq!(st.state_elements(), before);
        // packed form: d(d+1)/2 second-order rows, not d²
        let t = d * (d + 1) / 2;
        assert_eq!(before, 1 + dv + d + d * dv + t + t * dv);
    }

    #[test]
    fn reset_restores_empty_state() {
        let (d, dv) = (4, 4);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec_f32(d, 1.0);
        let k = rng.normal_vec_f32(d, 1.0);
        let v = rng.normal_vec_f32(dv, 1.0);
        let mut a = HoState::paper(d, dv);
        let mut out1 = vec![0.0f32; dv];
        a.step(&q, &k, &v, &mut out1);
        a.reset();
        let mut out2 = vec![0.0f32; dv];
        a.step(&q, &k, &v, &mut out2);
        assert_eq!(out1, out2);
    }

    #[test]
    #[should_panic(expected = "orders 0..=2")]
    fn rejects_order_three() {
        HoState::new(4, 4, 3, 3.0, true);
    }
}
