//! The paper's higher-order (Taylor) linear attention — now a thin
//! instantiation of the generic φ-outer-product recurrence:
//! [`HoState`] = [`PhiState`]<[`TaylorMap`]>.
//!
//! Everything that used to live here (the hand-specialized order-0/1/2
//! absorb/query/vjp bodies) is the generic [`PhiState`] implementation in
//! `kernels/phi.rs` driven by the packed-monomial features of
//! [`TaylorMap`] in `kernels/featuremap.rs` — one recurrence, any order.
//! Order ≤ 2 results are bit-identical to the deleted specialized code
//! (pinned in `rust/tests/golden_order2.rs`); order ≥ 3 is the same code
//! with more feature blocks.

use crate::kernels::{PhiState, TaylorMap};

/// Recurrent state for Taylor attention of any order over one head.
pub type HoState = PhiState<TaylorMap>;

impl PhiState<TaylorMap> {
    /// New empty state.  `order` is any Taylor order r ≥ 0 — the packed
    /// symmetric state is `Σ_{j≤r} C(d+j−1, j)` features per head (NOT
    /// dʳ — packing is exactly why order 3 is affordable); construction
    /// panics with the computed feature dim when it exceeds
    /// [`crate::kernels::MAX_TAYLOR_FEATURES`].  `alpha` is the logit
    /// damping α, `normalize_qk` applies per-row LayerNorm to q and k as
    /// in the paper.
    pub fn new(d: usize, dv: usize, order: usize, alpha: f64, normalize_qk: bool) -> HoState {
        PhiState::with_map(TaylorMap::new(d, order, alpha, normalize_qk), dv)
    }

    /// Paper defaults: order 2, α = 3, LayerNorm on q/k.
    pub fn paper(d: usize, dv: usize) -> HoState {
        HoState::new(d, dv, 2, 3.0, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{streaming_forward, RecurrentAttention};
    use crate::mathref;
    use crate::rng::Rng;

    #[test]
    fn absorb_prepped_equals_absorb_on_raw_rows() {
        // the blocked state pass reuses prepped rows; it must land on the
        // exact same state as the streaming absorb of raw rows
        let mut rng = Rng::new(6);
        let (d, dv) = (6, 5);
        let mut a = HoState::paper(d, dv);
        let mut b = HoState::paper(d, dv);
        for _ in 0..7 {
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            a.absorb(&k, &v);
            let kp = b.prep_rows(&k, 1);
            b.absorb_prepped(&kp, &v);
        }
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        a.save_state(&mut sa);
        b.save_state(&mut sb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn matches_oracle_on_small_case() {
        let mut rng = Rng::new(1);
        let (n, d, dv) = (10, 6, 5);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        // order 3 rides the same loop now — one kernel, one more block
        for order in [0, 1, 2, 3] {
            for causal in [true, false] {
                let oracle =
                    mathref::ho_attention(&q, &k, &v, n, n, d, dv, order, 3.0, causal, true);
                let mut st = HoState::new(d, dv, order, 3.0, true);
                let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
                for (a, b) in got.iter().zip(&oracle) {
                    assert!((a - b).abs() < 1e-5, "order {order} causal {causal}");
                }
            }
        }
    }

    #[test]
    fn constant_v_is_reproduced() {
        // row-normalized weights: constant v comes back exactly
        let mut rng = Rng::new(2);
        let (d, dv) = (8, 8);
        let mut st = HoState::paper(d, dv);
        let mut out = vec![0.0f32; dv];
        let constant_v = vec![1.5f32; dv];
        for _ in 0..20 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            st.step(&q, &k, &constant_v, &mut out);
            for &x in &out {
                assert!((x - 1.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn state_size_is_constant_in_sequence_length() {
        let (d, dv) = (16, 16);
        let mut st = HoState::paper(d, dv);
        let before = st.state_elements();
        let mut rng = Rng::new(3);
        let mut out = vec![0.0f32; dv];
        for _ in 0..500 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            st.step(&q, &k, &v, &mut out);
        }
        assert_eq!(st.state_elements(), before);
        // packed form: d(d+1)/2 second-order rows, not d²
        let t = d * (d + 1) / 2;
        assert_eq!(before, (1 + d + t) * (1 + dv));
    }

    #[test]
    fn order3_state_is_the_packed_cubic() {
        let (d, dv) = (8, 8);
        let st = HoState::new(d, dv, 3, 3.0, true);
        // C(d+2, 3) packed cubic rows, not d³
        let t2 = d * (d + 1) / 2;
        let t3 = d * (d + 1) * (d + 2) / 6;
        assert_eq!(st.state_elements(), (1 + d + t2 + t3) * (1 + dv));
        assert_eq!(st.order(), 3);
    }

    #[test]
    fn reset_restores_empty_state() {
        let (d, dv) = (4, 4);
        let mut rng = Rng::new(4);
        let q = rng.normal_vec_f32(d, 1.0);
        let k = rng.normal_vec_f32(d, 1.0);
        let v = rng.normal_vec_f32(dv, 1.0);
        let mut a = HoState::new(d, dv, 3, 3.0, true);
        let mut out1 = vec![0.0f32; dv];
        a.step(&q, &k, &v, &mut out1);
        a.reset();
        let mut out2 = vec![0.0f32; dv];
        a.step(&q, &k, &v, &mut out2);
        assert_eq!(out1, out2);
    }

    #[test]
    #[should_panic(expected = "packed features")]
    fn oversized_order_reports_the_computed_feature_dim() {
        // the old assert claimed "order r needs d^r-sized state" — wrong
        // (packed state is C(d+r−1, r) per degree, which is the whole
        // point); the error now reports the computed feature dim
        HoState::new(32, 64, 64, 3.0, true);
    }
}
