//! Per-engine scratch arena for the φ hot path.
//!
//! Every transient buffer the recurrence needs — φ features, reverse-mode
//! dφ, the f64-widened value row, the normalized-read numerator, and the
//! prepped q/k rows — lives here, owned by the `PhiState` that uses it.
//! Buffers are sized once (at state construction or on first use) and
//! reused for the lifetime of the engine, so decode, prefill, and train
//! steps do **zero heap traffic per token** after warm-up (pinned by the
//! counting-allocator test `rust/tests/alloc_decode.rs`).
//!
//! # Ownership rules
//!
//! * The arena is reached through a single `RefCell` on the owning state;
//!   kernel entry points take at most one borrow at a time.
//! * Entry points that need a scratch buffer *and* call back into another
//!   scratch-using entry point (`absorb` → `absorb_prepped`, `query` →
//!   `query_raw_prepped`) **move** the buffer out with the `take_*` /
//!   `put_*` pair instead of holding the borrow across the call — the
//!   `Vec` travels by value, the `RefCell` stays free, and the capacity
//!   comes back when the buffer is returned.
//! * All buffers are assign-only in their users (every element written
//!   before read), so reuse never needs a zero-fill pass.

/// Reusable transient buffers for one `PhiState` engine.
#[derive(Debug, Default)]
pub struct Scratch {
    /// φ features of the row being absorbed or queried (len `feature_dim`).
    pub phi: Vec<f64>,
    /// Reverse-mode dφ accumulator for the vjps (len `feature_dim`).
    pub dphi: Vec<f64>,
    /// f64-widened value row for the state update (len `dv`).
    pub v64: Vec<f64>,
    /// Numerator buffer for the normalized `query` read (len `dv`);
    /// taken/put because `query` hands it to `query_raw_prepped`, which
    /// borrows the arena itself.
    num: Vec<f64>,
    /// Prepped single-row q/k buffer (capacity `d`); taken/put around
    /// feature-map calls for the same reason.
    prep: Vec<f32>,
    /// Second prepped-row buffer — `pair_weight` preps q and k at once.
    prep2: Vec<f32>,
}

impl Scratch {
    /// Arena pre-sized for an engine with `feature_dim` features, value
    /// width `dv`, and input width `d` — no allocation after this.
    pub fn sized(feature_dim: usize, dv: usize, d: usize) -> Scratch {
        Scratch {
            phi: vec![0.0; feature_dim],
            dphi: vec![0.0; feature_dim],
            v64: vec![0.0; dv],
            num: vec![0.0; dv],
            prep: Vec::with_capacity(d),
            prep2: Vec::with_capacity(d),
        }
    }

    /// Move the prepped-row buffer out (cleared); return it with
    /// [`Scratch::put_prep`].  Moving keeps borrow scopes disjoint from
    /// the f64 buffers the callee borrows.
    pub fn take_prep(&mut self) -> Vec<f32> {
        let mut buf = std::mem::take(&mut self.prep);
        buf.clear();
        buf
    }

    pub fn put_prep(&mut self, buf: Vec<f32>) {
        self.prep = buf;
    }

    pub fn take_prep2(&mut self) -> Vec<f32> {
        let mut buf = std::mem::take(&mut self.prep2);
        buf.clear();
        buf
    }

    pub fn put_prep2(&mut self, buf: Vec<f32>) {
        self.prep2 = buf;
    }

    /// Move the numerator buffer out; return it with [`Scratch::put_num`].
    pub fn take_num(&mut self) -> Vec<f64> {
        std::mem::take(&mut self.num)
    }

    pub fn put_num(&mut self, buf: Vec<f64>) {
        self.num = buf;
    }
}

/// Resize `buf` to `n` reusing capacity; contents are unspecified (the
/// callers are assign-only, so no zero-fill is spent on reuse).
#[inline]
pub fn ensure_len(buf: &mut Vec<f64>, n: usize) {
    if buf.len() != n {
        buf.resize(n, 0.0);
    }
}

/// `out[i] = x[i] as f64` (exact widening), reusing `out`'s capacity.
#[inline]
pub fn widen(out: &mut Vec<f64>, x: &[f32]) {
    out.clear();
    out.extend(x.iter().map(|&v| v as f64));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_round_trips_capacity() {
        let mut s = Scratch::sized(8, 4, 16);
        let mut p = s.take_prep();
        let cap = p.capacity();
        assert!(cap >= 16);
        p.extend_from_slice(&[1.0; 16]);
        s.put_prep(p);
        let p = s.take_prep();
        assert!(p.is_empty() && p.capacity() == cap);
        s.put_prep(p);

        let mut n = s.take_num();
        assert_eq!(n.len(), 4);
        ensure_len(&mut n, 4);
        s.put_num(n);
    }

    #[test]
    fn widen_is_exact_and_reuses() {
        let mut out = Vec::with_capacity(4);
        widen(&mut out, &[1.5f32, -2.25, 0.1]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], 1.5);
        assert_eq!(out[1], -2.25);
        assert_eq!(out[2], 0.1f32 as f64);
    }
}
