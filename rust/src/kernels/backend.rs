//! `NativeBackend` — pure-rust attention evaluation behind the same
//! `(kind, bh, n, d)` surface as `mathref::attention_bhnd` and the AOT
//! attention artifacts.  This is the no-PJRT, no-Python execution path:
//! examples, benches and the CLI cross-checks run against it end to end.

use anyhow::{bail, Result};

use crate::kernels::{
    chunked_forward, simd, streaming_forward, AttentionGrad, HoState, LinearState,
    RecurrentAttention,
};
use crate::mathref;

/// How to evaluate the recurrence over a full sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Evaluation {
    /// Token-by-token `step` — the decode recurrence.
    Streaming,
    /// Blocked: direct O(c²) inside chunks, recurrent across them.
    Chunked,
}

/// Config + entry points for the native kernels.
///
/// `kind` strings match the manifest/`mathref` vocabulary: `"ho"` (the
/// Taylor kernel at any `order`, honoring `alpha`/`normalize_qk`; the
/// historic spelling `"ho2"` is an alias), `"linear"` (elu+1 baseline),
/// and `"softmax"` — which has no linear-time form and falls back to the
/// exact O(n²) reference so callers can still use one backend for every
/// baseline in a comparison table.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    /// Taylor order for the `"ho"`/`"ho2"` kind — any r ≥ 0 whose packed
    /// feature dim fits [`crate::kernels::MAX_TAYLOR_FEATURES`].
    pub order: usize,
    /// Logit damping α for the `"ho"`/`"ho2"` kind.
    pub alpha: f64,
    /// Per-row LayerNorm on q/k for the `"ho"`/`"ho2"` kind.
    pub normalize_qk: bool,
    /// Chunk length for [`Evaluation::Chunked`].
    pub chunk: usize,
    pub evaluation: Evaluation,
    /// Pin the lane dispatch of every state this backend constructs
    /// (`None` = the runtime-detected [`simd::active`] default).  Benches
    /// use `Some(Isa::Scalar)` to measure the reference path; tests use
    /// it to pin bit-exact comparisons.
    pub isa: Option<simd::Isa>,
}

impl Default for NativeBackend {
    /// The paper's settings: order 2, α = 3, LayerNorm on, chunked with
    /// 64-token blocks.
    fn default() -> NativeBackend {
        NativeBackend {
            order: 2,
            alpha: 3.0,
            normalize_qk: true,
            chunk: 64,
            evaluation: Evaluation::Chunked,
            isa: None,
        }
    }
}

impl NativeBackend {
    pub fn paper() -> NativeBackend {
        NativeBackend::default()
    }

    /// Fresh recurrent state for one head — the O(1)-per-token decode
    /// object. Errors for `"softmax"`, which has no recurrent form.
    /// `Send` so per-slot decode sessions can move across pool threads.
    pub fn state(
        &self,
        kind: &str,
        d: usize,
        dv: usize,
    ) -> Result<Box<dyn RecurrentAttention + Send>> {
        match kind {
            "ho2" | "ho" => {
                let mut st = HoState::new(d, dv, self.order, self.alpha, self.normalize_qk);
                if let Some(isa) = self.isa {
                    st.set_isa(isa);
                }
                Ok(Box::new(st))
            }
            "linear" => {
                let mut st = LinearState::new(d, dv);
                if let Some(isa) = self.isa {
                    st.set_isa(isa);
                }
                Ok(Box::new(st))
            }
            "softmax" => bail!("softmax attention has no O(1) recurrent state"),
            _ => bail!("unknown attention kind '{kind}' (want ho | ho2 | linear | softmax)"),
        }
    }

    /// Like [`Self::state`], but with the backward hooks
    /// ([`AttentionGrad`]) — the training path's kernel constructor.
    /// `"softmax"` errors here too: its backward is the direct
    /// [`crate::kernels::softmax_attention_vjp`], no state involved.
    pub fn grad_state(
        &self,
        kind: &str,
        d: usize,
        dv: usize,
    ) -> Result<Box<dyn AttentionGrad + Send>> {
        match kind {
            "ho2" | "ho" => {
                let mut st = HoState::new(d, dv, self.order, self.alpha, self.normalize_qk);
                if let Some(isa) = self.isa {
                    st.set_isa(isa);
                }
                Ok(Box::new(st))
            }
            "linear" => {
                let mut st = LinearState::new(d, dv);
                if let Some(isa) = self.isa {
                    st.set_isa(isa);
                }
                Ok(Box::new(st))
            }
            "softmax" => bail!(
                "softmax attention has no recurrent state; its backward is \
                 kernels::softmax_attention_vjp"
            ),
            _ => bail!("unknown attention kind '{kind}' (want ho | ho2 | linear | softmax)"),
        }
    }

    /// Single-head forward: q/k are (n, d), v is (n, dv).
    #[allow(clippy::too_many_arguments)]
    pub fn forward(
        &self,
        kind: &str,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        d: usize,
        dv: usize,
        causal: bool,
    ) -> Result<Vec<f32>> {
        if kind == "softmax" {
            // no linear-time form — exact quadratic reference
            return Ok(mathref::softmax_attention(q, k, v, n, n, d, dv, causal));
        }
        let mut state = self.state(kind, d, dv)?;
        Ok(match self.evaluation {
            Evaluation::Streaming => streaming_forward(state.as_mut(), q, k, v, n, causal),
            Evaluation::Chunked => chunked_forward(state.as_mut(), q, k, v, n, self.chunk, causal),
        })
    }

    /// Batched multi-head forward over (b·h, n, d) flat buffers — the
    /// same layout `mathref::attention_bhnd` and the AOT artifacts use.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_bhnd(
        &self,
        kind: &str,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        bh: usize,
        n: usize,
        d: usize,
        causal: bool,
    ) -> Result<Vec<f32>> {
        let stride = n * d;
        assert_eq!(q.len(), bh * stride, "q shape");
        assert_eq!(k.len(), bh * stride, "k shape");
        assert_eq!(v.len(), bh * stride, "v shape");
        let mut out = vec![0.0f32; bh * stride];
        for s in 0..bh {
            let o = self.forward(
                kind,
                &q[s * stride..(s + 1) * stride],
                &k[s * stride..(s + 1) * stride],
                &v[s * stride..(s + 1) * stride],
                n,
                d,
                d,
                causal,
            )?;
            out[s * stride..(s + 1) * stride].copy_from_slice(&o);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn bhnd_matches_mathref_for_all_kinds() {
        let mut rng = Rng::new(31);
        let (bh, n, d) = (3, 16, 8);
        let q = rng.normal_vec_f32(bh * n * d, 1.0);
        let k = rng.normal_vec_f32(bh * n * d, 1.0);
        let v = rng.normal_vec_f32(bh * n * d, 1.0);
        let be = NativeBackend::paper();
        for kind in ["softmax", "linear", "ho2"] {
            let got = be.attention_bhnd(kind, &q, &k, &v, bh, n, d, true).unwrap();
            let want = mathref::attention_bhnd(kind, &q, &k, &v, bh, n, d, 2, 3.0, true);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{kind}");
            }
        }
    }

    #[test]
    fn streaming_and_chunked_evaluations_agree() {
        let mut rng = Rng::new(32);
        let (n, d) = (33, 8);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * d, 1.0);
        let mut be = NativeBackend::paper();
        be.evaluation = Evaluation::Streaming;
        let a = be.forward("ho2", &q, &k, &v, n, d, d, true).unwrap();
        be.evaluation = Evaluation::Chunked;
        be.chunk = 5;
        let b = be.forward("ho2", &q, &k, &v, n, d, d, true).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_has_no_state() {
        assert!(NativeBackend::paper().state("softmax", 4, 4).is_err());
        assert!(NativeBackend::paper().state("nope", 4, 4).is_err());
    }

    #[test]
    fn ho_kind_at_order_three_matches_oracle() {
        // "ho" is the canonical kind now, order is a config value — the
        // order-3 data point the paper never ran needs no new kernel code
        let mut rng = Rng::new(33);
        let (bh, n, d) = (2, 20, 8);
        let q = rng.normal_vec_f32(bh * n * d, 1.0);
        let k = rng.normal_vec_f32(bh * n * d, 1.0);
        let v = rng.normal_vec_f32(bh * n * d, 1.0);
        let be = NativeBackend { order: 3, ..NativeBackend::paper() };
        let got = be.attention_bhnd("ho", &q, &k, &v, bh, n, d, true).unwrap();
        let want = mathref::attention_bhnd("ho", &q, &k, &v, bh, n, d, 3, 3.0, true);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
        let st = be.state("ho", d, d).unwrap();
        let t2 = d * (d + 1) / 2;
        let t3 = d * (d + 1) * (d + 2) / 6;
        assert_eq!(st.state_elements(), (1 + d + t2 + t3) * (1 + d));
    }
}
