//! Hand-derived backward through the O(n) attention recurrence.
//!
//! Katharopoulos et al. 2020 observe that the gradient of causal linear
//! attention factorizes through the same prefix-sum states as the
//! forward; this module is that observation made concrete for **any**
//! [`crate::kernels::FeatureMap`] kernel (Taylor at any order, elu+1),
//! in the same cache-blocked shape as [`chunked_forward`]:
//!
//! * **inside a chunk** the O(c²) pairwise weights are differentiated
//!   directly — `w = f(uᵢ·κⱼ)` with `f' ` supplied by the kernel
//!   ([`AttentionGrad::pair_weight_dot_grad`]; for Taylor order r the
//!   derivative is the order r−1 series, `Tᵣ'(s) = Tᵣ₋₁(s)`),
//! * **across chunks** a single *state gradient* vector (the loss
//!   gradient w.r.t. every moment in the kernel state, in the
//!   [`RecurrentAttention::save_state`] layout) is carried backward.
//!   Absorbing is additive, so the state gradient passes through
//!   untouched and each chunk contributes its reads' gradients on the
//!   way back — the mirror image of the forward prefix sums.
//!
//! The reverse sweep needs the state each chunk's queries actually read
//! (the state *before* that chunk was absorbed), plus the raw
//! denominators and f64 numerators of every position.  The **capture**
//! phase ([`chunked_forward_captured`]) records all of it — snapshots at
//! every chunk boundary, dens/nums, and the prepped q/k rows — into a
//! [`CapturedChunks`] *while producing the normal attention output*, so
//! the model's training forward doubles as the backward's tape and a
//! train step runs exactly **one** attention forward.  The **reverse**
//! phase ([`chunked_attention_vjp_reverse`]) consumes the capture:
//! nothing recomputed, nothing re-prepped on the way back.
//! [`chunked_attention_vjp`] remains as the self-contained
//! capture-then-reverse wrapper for callers with no forward to reuse
//! (FD checks, one-off Jacobians).
//!
//! Processing order per chunk (reversed) matters: the chunk's absorbs
//! feed only *later* reads, so [`AttentionGrad::absorb_vjp`] must run
//! against the state gradient **before** this chunk's own reads are
//! folded in via [`AttentionGrad::query_vjp`].
//!
//! Everything is checked against finite differences of the O(n²)
//! oracles in `rust/tests/grad_check.rs` (all kinds × orders 0–3,
//! several chunk sizes, rel. err ≤ 1e-3).

use crate::kernels::{den_is_clamped, floor_den, simd, RecurrentAttention};

/// A [`RecurrentAttention`] kernel that can run backward: the vector-
/// Jacobian products of its three primitive operations (state read,
/// absorb, per-row prep), plus the scalar derivative of the pair weight.
///
/// Gradients flow in f64 (they accumulate across whole sequences, like
/// the forward states); the *state gradient* buffers use exactly the
/// [`RecurrentAttention::save_state`] layout.  The single implementation
/// is the generic [`crate::kernels::PhiState`], which derives every
/// method from its [`crate::kernels::FeatureMap`] — per-kernel vjp
/// bodies no longer exist.
pub trait AttentionGrad: RecurrentAttention {
    /// The pair weight as a function of the prepped-row dot product
    /// (every kernel here is one): `w = f(qp·kp)`.
    fn pair_weight_from_dot(&self, dot: f64) -> f64;

    /// `df/d(dot)` at the given dot product.
    fn pair_weight_dot_grad(&self, dot: f64) -> f64;

    /// VJP of [`RecurrentAttention::query_raw_prepped`] against the
    /// *current* state: given upstream gradients `dnum` (length `dv`)
    /// and `dden` for the raw read of prepped query `qp`, accumulate
    /// the gradient w.r.t. the state into `gstate` (save_state layout,
    /// length `state_elements`) and w.r.t. `qp` into `gqp`.
    fn query_vjp(&self, qp: &[f32], dnum: &[f64], dden: f64, gstate: &mut [f64], gqp: &mut [f64]);

    /// VJP of [`RecurrentAttention::absorb_prepped`]: given the loss
    /// gradient w.r.t. the state (absorbing is additive, so this is the
    /// same before and after the absorb), accumulate the gradient
    /// w.r.t. the prepped key row into `gkp` and w.r.t. the value row
    /// into `gv`.  Independent of the current state values.
    fn absorb_vjp(&self, kp: &[f32], v: &[f32], gstate: &[f64], gkp: &mut [f64], gv: &mut [f64]);

    /// VJP of [`RecurrentAttention::prep_rows`]: `rows` are the raw
    /// q/k rows, `g` the gradient w.r.t. the prepped rows; returns the
    /// gradient w.r.t. `rows`.
    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64>;
}

/// The backward's tape: everything one causal chunked forward must hand
/// the reverse sweep so nothing is recomputed.  Produced by
/// [`chunked_forward_captured`], consumed by
/// [`chunked_attention_vjp_reverse`]; opaque to the model layer, which
/// just carries it from its forward to its backward.
///
/// Contents per sequence: raw (pre-floor) denominators (n f64), f64
/// numerators (n·dv), one `save_state` snapshot per chunk boundary
/// (n/c · S), and the prepped q/k rows (2·n·d f32) — the prepped rows
/// riding along is what lets the backward run **zero** `prep_rows`
/// calls.
pub struct CapturedChunks {
    n: usize,
    chunk: usize,
    /// raw per-position denominators (pre-floor: the subgradient of the
    /// [`crate::kernels::DEN_FLOOR`] clamp needs the unclamped value)
    dens: Vec<f64>,
    /// f64 per-position numerators, row-major (n, dv)
    nums: Vec<f64>,
    /// kernel state at each chunk boundary (save_state layout)
    snaps: Vec<Vec<f64>>,
    /// prepped (q, k) rows per chunk, exactly as the forward used them
    preps: Vec<(Vec<f32>, Vec<f32>)>,
}

/// [`chunked_forward`] (causal) that additionally records the backward's
/// tape: returns the normal attention output **and** a
/// [`CapturedChunks`] for [`chunked_attention_vjp_reverse`].
///
/// Arithmetic is identical to [`chunked_forward`] — same prep, same
/// per-pair `pair_weight_from_dot(dot)` weights, same accumulation
/// order, same [`floor_den`] at the output — so the captured output is
/// bit-identical to the serving forward and the capture is free of any
/// second pass.  Counts as one attention forward
/// ([`crate::kernels::counters`]).
///
/// [`chunked_forward`]: crate::kernels::chunked_forward
pub fn chunked_forward_captured<K: AttentionGrad + ?Sized>(
    kernel: &mut K,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    chunk: usize,
) -> (Vec<f32>, CapturedChunks) {
    let (d, dv) = (kernel.d(), kernel.dv());
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    let chunk = chunk.max(1);
    let n_chunks = n.div_ceil(chunk);
    let isa = kernel.isa();
    crate::kernels::counters::count_attn_forward();

    kernel.reset();
    let mut out = vec![0.0f32; n * dv];
    let mut dens = vec![0.0f64; n];
    let mut nums = vec![0.0f64; n * dv];
    let mut snaps: Vec<Vec<f64>> = Vec::with_capacity(n_chunks);
    let mut preps: Vec<(Vec<f32>, Vec<f32>)> = Vec::with_capacity(n_chunks);
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + chunk).min(n);
        let qp = kernel.prep_rows(&q[c0 * d..c1 * d], c1 - c0);
        let kp = kernel.prep_rows(&k[c0 * d..c1 * d], c1 - c0);
        let mut snap = Vec::new();
        kernel.save_state(&mut snap);
        snaps.push(snap);
        for i in c0..c1 {
            let qi = &qp[(i - c0) * d..(i - c0 + 1) * d];
            let num = &mut nums[i * dv..(i + 1) * dv];
            let mut den = kernel.query_raw_prepped(qi, num);
            for j in c0..=i {
                let kj = &kp[(j - c0) * d..(j - c0 + 1) * d];
                let dot = simd::dot_ps(isa, qi, kj);
                let w = kernel.pair_weight_from_dot(dot);
                den += w;
                simd::axpy_ps(isa, num, &v[j * dv..(j + 1) * dv], w);
            }
            dens[i] = den;
            let fden = floor_den(den);
            for (o, &x) in out[i * dv..(i + 1) * dv].iter_mut().zip(num.iter()) {
                *o = (x / fden) as f32;
            }
        }
        for j in c0..c1 {
            kernel.absorb_prepped(&kp[(j - c0) * d..(j - c0 + 1) * d], &v[j * dv..(j + 1) * dv]);
        }
        preps.push((qp, kp));
        c0 = c1;
    }
    (out, CapturedChunks { n, chunk, dens, nums, snaps, preps })
}

/// Reverse phase: consume a [`CapturedChunks`] tape and `go = dL/d out`,
/// return `(gq, gk, gv)`.  Runs the chunk sweep described in the module
/// docs — absorbs first against the carried state gradient, then reads
/// against the restored boundary snapshot — entirely from the tape:
/// no forward replay, no `prep_rows` calls (only the row-wise
/// [`AttentionGrad::prep_rows_vjp`] at the end, which is the prep's
/// *backward* and irreducible).  O(n·c·d·dv + (n/c)·S) time, linear in
/// `n` like the forward.
pub fn chunked_attention_vjp_reverse<K: AttentionGrad + ?Sized>(
    kernel: &mut K,
    cap: &CapturedChunks,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    go: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (d, dv) = (kernel.d(), kernel.dv());
    let (n, chunk) = (cap.n, cap.chunk);
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    assert_eq!(go.len(), n * dv, "go shape");
    let n_chunks = n.div_ceil(chunk);
    let isa = kernel.isa();
    let CapturedChunks { dens, nums, snaps, preps, .. } = cap;

    let mut gqp = vec![0.0f64; n * d];
    let mut gkp = vec![0.0f64; n * d];
    let mut gv = vec![0.0f64; n * dv];
    let mut gstate = vec![0.0f64; kernel.state_elements()];
    // per-position upstream-numerator gradient, hoisted (assign-only)
    let mut dnum = vec![0.0f64; dv];
    for ci in (0..n_chunks).rev() {
        let c0 = ci * chunk;
        let c1 = (c0 + chunk).min(n);
        let (qp, kp) = &preps[ci];
        // 1. this chunk's absorbs feed every later read: gstate is
        //    currently dL/d(state after this chunk) — use it first
        for j in c0..c1 {
            kernel.absorb_vjp(
                &kp[(j - c0) * d..(j - c0 + 1) * d],
                &v[j * dv..(j + 1) * dv],
                &gstate,
                &mut gkp[j * d..(j + 1) * d],
                &mut gv[j * dv..(j + 1) * dv],
            );
        }
        // 2. this chunk's reads saw the state *before* the absorbs
        kernel.load_state(&snaps[ci]);
        for i in c0..c1 {
            let qi = &qp[(i - c0) * d..(i - c0 + 1) * d];
            let den = floor_den(dens[i]);
            let num = &nums[i * dv..(i + 1) * dv];
            let g = &go[i * dv..(i + 1) * dv];
            // o = num/den: dnum = g/den, dden = −(g·o)/den (0 if clamped)
            let mut gdoto = 0.0f64;
            for ((dn, &gc), &nc) in dnum.iter_mut().zip(g).zip(num) {
                *dn = gc as f64 / den;
                gdoto += gc as f64 * (nc / den);
            }
            let dden = if den_is_clamped(dens[i]) { 0.0 } else { -gdoto / den };
            kernel.query_vjp(qi, &dnum, dden, &mut gstate, &mut gqp[i * d..(i + 1) * d]);
            // intra-chunk triangle, differentiated directly
            for j in c0..=i {
                let kj = &kp[(j - c0) * d..(j - c0 + 1) * d];
                let dot = simd::dot_ps(isa, qi, kj);
                let w = kernel.pair_weight_from_dot(dot);
                let mut a_ij = dden;
                for (dn, &x) in dnum.iter().zip(&v[j * dv..(j + 1) * dv]) {
                    a_ij += dn * x as f64;
                }
                simd::axpy(isa, &mut gv[j * dv..(j + 1) * dv], &dnum, w);
                let s = kernel.pair_weight_dot_grad(dot) * a_ij;
                for ((gq, &kc), (gk, &qc)) in gqp[i * d..(i + 1) * d]
                    .iter_mut()
                    .zip(kj)
                    .zip(gkp[j * d..(j + 1) * d].iter_mut().zip(qi))
                {
                    *gq += s * kc as f64;
                    *gk += s * qc as f64;
                }
            }
        }
    }

    // ---- prep backward on whole arrays (row-wise) ----
    let gq = kernel.prep_rows_vjp(q, n, &gqp);
    let gk = kernel.prep_rows_vjp(k, n, &gkp);
    (to_f32(&gq), to_f32(&gk), to_f32(&gv))
}

/// Backward of [`chunked_forward`] (causal): given `go = dL/d out`,
/// returns `(gq, gk, gv)`.  Self-contained capture-then-reverse wrapper
/// — it runs [`chunked_forward_captured`] (one attention forward) and
/// feeds the tape straight to [`chunked_attention_vjp_reverse`].  The
/// training path doesn't use it: `model/grad.rs` captures during its
/// own forward and calls the reverse directly, paying for attention
/// once per step.  This stays as the entry point for FD checks and any
/// caller without a forward to reuse.
///
/// [`chunked_forward`]: crate::kernels::chunked_forward
#[allow(clippy::too_many_arguments)]
pub fn chunked_attention_vjp<K: AttentionGrad + ?Sized>(
    kernel: &mut K,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    chunk: usize,
    go: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (_out, cap) = chunked_forward_captured(kernel, q, k, v, n, chunk);
    chunked_attention_vjp_reverse(kernel, &cap, q, k, v, go)
}

/// Backward of the exact softmax attention baseline
/// ([`crate::mathref::softmax_attention`], causal): standard softmax
/// VJP, direct O(n²) — the baseline has no linear-time form in either
/// direction, which is the comparison the paper is making.
#[allow(clippy::too_many_arguments)]
pub fn softmax_attention_vjp(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    dv: usize,
    causal: bool,
    go: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    assert_eq!(go.len(), n * dv, "go shape");
    let scale = 1.0 / (d as f64).sqrt();
    let mut gq = vec![0.0f64; n * d];
    let mut gk = vec![0.0f64; n * d];
    let mut gv = vec![0.0f64; n * dv];
    let mut w = vec![0.0f64; n];
    let mut dw = vec![0.0f64; n];
    for i in 0..n {
        let limit = if causal { i + 1 } else { n };
        let qi = &q[i * d..(i + 1) * d];
        // recompute row i's softmax weights in f64
        let mut maxv = f64::NEG_INFINITY;
        for j in 0..limit {
            let dot = dot_f64(qi, &k[j * d..(j + 1) * d]);
            w[j] = dot * scale;
            maxv = maxv.max(w[j]);
        }
        let mut den = 0.0f64;
        for wj in w.iter_mut().take(limit) {
            *wj = (*wj - maxv).exp();
            den += *wj;
        }
        for wj in w.iter_mut().take(limit) {
            *wj /= den;
        }
        // dL/dw_ij = go_i · v_j, then softmax jacobian
        let g = &go[i * dv..(i + 1) * dv];
        let mut wdw = 0.0f64;
        for j in 0..limit {
            let mut acc = 0.0f64;
            for (&gc, &vc) in g.iter().zip(&v[j * dv..(j + 1) * dv]) {
                acc += gc as f64 * vc as f64;
            }
            dw[j] = acc;
            wdw += w[j] * acc;
            for (gvc, &gc) in gv[j * dv..(j + 1) * dv].iter_mut().zip(g) {
                *gvc += w[j] * gc as f64;
            }
        }
        for j in 0..limit {
            let ds = w[j] * (dw[j] - wdw) * scale;
            for ((gqc, &kc), (gkc, &qc)) in gq[i * d..(i + 1) * d]
                .iter_mut()
                .zip(&k[j * d..(j + 1) * d])
                .zip(gk[j * d..(j + 1) * d].iter_mut().zip(qi))
            {
                *gqc += ds * kc as f64;
                *gkc += ds * qc as f64;
            }
        }
    }
    (to_f32(&gq), to_f32(&gk), to_f32(&gv))
}

fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as f64 * y as f64;
    }
    acc
}

fn to_f32(xs: &[f64]) -> Vec<f32> {
    xs.iter().map(|&x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{chunked_forward, HoState, LinearState};
    use crate::rng::Rng;

    /// The vjp's internal forward replay must agree with chunked_forward
    /// (same arithmetic); cheap sanity before the FD suite in
    /// rust/tests/grad_check.rs does the heavy lifting.
    #[test]
    fn vjp_is_chunk_size_invariant() {
        let mut rng = Rng::new(91);
        let (n, d, dv) = (17, 4, 3);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let go = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = HoState::paper(d, dv);
        let (gq1, gk1, gv1) = chunked_attention_vjp(&mut st, &q, &k, &v, n, 1, &go);
        for chunk in [2, 5, 17, 64] {
            let (gq, gk, gv) = chunked_attention_vjp(&mut st, &q, &k, &v, n, chunk, &go);
            for (a, b) in gq.iter().zip(&gq1).chain(gk.iter().zip(&gk1)).chain(gv.iter().zip(&gv1))
            {
                assert!((a - b).abs() < 1e-4, "chunk {chunk}: {a} vs {b}");
            }
        }
    }

    /// The capture phase must be the serving forward, not an
    /// approximation of it: outputs bit-identical to [`chunked_forward`]
    /// for the same kernel/chunking (the full order × chunk sweep lives
    /// in rust/tests/grad_check.rs).
    #[test]
    fn captured_forward_matches_chunked_forward_bitwise() {
        let mut rng = Rng::new(94);
        let (n, d, dv) = (19, 4, 3);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = HoState::paper(d, dv);
        for chunk in [1, 4, 64] {
            let want = chunked_forward(&mut st, &q, &k, &v, n, chunk, true);
            let (got, cap) = chunked_forward_captured(&mut st, &q, &k, &v, n, chunk);
            assert_eq!(got, want, "chunk {chunk}");
            assert_eq!(cap.dens.len(), n);
            assert_eq!(cap.snaps.len(), n.div_ceil(chunk));
        }
    }

    /// The wrapper (capture + reverse) is the old replay path: same
    /// gradients as driving the two phases by hand.
    #[test]
    fn wrapper_equals_explicit_capture_then_reverse() {
        let mut rng = Rng::new(95);
        let (n, d, dv) = (13, 4, 3);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let go = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = HoState::paper(d, dv);
        let (_out, cap) = chunked_forward_captured(&mut st, &q, &k, &v, n, 4);
        let by_hand = chunked_attention_vjp_reverse(&mut st, &cap, &q, &k, &v, &go);
        let wrapped = chunked_attention_vjp(&mut st, &q, &k, &v, n, 4, &go);
        assert_eq!(by_hand, wrapped);
    }

    #[test]
    fn linear_kernel_vjp_runs_and_is_finite() {
        let mut rng = Rng::new(92);
        let (n, d, dv) = (9, 4, 4);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let go = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = LinearState::new(d, dv);
        let (gq, gk, gv) = chunked_attention_vjp(&mut st, &q, &k, &v, n, 3, &go);
        assert!(gq.iter().chain(&gk).chain(&gv).all(|x| x.is_finite()));
        // the forward state must be unharmed as an invariant: a fresh
        // forward still matches the oracle
        let out = chunked_forward(&mut st, &q, &k, &v, n, 3, true);
        let want = crate::mathref::linear_attention(&q, &k, &v, n, n, d, dv, true);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn den_floor_subgradient_is_pinned() {
        // elu+1 features of strongly negative rows are ~e^x tiny, so a
        // single-token sequence lands below DEN_FLOOR: the forward must
        // divide by the constant floor and the backward must take the
        // subgradient dden = 0 — i.e. the only gq/gk signal left is the
        // numerator path w'·(v·go)/DEN_FLOOR, which has a closed form
        // for n = 1 that we can check to near-f64 precision.
        use crate::kernels::{den_is_clamped, DEN_FLOOR};
        use crate::mathref::elu1;
        let (d, dv) = (3, 2);
        let q = vec![-16.0f32, -17.0, -18.0];
        let k = vec![-18.5f32, -16.5, -17.5];
        let v = vec![0.7f32, -0.3];
        let go = vec![1.1f32, 0.4];
        let mut st = LinearState::new(d, dv);
        let w = st.pair_weight(&q, &k);
        assert!(den_is_clamped(w), "test setup: w = {w} must sit below the floor");
        // forward: out = w·v / DEN_FLOOR (the clamp, not the raw den)
        let out = chunked_forward(&mut st, &q, &k, &v, 1, 4, true);
        for (o, &vc) in out.iter().zip(&v) {
            let want = (w * vc as f64 / DEN_FLOOR) as f32;
            assert!((o - want).abs() <= want.abs() * 1e-6, "fwd {o} vs {want}");
        }
        // backward: with dden = 0, gq_a = (Σ_c go_c·v_c / FLOOR)·φ(k_a)·φ'(q_a)
        // (and symmetrically for gk) — any dden leakage would add the
        // enormous −(go·out)/FLOOR term and miss by orders of magnitude
        let (gq, gk, gv) = chunked_attention_vjp(&mut st, &q, &k, &v, 1, 4, &go);
        let a = go
            .iter()
            .zip(&v)
            .map(|(&g, &x)| g as f64 * x as f64)
            .sum::<f64>()
            / DEN_FLOOR;
        for c in 0..d {
            let wq = a * elu1(k[c]) as f64 * (q[c] as f64).exp();
            let wk = a * elu1(q[c]) as f64 * (k[c] as f64).exp();
            assert!((gq[c] as f64 - wq).abs() <= wq.abs() * 1e-5, "gq[{c}] {} vs {wq}", gq[c]);
            assert!((gk[c] as f64 - wk).abs() <= wk.abs() * 1e-5, "gk[{c}] {} vs {wk}", gk[c]);
        }
        // gv = w·go/FLOOR
        for c in 0..dv {
            let want = (w * go[c] as f64 / DEN_FLOOR) as f32;
            assert!((gv[c] - want).abs() <= want.abs() * 1e-5, "gv[{c}]");
        }
    }

    #[test]
    fn softmax_vjp_rows_sum_consistency() {
        // constant v ⇒ out is constant ⇒ gq = gk = 0 exactly (softmax
        // rows are convex combinations), gv gets the full weight mass
        let mut rng = Rng::new(93);
        let (n, d, dv) = (8, 5, 3);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = vec![2.0f32; n * dv];
        let go = rng.normal_vec_f32(n * dv, 1.0);
        let (gq, gk, gv) = softmax_attention_vjp(&q, &k, &v, n, d, dv, true, &go);
        for x in gq.iter().chain(&gk) {
            assert!(x.abs() < 1e-5, "{x}");
        }
        // per value column, the gv mass over keys equals the go mass
        // over queries (weights are row-stochastic)
        for c in 0..dv {
            let gv_sum: f32 = (0..n).map(|j| gv[j * dv + c]).sum();
            let go_sum: f32 = (0..n).map(|i| go[i * dv + c]).sum();
            assert!((gv_sum - go_sum).abs() < 1e-4, "col {c}");
        }
    }
}
