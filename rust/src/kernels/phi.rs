//! `PhiState` — the one φ-outer-product recurrence every kernel runs on.
//!
//! For any [`FeatureMap`] φ the kernelized attention weights factorize:
//!
//! ```text
//! Σⱼ w(q, kⱼ)·vⱼ = φ_q(q) · Σⱼ φ_k(kⱼ)⊗vⱼ      (numerator)
//! Σⱼ w(q, kⱼ)    = φ_q(q) · Σⱼ φ_k(kⱼ)         (denominator)
//! ```
//!
//! so the whole history lives in the moment state `(Σφ_k(k), Σφ_k(k)⊗v)`
//! — `feature_dim · (1 + dv)` f64s, constant in sequence length.  This
//! type implements [`RecurrentAttention`] (absorb / query / snapshot)
//! and [`AttentionGrad`] (the state-gradient VJPs) **once**; the historic
//! `HoState` / `LinearState` are now just type aliases instantiating it
//! with [`TaylorMap`] / `EluMap` (see `kernels/ho.rs`, `kernels/linear.rs`).
//!
//! For [`TaylorMap`] at order ≤ 2 the feature layout reproduces the
//! pre-`FeatureMap` `s0/s1/s2` packed layout entry for entry, and every
//! accumulator here runs the same f64 additions in the same order as the
//! deleted hand-specialized bodies — order ≤ 2 outputs are bit-identical
//! (pinned against a verbatim copy of the old kernels in
//! `rust/tests/golden_order2.rs`).
//!
//! All state is f64 — running sums live across entire sequences, where
//! f32 cancellation would show up long before the 1e-4 oracle tolerance.

use std::cell::RefCell;

use crate::kernels::{AttentionGrad, FeatureMap, RecurrentAttention, TaylorMap};

/// Recurrent kernelized-attention state over one head for feature map `M`.
pub struct PhiState<M: FeatureMap> {
    map: M,
    dv: usize,
    /// Σ φ_k(k) — (F).
    z: Vec<f64>,
    /// Σ φ_k(k)⊗v — (F, dv) row-major.
    m: Vec<f64>,
    /// Reused feature buffer for absorb/query — the decode hot path runs
    /// both once per token per (layer, head) and must not allocate a
    /// feature_dim-sized Vec each time.  `RefCell` because `query_raw`
    /// takes `&self`; states are owned per decode slot / per attention
    /// unit and never shared across threads (`Send`, not `Sync`).
    phi_scratch: RefCell<Vec<f64>>,
}

impl<M: FeatureMap> PhiState<M> {
    /// Empty state for `map` with value dimension `dv`.
    pub fn with_map(map: M, dv: usize) -> PhiState<M> {
        assert!(dv > 0, "empty value dim");
        let f = map.feature_dim();
        PhiState {
            map,
            dv,
            z: vec![0.0; f],
            m: vec![0.0; f * dv],
            phi_scratch: RefCell::new(vec![0.0; f]),
        }
    }

    /// The feature map driving this state.
    pub fn feature_map(&self) -> &M {
        &self.map
    }

    /// Features of the state (= per-degree packed moments for Taylor).
    pub fn feature_dim(&self) -> usize {
        self.z.len()
    }
}

impl PhiState<TaylorMap> {
    /// Taylor order of the underlying [`TaylorMap`].
    pub fn order(&self) -> usize {
        self.feature_map().order()
    }
}

impl<M: FeatureMap> RecurrentAttention for PhiState<M> {
    fn d(&self) -> usize {
        self.map.d()
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn reset(&mut self) {
        self.z.fill(0.0);
        self.m.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        let kp = self.map.prep_rows(k, 1);
        self.absorb_prepped(&kp, v);
    }

    /// Absorb a key row that already went through [`Self::prep_rows`] —
    /// the blocked path pays the per-row prep once instead of twice.
    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        let dv = self.dv;
        assert_eq!(kp.len(), self.map.d(), "k row");
        assert_eq!(v.len(), dv, "v row");
        let mut phi = self.phi_scratch.borrow_mut();
        self.map.map_k(kp, &mut phi);
        for (a, &p) in phi.iter().enumerate() {
            self.z[a] += p;
            let row = &mut self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in row.iter_mut().zip(v) {
                *acc += p * x as f64;
            }
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        let qp = self.map.prep_rows(q, 1);
        self.query_raw_prepped(&qp, num)
    }

    fn query_raw_prepped(&self, qp: &[f32], num: &mut [f64]) -> f64 {
        let dv = self.dv;
        assert_eq!(qp.len(), self.map.d(), "q row");
        assert_eq!(num.len(), dv, "num row");
        let mut phi = self.phi_scratch.borrow_mut();
        self.map.map_q(qp, &mut phi);
        num.fill(0.0);
        let mut den = 0.0f64;
        for (a, &p) in phi.iter().enumerate() {
            den += p * self.z[a];
            let row = &self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in num.iter_mut().zip(row) {
                *acc += p * x;
            }
        }
        den
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        self.pair_weight_prepped(&self.map.prep_rows(q, 1), &self.map.prep_rows(k, 1))
    }

    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        self.map.prep_rows(rows, n)
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        let mut dot = 0.0f64;
        for (&a, &b) in q.iter().zip(k) {
            dot += a as f64 * b as f64;
        }
        self.map.pair_weight_from_dot(dot)
    }

    fn state_elements(&self) -> usize {
        self.z.len() + self.m.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.reserve(self.state_elements());
        out.extend_from_slice(&self.z);
        out.extend_from_slice(&self.m);
    }

    fn load_state(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.state_elements(), "PhiState snapshot size");
        let (z, m) = data.split_at(self.z.len());
        self.z.copy_from_slice(z);
        self.m.copy_from_slice(m);
    }
}

impl<M: FeatureMap> AttentionGrad for PhiState<M> {
    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        self.map.pair_weight_from_dot(dot)
    }

    fn pair_weight_dot_grad(&self, dot: f64) -> f64 {
        self.map.pair_weight_dot_grad(dot)
    }

    fn query_vjp(&self, qp: &[f32], dnum: &[f64], dden: f64, gstate: &mut [f64], gqp: &mut [f64]) {
        let (f, dv) = (self.z.len(), self.dv);
        assert_eq!(qp.len(), self.map.d(), "q row");
        assert_eq!(dnum.len(), dv, "dnum row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let mut phi = vec![0.0f64; f];
        self.map.map_q(qp, &mut phi);
        // gstate layout == save_state: [z (F), m (F·dv)]
        let mut dphi = vec![0.0f64; f];
        for (a, &p) in phi.iter().enumerate() {
            gstate[a] += dden * p;
            let mut acc = dden * self.z[a];
            let srow = &self.m[a * dv..(a + 1) * dv];
            let grow = &mut gstate[f + a * dv..f + (a + 1) * dv];
            for ((g, &x), &s) in grow.iter_mut().zip(dnum).zip(srow) {
                *g += p * x;
                acc += x * s;
            }
            dphi[a] = acc;
        }
        self.map.map_q_vjp(qp, &dphi, gqp);
    }

    fn absorb_vjp(&self, kp: &[f32], v: &[f32], gstate: &[f64], gkp: &mut [f64], gv: &mut [f64]) {
        let (f, dv) = (self.z.len(), self.dv);
        assert_eq!(kp.len(), self.map.d(), "k row");
        assert_eq!(v.len(), dv, "v row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let mut phi = vec![0.0f64; f];
        self.map.map_k(kp, &mut phi);
        let mut dphi = vec![0.0f64; f];
        for (a, &p) in phi.iter().enumerate() {
            let grow = &gstate[f + a * dv..f + (a + 1) * dv];
            let mut acc = gstate[a];
            for ((gvc, &gs), &vc) in gv.iter_mut().zip(grow).zip(v) {
                *gvc += p * gs;
                acc += gs * vc as f64;
            }
            dphi[a] = acc;
        }
        self.map.map_k_vjp(kp, &dphi, gkp);
    }

    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64> {
        self.map.prep_rows_vjp(rows, n, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{streaming_forward, EluMap};
    use crate::rng::Rng;

    #[test]
    fn state_count_is_feature_dim_times_one_plus_dv() {
        let (d, dv) = (6, 5);
        for order in 0..=3 {
            let st = PhiState::with_map(TaylorMap::new(d, order, 3.0, true), dv);
            let f = st.feature_dim();
            assert_eq!(st.state_elements(), f * (1 + dv), "order {order}");
        }
        let st = PhiState::with_map(EluMap::new(d), dv);
        assert_eq!(st.state_elements(), d * (1 + dv));
    }

    #[test]
    fn save_load_roundtrip_any_map() {
        let mut rng = Rng::new(81);
        let (d, dv) = (5, 4);
        let mut a = PhiState::with_map(TaylorMap::new(d, 3, 2.0, true), dv);
        for _ in 0..6 {
            a.absorb(&rng.normal_vec_f32(d, 1.0), &rng.normal_vec_f32(dv, 1.0));
        }
        let mut snap = Vec::new();
        a.save_state(&mut snap);
        let mut b = PhiState::with_map(TaylorMap::new(d, 3, 2.0, true), dv);
        b.load_state(&snap);
        let q = rng.normal_vec_f32(d, 1.0);
        let mut na = vec![0.0f64; dv];
        let mut nb = vec![0.0f64; dv];
        assert_eq!(a.query_raw(&q, &mut na), b.query_raw(&q, &mut nb));
        assert_eq!(na, nb);
    }

    #[test]
    fn order3_recurrence_matches_oracle() {
        // the genuinely new data point: order-3 streaming ≡ the direct
        // O(n²) Taylor-3 oracle
        let mut rng = Rng::new(82);
        let (n, d, dv) = (14, 6, 5);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        for causal in [true, false] {
            let oracle =
                crate::mathref::ho_attention(&q, &k, &v, n, n, d, dv, 3, 3.0, causal, true);
            let mut st = PhiState::with_map(TaylorMap::new(d, 3, 3.0, true), dv);
            let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
            for (a, b) in got.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-5, "causal {causal}");
            }
        }
    }

    #[test]
    fn constant_v_is_reproduced_at_order3() {
        // row-normalized weights reproduce a constant v exactly, at any
        // order — the denominator really is the summed weights
        let mut rng = Rng::new(83);
        let (d, dv) = (8, 8);
        let mut st = PhiState::with_map(TaylorMap::new(d, 3, 3.0, true), dv);
        let constant_v = vec![1.5f32; dv];
        let mut out = vec![0.0f32; dv];
        for _ in 0..20 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            st.step(&q, &k, &constant_v, &mut out);
            for &x in &out {
                assert!((x - 1.5).abs() < 1e-5);
            }
        }
    }
}
