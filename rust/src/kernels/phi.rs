//! `PhiState` — the one φ-outer-product recurrence every kernel runs on.
//!
//! For any [`FeatureMap`] φ the kernelized attention weights factorize:
//!
//! ```text
//! Σⱼ w(q, kⱼ)·vⱼ = φ_q(q) · Σⱼ φ_k(kⱼ)⊗vⱼ      (numerator)
//! Σⱼ w(q, kⱼ)    = φ_q(q) · Σⱼ φ_k(kⱼ)         (denominator)
//! ```
//!
//! so the whole history lives in the moment state `(Σφ_k(k), Σφ_k(k)⊗v)`
//! — `feature_dim · (1 + dv)` f64s, constant in sequence length.  This
//! type implements [`RecurrentAttention`] (absorb / query / snapshot)
//! and [`AttentionGrad`] (the state-gradient VJPs) **once**; the historic
//! `HoState` / `LinearState` are now just type aliases instantiating it
//! with [`TaylorMap`] / `EluMap` (see `kernels/ho.rs`, `kernels/linear.rs`).
//!
//! For [`TaylorMap`] at order ≤ 2 the feature layout reproduces the
//! pre-`FeatureMap` `s0/s1/s2` packed layout entry for entry, and under
//! [`Isa::Scalar`] every accumulator runs the same f64 additions in the
//! same order as the deleted hand-specialized bodies — order ≤ 2 outputs
//! are bit-identical (pinned against a verbatim copy of the old kernels
//! in `rust/tests/golden_order2.rs`).  Off the scalar path the inner
//! loops are lane-tiled ([`simd`]): the absorb update stays bit-identical
//! (elementwise, no FMA), the query reductions reassociate within the
//! documented ≤ 1e-6 (pinned in `rust/tests/simd_hotpath.rs`).
//!
//! All transient buffers live in the per-engine [`Scratch`] arena — after
//! the first token, absorb / query / step / the vjps allocate nothing
//! (pinned by the counting-allocator test `rust/tests/alloc_decode.rs`).
//!
//! All state is f64 — running sums live across entire sequences, where
//! f32 cancellation would show up long before the 1e-4 oracle tolerance.

use std::cell::RefCell;

use crate::kernels::scratch::{self, Scratch};
use crate::kernels::simd::{self, Isa};
use crate::kernels::{AttentionGrad, FeatureMap, RecurrentAttention, TaylorMap};

/// Recurrent kernelized-attention state over one head for feature map `M`.
pub struct PhiState<M: FeatureMap> {
    map: M,
    dv: usize,
    /// Σ φ_k(k) — (F).
    z: Vec<f64>,
    /// Σ φ_k(k)⊗v — (F, dv) row-major.
    m: Vec<f64>,
    /// Which lane-tiled implementation the inner loops run.  Per-state
    /// (not global) so tests and benches can pin a path without racing
    /// other threads; defaults to [`simd::active`].
    isa: Isa,
    /// Transient-buffer arena — the decode hot path runs absorb + query
    /// once per token per (layer, head) and must not allocate.  `RefCell`
    /// because `query_raw` takes `&self`; states are owned per decode
    /// slot / per attention unit and never shared across threads
    /// (`Send`, not `Sync`).
    scratch: RefCell<Scratch>,
}

impl<M: FeatureMap> PhiState<M> {
    /// Empty state for `map` with value dimension `dv`.
    pub fn with_map(map: M, dv: usize) -> PhiState<M> {
        assert!(dv > 0, "empty value dim");
        let f = map.feature_dim();
        let d = map.d();
        PhiState {
            dv,
            z: vec![0.0; f],
            m: vec![0.0; f * dv],
            isa: simd::active(),
            scratch: RefCell::new(Scratch::sized(f, dv, d)),
            map,
        }
    }

    /// The feature map driving this state.
    pub fn feature_map(&self) -> &M {
        &self.map
    }

    /// Features of the state (= per-degree packed moments for Taylor).
    pub fn feature_dim(&self) -> usize {
        self.z.len()
    }

    /// Pin the lane dispatch for this state (tests, benches, golden
    /// pins); requests are clamped to what the machine supports.
    pub fn set_isa(&mut self, isa: Isa) {
        self.isa = simd::resolve(isa);
    }
}

impl PhiState<TaylorMap> {
    /// Taylor order of the underlying [`TaylorMap`].
    pub fn order(&self) -> usize {
        self.feature_map().order()
    }
}

impl<M: FeatureMap> RecurrentAttention for PhiState<M> {
    fn d(&self) -> usize {
        self.map.d()
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn isa(&self) -> Isa {
        self.isa
    }

    fn reset(&mut self) {
        self.z.fill(0.0);
        self.m.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        // take/put instead of holding the borrow: absorb_prepped needs
        // the arena for φ
        let mut kp = self.scratch.get_mut().take_prep();
        self.map.prep_rows_into(k, 1, &mut kp);
        self.absorb_prepped(&kp, v);
        self.scratch.get_mut().put_prep(kp);
    }

    /// Absorb a key row that already went through [`Self::prep_rows`] —
    /// the blocked path pays the per-row prep once instead of twice.
    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        let dv = self.dv;
        assert_eq!(kp.len(), self.map.d(), "k row");
        assert_eq!(v.len(), dv, "v row");
        let isa = self.isa;
        let sc = self.scratch.get_mut();
        self.map.map_k(kp, &mut sc.phi);
        scratch::widen(&mut sc.v64, v);
        // elementwise mul-then-add (no FMA) in every ISA: the state bits
        // never depend on the dispatch — see simd module docs
        for (a, &p) in sc.phi.iter().enumerate() {
            self.z[a] += p;
            simd::axpy(isa, &mut self.m[a * dv..(a + 1) * dv], &sc.v64, p);
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        let mut qp = self.scratch.borrow_mut().take_prep();
        self.map.prep_rows_into(q, 1, &mut qp);
        let den = self.query_raw_prepped(&qp, num);
        self.scratch.borrow_mut().put_prep(qp);
        den
    }

    fn query_raw_prepped(&self, qp: &[f32], num: &mut [f64]) -> f64 {
        let dv = self.dv;
        assert_eq!(qp.len(), self.map.d(), "q row");
        assert_eq!(num.len(), dv, "num row");
        let mut sc = self.scratch.borrow_mut();
        self.map.map_q(qp, &mut sc.phi);
        num.fill(0.0);
        // split reductions: φ·Z then the blocked (F, dv) read.  Under
        // Isa::Scalar each accumulator still sees the historic per-index
        // order, so scalar results stay bit-identical to the pre-SIMD
        // interleaved loop.
        let den = simd::dot_pd(self.isa, &sc.phi, &self.z);
        simd::matvec_accum(self.isa, num, &sc.phi, &self.m, dv);
        den
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        let (mut qp, mut kp) = {
            let mut sc = self.scratch.borrow_mut();
            (sc.take_prep(), sc.take_prep2())
        };
        self.map.prep_rows_into(q, 1, &mut qp);
        self.map.prep_rows_into(k, 1, &mut kp);
        let w = self.pair_weight_prepped(&qp, &kp);
        let mut sc = self.scratch.borrow_mut();
        sc.put_prep(qp);
        sc.put_prep2(kp);
        w
    }

    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        self.map.prep_rows(rows, n)
    }

    fn prep_rows_into(&self, rows: &[f32], n: usize, out: &mut Vec<f32>) {
        self.map.prep_rows_into(rows, n, out);
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        self.map
            .pair_weight_from_dot(simd::dot_ps(self.isa, q, k))
    }

    fn state_elements(&self) -> usize {
        self.z.len() + self.m.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.reserve(self.state_elements());
        out.extend_from_slice(&self.z);
        out.extend_from_slice(&self.m);
    }

    fn load_state(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.state_elements(), "PhiState snapshot size");
        let (z, m) = data.split_at(self.z.len());
        self.z.copy_from_slice(z);
        self.m.copy_from_slice(m);
    }

    fn query(&self, q: &[f32], out: &mut [f32]) {
        // overrides the allocating trait default: numerator comes from
        // the arena, so step() is allocation-free
        let (mut qp, mut num) = {
            let mut sc = self.scratch.borrow_mut();
            (sc.take_prep(), sc.take_num())
        };
        self.map.prep_rows_into(q, 1, &mut qp);
        scratch::ensure_len(&mut num, self.dv);
        let den = crate::kernels::floor_den(self.query_raw_prepped(&qp, &mut num));
        for (o, &x) in out.iter_mut().zip(num.iter()) {
            *o = (x / den) as f32;
        }
        let mut sc = self.scratch.borrow_mut();
        sc.put_prep(qp);
        sc.put_num(num);
    }
}

impl<M: FeatureMap> AttentionGrad for PhiState<M> {
    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        self.map.pair_weight_from_dot(dot)
    }

    fn pair_weight_dot_grad(&self, dot: f64) -> f64 {
        self.map.pair_weight_dot_grad(dot)
    }

    fn query_vjp(&self, qp: &[f32], dnum: &[f64], dden: f64, gstate: &mut [f64], gqp: &mut [f64]) {
        let (f, dv) = (self.z.len(), self.dv);
        assert_eq!(qp.len(), self.map.d(), "q row");
        assert_eq!(dnum.len(), dv, "dnum row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let isa = self.isa;
        let mut sc = self.scratch.borrow_mut();
        let Scratch { phi, dphi, .. } = &mut *sc;
        self.map.map_q(qp, phi);
        // gstate layout == save_state: [z (F), m (F·dv)]
        for (a, &p) in phi.iter().enumerate() {
            gstate[a] += dden * p;
            let srow = &self.m[a * dv..(a + 1) * dv];
            let grow = &mut gstate[f + a * dv..f + (a + 1) * dv];
            simd::axpy(isa, grow, dnum, p);
            dphi[a] = dden * self.z[a] + simd::dot_pd(isa, dnum, srow);
        }
        self.map.map_q_vjp(qp, dphi, gqp);
    }

    fn absorb_vjp(&self, kp: &[f32], v: &[f32], gstate: &[f64], gkp: &mut [f64], gv: &mut [f64]) {
        let (f, dv) = (self.z.len(), self.dv);
        assert_eq!(kp.len(), self.map.d(), "k row");
        assert_eq!(v.len(), dv, "v row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        let isa = self.isa;
        let mut sc = self.scratch.borrow_mut();
        let Scratch { phi, dphi, v64, .. } = &mut *sc;
        self.map.map_k(kp, phi);
        scratch::widen(v64, v);
        for (a, &p) in phi.iter().enumerate() {
            let grow = &gstate[f + a * dv..f + (a + 1) * dv];
            simd::axpy(isa, gv, grow, p);
            dphi[a] = gstate[a] + simd::dot_pd(isa, grow, v64);
        }
        self.map.map_k_vjp(kp, dphi, gkp);
    }

    fn prep_rows_vjp(&self, rows: &[f32], n: usize, g: &[f64]) -> Vec<f64> {
        self.map.prep_rows_vjp(rows, n, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{streaming_forward, EluMap};
    use crate::rng::Rng;

    #[test]
    fn state_count_is_feature_dim_times_one_plus_dv() {
        let (d, dv) = (6, 5);
        for order in 0..=3 {
            let st = PhiState::with_map(TaylorMap::new(d, order, 3.0, true), dv);
            let f = st.feature_dim();
            assert_eq!(st.state_elements(), f * (1 + dv), "order {order}");
        }
        let st = PhiState::with_map(EluMap::new(d), dv);
        assert_eq!(st.state_elements(), d * (1 + dv));
    }

    #[test]
    fn save_load_roundtrip_any_map() {
        let mut rng = Rng::new(81);
        let (d, dv) = (5, 4);
        let mut a = PhiState::with_map(TaylorMap::new(d, 3, 2.0, true), dv);
        for _ in 0..6 {
            a.absorb(&rng.normal_vec_f32(d, 1.0), &rng.normal_vec_f32(dv, 1.0));
        }
        let mut snap = Vec::new();
        a.save_state(&mut snap);
        let mut b = PhiState::with_map(TaylorMap::new(d, 3, 2.0, true), dv);
        b.load_state(&snap);
        let q = rng.normal_vec_f32(d, 1.0);
        let mut na = vec![0.0f64; dv];
        let mut nb = vec![0.0f64; dv];
        assert_eq!(a.query_raw(&q, &mut na), b.query_raw(&q, &mut nb));
        assert_eq!(na, nb);
    }

    #[test]
    fn order3_recurrence_matches_oracle() {
        // the genuinely new data point: order-3 streaming ≡ the direct
        // O(n²) Taylor-3 oracle
        let mut rng = Rng::new(82);
        let (n, d, dv) = (14, 6, 5);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        for causal in [true, false] {
            let oracle =
                crate::mathref::ho_attention(&q, &k, &v, n, n, d, dv, 3, 3.0, causal, true);
            for isa in simd::available() {
                let mut st = PhiState::with_map(TaylorMap::new(d, 3, 3.0, true), dv);
                st.set_isa(isa);
                let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
                for (a, b) in got.iter().zip(&oracle) {
                    assert!((a - b).abs() < 1e-5, "causal {causal} isa {isa:?}");
                }
            }
        }
    }

    #[test]
    fn constant_v_is_reproduced_at_order3() {
        // row-normalized weights reproduce a constant v exactly, at any
        // order — the denominator really is the summed weights
        let mut rng = Rng::new(83);
        let (d, dv) = (8, 8);
        let mut st = PhiState::with_map(TaylorMap::new(d, 3, 3.0, true), dv);
        let constant_v = vec![1.5f32; dv];
        let mut out = vec![0.0f32; dv];
        for _ in 0..20 {
            let q = rng.normal_vec_f32(d, 1.0);
            let k = rng.normal_vec_f32(d, 1.0);
            st.step(&q, &k, &constant_v, &mut out);
            for &x in &out {
                assert!((x - 1.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn absorb_states_are_bit_identical_across_isas() {
        // the no-FMA elementwise contract at the PhiState level: states
        // built under any ISA carry exactly the same bits
        let mut rng = Rng::new(84);
        let (d, dv, n) = (7, 6, 12);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let mut want = Vec::new();
        for isa in simd::available() {
            let mut st = PhiState::with_map(TaylorMap::new(d, 2, 2.0, true), dv);
            st.set_isa(isa);
            for j in 0..n {
                st.absorb(&k[j * d..(j + 1) * d], &v[j * dv..(j + 1) * dv]);
            }
            let mut snap = Vec::new();
            st.save_state(&mut snap);
            if want.is_empty() {
                want = snap;
            } else {
                assert_eq!(snap, want, "isa {isa:?}");
            }
        }
    }
}
