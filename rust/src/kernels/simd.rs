//! Lane-tiled inner-loop primitives for the φ hot path, behind runtime
//! feature dispatch.
//!
//! Every op ships in (up to) three implementations:
//!
//! * [`Isa::Scalar`] — the original element-at-a-time loops, kept verbatim
//!   as the always-available **reference path**.  Accumulation order is
//!   exactly the pre-SIMD kernels'; the proptests in
//!   `rust/tests/simd_hotpath.rs` pin the other paths against it.
//! * [`Isa::Unrolled`] — safe Rust, hand-unrolled into 4 × f64 lanes with
//!   independent partial accumulators.  Autovectorizes on any target
//!   (NEON, SSE2 baseline) without `unsafe`.
//! * [`Isa::Avx2`] — x86_64 AVX2 + FMA intrinsics, selected only when
//!   `is_x86_feature_detected!` says so at runtime.
//!
//! # Reassociation contract
//!
//! The elementwise ops ([`axpy`], [`axpy_ps`]) perform the *same*
//! rounded multiply-then-add per element in every ISA (no FMA
//! contraction) — results are **bit-identical** across paths, which is
//! why kernel *states* (built only from elementwise absorbs) never
//! depend on the dispatch.  The reductions ([`dot_pd`], [`dot_ps`],
//! [`matvec_accum`]) are where the speedup lives: lane-blocked partial
//! sums + FMA, i.e. documented float reassociation, ≤ 1e-6 relative
//! drift vs [`Isa::Scalar`].  Anything pinned bit-exact (golden tests)
//! must run the scalar path — see `PhiState::set_isa`.

use std::sync::OnceLock;

/// Which implementation of the lane-tiled primitives to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Element-at-a-time reference loops (the pre-SIMD semantics anchor).
    Scalar,
    /// Safe 4-lane hand-unrolled Rust — available everywhere.
    Unrolled,
    /// AVX2 + FMA intrinsics — x86_64 with runtime detection only.
    Avx2,
}

/// Best ISA the running CPU supports.
pub fn detect() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2;
        }
    }
    Isa::Unrolled
}

/// Clamp a requested ISA to what this machine can run: [`Isa::Avx2`]
/// downgrades to [`Isa::Unrolled`] when unavailable, everything else is
/// returned unchanged.
pub fn resolve(isa: Isa) -> Isa {
    if isa == Isa::Avx2 && detect() != Isa::Avx2 {
        return Isa::Unrolled;
    }
    isa
}

/// The process-wide default: runtime detection, overridable with
/// `HOLT_SIMD=scalar|unrolled|avx2` (downgraded if unsupported).  Read
/// once; per-state overrides (`PhiState::set_isa`) exist so tests and
/// benches can pin a path without touching global state.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var("HOLT_SIMD").ok().as_deref() {
        Some("scalar") => Isa::Scalar,
        Some("unrolled") => Isa::Unrolled,
        Some("avx2") => resolve(Isa::Avx2),
        _ => detect(),
    })
}

/// Every ISA runnable on this machine, [`Isa::Scalar`] first — the
/// iteration axis of the SIMD ≡ scalar pin tests.
pub fn available() -> Vec<Isa> {
    let mut out = vec![Isa::Scalar, Isa::Unrolled];
    if detect() == Isa::Avx2 {
        out.push(Isa::Avx2);
    }
    out
}

// ---------------------------------------------------------------------------
// axpy: acc[c] += a · x[c]   (f64 × f64 — the Σφ(k)⊗v state update)
// ---------------------------------------------------------------------------

/// `acc[c] += a · x[c]` — elementwise, multiply-then-add in every path
/// (**no FMA**): bit-identical across ISAs so absorb leaves the same
/// state bits no matter the dispatch.
#[inline]
pub fn axpy(isa: Isa, acc: &mut [f64], x: &[f64], a: f64) {
    debug_assert_eq!(acc.len(), x.len());
    match isa {
        Isa::Scalar => axpy_scalar(acc, x, a),
        Isa::Unrolled => axpy_unrolled(acc, x, a),
        Isa::Avx2 => axpy_avx2_dispatch(acc, x, a),
    }
}

fn axpy_scalar(acc: &mut [f64], x: &[f64], a: f64) {
    for (o, &v) in acc.iter_mut().zip(x) {
        *o += a * v;
    }
}

fn axpy_unrolled(acc: &mut [f64], x: &[f64], a: f64) {
    let n4 = (acc.len() / 4) * 4;
    let mut i = 0;
    while i < n4 {
        // same rounded mul-then-add per element as scalar — only the
        // loop structure changes, so results stay bit-identical
        acc[i] += a * x[i];
        acc[i + 1] += a * x[i + 1];
        acc[i + 2] += a * x[i + 2];
        acc[i + 3] += a * x[i + 3];
        i += 4;
    }
    for c in i..acc.len() {
        acc[c] += a * x[c];
    }
}

#[cfg(target_arch = "x86_64")]
fn axpy_avx2_dispatch(acc: &mut [f64], x: &[f64], a: f64) {
    // Safety: Isa::Avx2 is only produced by detect()/resolve() when the
    // CPU reports avx2+fma.
    unsafe { axpy_avx2(acc, x, a) }
}

#[cfg(not(target_arch = "x86_64"))]
fn axpy_avx2_dispatch(acc: &mut [f64], x: &[f64], a: f64) {
    axpy_unrolled(acc, x, a)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_avx2(acc: &mut [f64], x: &[f64], a: f64) {
    use core::arch::x86_64::*;
    let n = acc.len();
    let av = _mm256_set1_pd(a);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm256_loadu_pd(x.as_ptr().add(i));
        let ov = _mm256_loadu_pd(acc.as_ptr().add(i));
        // mul then add (NOT fmadd): keeps the per-element rounding
        // identical to the scalar reference — states stay bit-equal
        let r = _mm256_add_pd(ov, _mm256_mul_pd(av, xv));
        _mm256_storeu_pd(acc.as_mut_ptr().add(i), r);
        i += 4;
    }
    for c in i..n {
        acc[c] += a * x[c];
    }
}

// ---------------------------------------------------------------------------
// axpy_ps: acc[c] += a · x[c]   (f64 acc, f32 x — intra-chunk v row)
// ---------------------------------------------------------------------------

/// `acc[c] += a · (x[c] as f64)` — elementwise, no FMA, bit-identical
/// across ISAs (the f32 → f64 widening is exact).
#[inline]
pub fn axpy_ps(isa: Isa, acc: &mut [f64], x: &[f32], a: f64) {
    debug_assert_eq!(acc.len(), x.len());
    match isa {
        Isa::Scalar => axpy_ps_scalar(acc, x, a),
        Isa::Unrolled => axpy_ps_unrolled(acc, x, a),
        Isa::Avx2 => axpy_ps_avx2_dispatch(acc, x, a),
    }
}

fn axpy_ps_scalar(acc: &mut [f64], x: &[f32], a: f64) {
    for (o, &v) in acc.iter_mut().zip(x) {
        *o += a * v as f64;
    }
}

fn axpy_ps_unrolled(acc: &mut [f64], x: &[f32], a: f64) {
    let n4 = (acc.len() / 4) * 4;
    let mut i = 0;
    while i < n4 {
        acc[i] += a * x[i] as f64;
        acc[i + 1] += a * x[i + 1] as f64;
        acc[i + 2] += a * x[i + 2] as f64;
        acc[i + 3] += a * x[i + 3] as f64;
        i += 4;
    }
    for c in i..acc.len() {
        acc[c] += a * x[c] as f64;
    }
}

#[cfg(target_arch = "x86_64")]
fn axpy_ps_avx2_dispatch(acc: &mut [f64], x: &[f32], a: f64) {
    unsafe { axpy_ps_avx2(acc, x, a) }
}

#[cfg(not(target_arch = "x86_64"))]
fn axpy_ps_avx2_dispatch(acc: &mut [f64], x: &[f32], a: f64) {
    axpy_ps_unrolled(acc, x, a)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn axpy_ps_avx2(acc: &mut [f64], x: &[f32], a: f64) {
    use core::arch::x86_64::*;
    let n = acc.len();
    let av = _mm256_set1_pd(a);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let ov = _mm256_loadu_pd(acc.as_ptr().add(i));
        let r = _mm256_add_pd(ov, _mm256_mul_pd(av, xv));
        _mm256_storeu_pd(acc.as_mut_ptr().add(i), r);
        i += 4;
    }
    for c in i..n {
        acc[c] += a * x[c] as f64;
    }
}

// ---------------------------------------------------------------------------
// dot_pd: Σ a[i]·b[i]   (f64 — the φ(q)·Z denominator read, vjp rows)
// ---------------------------------------------------------------------------

/// `Σᵢ a[i]·b[i]` over f64 — lane-blocked with FMA off the scalar path
/// (reassociates; ≤ 1e-6 relative drift vs [`Isa::Scalar`]).
#[inline]
pub fn dot_pd(isa: Isa, a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot_pd_scalar(a, b),
        Isa::Unrolled => dot_pd_unrolled(a, b),
        Isa::Avx2 => dot_pd_avx2_dispatch(a, b),
    }
}

fn dot_pd_scalar(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

fn dot_pd_unrolled(a: &[f64], b: &[f64]) -> f64 {
    let n4 = (a.len() / 4) * 4;
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i < n4 {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut tail = 0.0f64;
    for c in i..a.len() {
        tail += a[c] * b[c];
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

#[cfg(target_arch = "x86_64")]
fn dot_pd_avx2_dispatch(a: &[f64], b: &[f64]) -> f64 {
    unsafe { dot_pd_avx2(a, b) }
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_pd_avx2_dispatch(a: &[f64], b: &[f64]) -> f64 {
    dot_pd_unrolled(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_pd_avx2(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0;
    while i + 8 <= n {
        acc0 = _mm256_fmadd_pd(
            _mm256_loadu_pd(a.as_ptr().add(i)),
            _mm256_loadu_pd(b.as_ptr().add(i)),
            acc0,
        );
        acc1 = _mm256_fmadd_pd(
            _mm256_loadu_pd(a.as_ptr().add(i + 4)),
            _mm256_loadu_pd(b.as_ptr().add(i + 4)),
            acc1,
        );
        i += 8;
    }
    if i + 4 <= n {
        acc0 = _mm256_fmadd_pd(
            _mm256_loadu_pd(a.as_ptr().add(i)),
            _mm256_loadu_pd(b.as_ptr().add(i)),
            acc0,
        );
        i += 4;
    }
    let mut tail = 0.0f64;
    for c in i..n {
        tail += a[c] * b[c];
    }
    hsum256(_mm256_add_pd(acc0, acc1)) + tail
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum256(v: core::arch::x86_64::__m256d) -> f64 {
    use core::arch::x86_64::*;
    let lo = _mm256_castpd256_pd128(v);
    let hi = _mm256_extractf128_pd::<1>(v);
    let s = _mm_add_pd(lo, hi);
    let s = _mm_add_sd(s, _mm_unpackhi_pd(s, s));
    _mm_cvtsd_f64(s)
}

// ---------------------------------------------------------------------------
// dot_ps: Σ a[i]·b[i]   (f32 inputs widened to f64 — the pair-weight dot)
// ---------------------------------------------------------------------------

/// `Σᵢ (a[i] as f64)·(b[i] as f64)` — the intra-chunk triangle's dot
/// product.  Lane-blocked + FMA off the scalar path (reassociates).
#[inline]
pub fn dot_ps(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        Isa::Scalar => dot_ps_scalar(a, b),
        Isa::Unrolled => dot_ps_unrolled(a, b),
        Isa::Avx2 => dot_ps_avx2_dispatch(a, b),
    }
}

fn dot_ps_scalar(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as f64 * y as f64;
    }
    acc
}

fn dot_ps_unrolled(a: &[f32], b: &[f32]) -> f64 {
    let n4 = (a.len() / 4) * 4;
    let mut acc = [0.0f64; 4];
    let mut i = 0;
    while i < n4 {
        acc[0] += a[i] as f64 * b[i] as f64;
        acc[1] += a[i + 1] as f64 * b[i + 1] as f64;
        acc[2] += a[i + 2] as f64 * b[i + 2] as f64;
        acc[3] += a[i + 3] as f64 * b[i + 3] as f64;
        i += 4;
    }
    let mut tail = 0.0f64;
    for c in i..a.len() {
        tail += a[c] as f64 * b[c] as f64;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

#[cfg(target_arch = "x86_64")]
fn dot_ps_avx2_dispatch(a: &[f32], b: &[f32]) -> f64 {
    unsafe { dot_ps_avx2(a, b) }
}

#[cfg(not(target_arch = "x86_64"))]
fn dot_ps_avx2_dispatch(a: &[f32], b: &[f32]) -> f64 {
    dot_ps_unrolled(a, b)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_ps_avx2(a: &[f32], b: &[f32]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let mut i = 0;
    while i + 8 <= n {
        acc0 = _mm256_fmadd_pd(
            _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i))),
            _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i))),
            acc0,
        );
        acc1 = _mm256_fmadd_pd(
            _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i + 4))),
            _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i + 4))),
            acc1,
        );
        i += 8;
    }
    if i + 4 <= n {
        acc0 = _mm256_fmadd_pd(
            _mm256_cvtps_pd(_mm_loadu_ps(a.as_ptr().add(i))),
            _mm256_cvtps_pd(_mm_loadu_ps(b.as_ptr().add(i))),
            acc0,
        );
        i += 4;
    }
    let mut tail = 0.0f64;
    for c in i..n {
        tail += a[c] as f64 * b[c] as f64;
    }
    hsum256(_mm256_add_pd(acc0, acc1)) + tail
}

// ---------------------------------------------------------------------------
// matvec_accum: num[c] += Σ_a phi[a] · m[a·dv + c]   (the φ(q)·M read)
// ---------------------------------------------------------------------------

/// `num[c] += Σₐ phi[a] · m[a·dv + c]` — the moment-matrix read, blocked
/// two feature rows at a time so each pass over `num` amortizes two rows
/// of `m` (the (F, dv) matrix streams through cache exactly once).
/// Reassociates off the scalar path.
#[inline]
pub fn matvec_accum(isa: Isa, num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    debug_assert_eq!(num.len(), dv);
    debug_assert_eq!(m.len(), phi.len() * dv);
    match isa {
        Isa::Scalar => matvec_scalar(num, phi, m, dv),
        Isa::Unrolled => matvec_unrolled(num, phi, m, dv),
        Isa::Avx2 => matvec_avx2_dispatch(num, phi, m, dv),
    }
}

fn matvec_scalar(num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    for (a, &p) in phi.iter().enumerate() {
        let row = &m[a * dv..(a + 1) * dv];
        for (acc, &x) in num.iter_mut().zip(row) {
            *acc += p * x;
        }
    }
}

fn matvec_unrolled(num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    let f = phi.len();
    let mut a = 0;
    while a + 2 <= f {
        let p0 = phi[a];
        let p1 = phi[a + 1];
        let r0 = &m[a * dv..(a + 1) * dv];
        let r1 = &m[(a + 1) * dv..(a + 2) * dv];
        for c in 0..dv {
            num[c] += p0 * r0[c] + p1 * r1[c];
        }
        a += 2;
    }
    if a < f {
        let p = phi[a];
        let row = &m[a * dv..(a + 1) * dv];
        for (acc, &x) in num.iter_mut().zip(row) {
            *acc += p * x;
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn matvec_avx2_dispatch(num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    unsafe { matvec_avx2(num, phi, m, dv) }
}

#[cfg(not(target_arch = "x86_64"))]
fn matvec_avx2_dispatch(num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    matvec_unrolled(num, phi, m, dv)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn matvec_avx2(num: &mut [f64], phi: &[f64], m: &[f64], dv: usize) {
    use core::arch::x86_64::*;
    let f = phi.len();
    let dv4 = (dv / 4) * 4;
    let mut a = 0;
    while a + 2 <= f {
        let p0 = _mm256_set1_pd(phi[a]);
        let p1 = _mm256_set1_pd(phi[a + 1]);
        let r0 = m.as_ptr().add(a * dv);
        let r1 = m.as_ptr().add((a + 1) * dv);
        let mut c = 0;
        while c < dv4 {
            let mut acc = _mm256_loadu_pd(num.as_ptr().add(c));
            acc = _mm256_fmadd_pd(p0, _mm256_loadu_pd(r0.add(c)), acc);
            acc = _mm256_fmadd_pd(p1, _mm256_loadu_pd(r1.add(c)), acc);
            _mm256_storeu_pd(num.as_mut_ptr().add(c), acc);
            c += 4;
        }
        while c < dv {
            num[c] += phi[a] * *r0.add(c) + phi[a + 1] * *r1.add(c);
            c += 1;
        }
        a += 2;
    }
    if a < f {
        let p = phi[a];
        let row = &m[a * dv..(a + 1) * dv];
        for (acc, &x) in num.iter_mut().zip(row) {
            *acc += p * x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn axpy_is_bit_identical_across_isas() {
        let mut rng = Rng::new(61);
        for n in [1, 3, 4, 7, 8, 33, 100] {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let base: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let a = rng.normal();
            let mut want = base.clone();
            axpy(Isa::Scalar, &mut want, &x, a);
            for isa in available() {
                let mut got = base.clone();
                axpy(isa, &mut got, &x, a);
                assert_eq!(got, want, "{isa:?} n={n}");
            }
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let mut want = base.clone();
            axpy_ps(Isa::Scalar, &mut want, &xf, a);
            for isa in available() {
                let mut got = base.clone();
                axpy_ps(isa, &mut got, &xf, a);
                assert_eq!(got, want, "ps {isa:?} n={n}");
            }
        }
    }

    #[test]
    fn reductions_match_scalar_within_reassociation() {
        let mut rng = Rng::new(62);
        for n in [1, 2, 4, 5, 8, 9, 31, 128, 1000] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let want = dot_pd(Isa::Scalar, &a, &b);
            let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            let want_ps = dot_ps(Isa::Scalar, &af, &bf);
            for isa in available() {
                assert!(close(dot_pd(isa, &a, &b), want, 1e-12), "{isa:?} n={n}");
                assert!(close(dot_ps(isa, &af, &bf), want_ps, 1e-12), "ps {isa:?} n={n}");
            }
        }
    }

    #[test]
    fn matvec_matches_scalar_within_reassociation() {
        let mut rng = Rng::new(63);
        for (f, dv) in [(1, 1), (2, 3), (5, 4), (7, 8), (66, 13), (231, 32)] {
            let phi: Vec<f64> = (0..f).map(|_| rng.normal()).collect();
            let m: Vec<f64> = (0..f * dv).map(|_| rng.normal()).collect();
            let base: Vec<f64> = (0..dv).map(|_| rng.normal()).collect();
            let mut want = base.clone();
            matvec_scalar(&mut want, &phi, &m, dv);
            for isa in available() {
                let mut got = base.clone();
                matvec_accum(isa, &mut got, &phi, &m, dv);
                for (g, w) in got.iter().zip(&want) {
                    assert!(close(*g, *w, 1e-12), "{isa:?} f={f} dv={dv}");
                }
            }
        }
    }

    #[test]
    fn resolve_never_returns_unsupported() {
        for isa in [Isa::Scalar, Isa::Unrolled, Isa::Avx2] {
            let r = resolve(isa);
            assert!(available().contains(&r), "{isa:?} resolved to {r:?}");
        }
        assert!(available().contains(&active()));
    }
}
