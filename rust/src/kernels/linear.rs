//! First-order linear attention baseline (Katharopoulos et al. 2020) —
//! a thin instantiation of the generic φ-outer-product recurrence:
//! [`LinearState`] = [`PhiState`]<[`EluMap`]>.
//!
//! The elu(x)+1 feature map happens in the per-row prep stage, so the map
//! proper is the identity, the state is `(Σφ(k), Σφ(k)⊗v)` with F = d,
//! and the pair weight is a plain dot product — the exact counterpart of
//! `mathref::linear_attention`.  The absorb/query/vjp bodies that used to
//! be duplicated here live once in `kernels/phi.rs` now.

use crate::kernels::{EluMap, PhiState};

/// Recurrent state for elu+1 linear attention over one head.
pub type LinearState = PhiState<EluMap>;

impl PhiState<EluMap> {
    pub fn new(d: usize, dv: usize) -> LinearState {
        PhiState::with_map(EluMap::new(d), dv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{streaming_forward, RecurrentAttention};
    use crate::mathref;
    use crate::rng::Rng;

    #[test]
    fn absorb_prepped_equals_absorb_on_raw_rows() {
        let mut rng = Rng::new(13);
        let (d, dv) = (5, 4);
        let mut a = LinearState::new(d, dv);
        let mut b = LinearState::new(d, dv);
        for _ in 0..6 {
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            a.absorb(&k, &v);
            let kp = b.prep_rows(&k, 1);
            b.absorb_prepped(&kp, &v);
        }
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        a.save_state(&mut sa);
        b.save_state(&mut sb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn matches_oracle_on_small_case() {
        let mut rng = Rng::new(11);
        let (n, d, dv) = (12, 7, 4);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        for causal in [true, false] {
            let oracle = mathref::linear_attention(&q, &k, &v, n, n, d, dv, causal);
            let mut st = LinearState::new(d, dv);
            let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
            for (a, b) in got.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-5, "causal {causal}");
            }
        }
    }

    #[test]
    fn weights_are_positive() {
        // φ > 0 everywhere, so the denominator clamp never matters after
        // the first absorb
        let mut rng = Rng::new(12);
        let st = LinearState::new(8, 4);
        for _ in 0..50 {
            let q = rng.normal_vec_f32(8, 2.0);
            let k = rng.normal_vec_f32(8, 2.0);
            assert!(st.pair_weight(&q, &k) > 0.0);
        }
    }
}
