//! First-order linear attention baseline (Katharopoulos et al. 2020):
//! feature map φ(x) = elu(x)+1, state Σφ(k) and Σφ(k)⊗v.  Same
//! [`RecurrentAttention`] contract as the higher-order kernel, O(d·dv)
//! state, and the exact counterpart of `mathref::linear_attention`.

use crate::kernels::{AttentionGrad, RecurrentAttention};
use crate::mathref::elu1;

/// Recurrent state for elu+1 linear attention over one head.
pub struct LinearState {
    d: usize,
    dv: usize,
    /// Σ φ(k) — (d).
    z: Vec<f64>,
    /// Σ φ(k)⊗v — (d, dv) row-major.
    m: Vec<f64>,
}

impl LinearState {
    pub fn new(d: usize, dv: usize) -> LinearState {
        assert!(d > 0 && dv > 0, "empty head dims");
        LinearState { d, dv, z: vec![0.0; d], m: vec![0.0; d * dv] }
    }

    /// State read with the query features supplied by `phi(a)`.
    fn query_raw_phi<F: Fn(usize) -> f32>(&self, phi: F, num: &mut [f64]) -> f64 {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(num.len(), dv, "num row");
        num.fill(0.0);
        let mut den = 0.0f64;
        for a in 0..d {
            let p = phi(a) as f64;
            den += p * self.z[a];
            let row = &self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in num.iter_mut().zip(row) {
                *acc += p * x;
            }
        }
        den
    }
}

impl RecurrentAttention for LinearState {
    fn d(&self) -> usize {
        self.d
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn reset(&mut self) {
        self.z.fill(0.0);
        self.m.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d, "k row");
        let kp: Vec<f32> = k.iter().map(|&x| elu1(x)).collect();
        self.absorb_prepped(&kp, v);
    }

    /// Absorb a key row with φ already applied ([`Self::prep_rows`]) —
    /// the blocked path pays the feature map once per row.
    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(kp.len(), d, "k row");
        assert_eq!(v.len(), dv, "v row");
        for a in 0..d {
            let phi = kp[a] as f64;
            self.z[a] += phi;
            let row = &mut self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in row.iter_mut().zip(v) {
                *acc += phi * x as f64;
            }
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        assert_eq!(q.len(), self.d, "q row");
        self.query_raw_phi(|a| elu1(q[a]), num)
    }

    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        // prep_rows already applied φ
        assert_eq!(q.len(), self.d, "q row");
        self.query_raw_phi(|a| q[a], num)
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        q.iter()
            .zip(k)
            .map(|(&a, &b)| elu1(a) as f64 * elu1(b) as f64)
            .sum()
    }

    /// Apply φ once per row block; prepped pair weights are then plain
    /// dot products.
    fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
        rows.iter().map(|&x| elu1(x)).collect()
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        q.iter().zip(k).map(|(&a, &b)| a as f64 * b as f64).sum()
    }

    fn state_elements(&self) -> usize {
        self.z.len() + self.m.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.reserve(self.state_elements());
        out.extend_from_slice(&self.z);
        out.extend_from_slice(&self.m);
    }

    fn load_state(&mut self, data: &[f64]) {
        assert_eq!(data.len(), self.state_elements(), "LinearState snapshot size");
        let (z, m) = data.split_at(self.z.len());
        self.z.copy_from_slice(z);
        self.m.copy_from_slice(m);
    }
}

impl AttentionGrad for LinearState {
    fn pair_weight_from_dot(&self, dot: f64) -> f64 {
        dot
    }

    fn pair_weight_dot_grad(&self, _dot: f64) -> f64 {
        1.0
    }

    fn query_vjp(&self, qp: &[f32], dnum: &[f64], dden: f64, gstate: &mut [f64], gqp: &mut [f64]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(qp.len(), d, "q row");
        assert_eq!(gstate.len(), self.state_elements(), "gstate layout");
        // gstate layout == save_state: [z (d), m (d·dv)]
        for a in 0..d {
            let u = qp[a] as f64;
            gstate[a] += dden * u;
            let srow = &self.m[a * dv..(a + 1) * dv];
            let grow = &mut gstate[d + a * dv..d + (a + 1) * dv];
            let mut acc = dden * self.z[a];
            for ((g, &x), &s) in grow.iter_mut().zip(dnum).zip(srow) {
                *g += u * x;
                acc += x * s;
            }
            gqp[a] += acc;
        }
    }

    fn absorb_vjp(&self, kp: &[f32], v: &[f32], gstate: &[f64], gkp: &mut [f64], gv: &mut [f64]) {
        let (d, dv) = (self.d, self.dv);
        assert_eq!(kp.len(), d, "k row");
        assert_eq!(v.len(), dv, "v row");
        for a in 0..d {
            let grow = &gstate[d + a * dv..d + (a + 1) * dv];
            let mut acc = gstate[a];
            for ((gvc, &gs), &vc) in gv.iter_mut().zip(grow).zip(v) {
                *gvc += kp[a] as f64 * gs;
                acc += gs * vc as f64;
            }
            gkp[a] += acc;
        }
    }

    fn prep_rows_vjp(&self, rows: &[f32], _n: usize, g: &[f64]) -> Vec<f64> {
        // φ = elu+1: φ'(x) = 1 for x > 0, eˣ otherwise
        rows.iter()
            .zip(g)
            .map(|(&x, &gp)| gp * if x > 0.0 { 1.0 } else { (x as f64).exp() })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::streaming_forward;
    use crate::mathref;
    use crate::rng::Rng;

    #[test]
    fn absorb_prepped_equals_absorb_on_raw_rows() {
        let mut rng = Rng::new(13);
        let (d, dv) = (5, 4);
        let mut a = LinearState::new(d, dv);
        let mut b = LinearState::new(d, dv);
        for _ in 0..6 {
            let k = rng.normal_vec_f32(d, 1.0);
            let v = rng.normal_vec_f32(dv, 1.0);
            a.absorb(&k, &v);
            let kp = b.prep_rows(&k, 1);
            b.absorb_prepped(&kp, &v);
        }
        let (mut sa, mut sb) = (Vec::new(), Vec::new());
        a.save_state(&mut sa);
        b.save_state(&mut sb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn matches_oracle_on_small_case() {
        let mut rng = Rng::new(11);
        let (n, d, dv) = (12, 7, 4);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        for causal in [true, false] {
            let oracle = mathref::linear_attention(&q, &k, &v, n, n, d, dv, causal);
            let mut st = LinearState::new(d, dv);
            let got = streaming_forward(&mut st, &q, &k, &v, n, causal);
            for (a, b) in got.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-5, "causal {causal}");
            }
        }
    }

    #[test]
    fn weights_are_positive() {
        // φ > 0 everywhere, so the denominator clamp never matters after
        // the first absorb
        let mut rng = Rng::new(12);
        let st = LinearState::new(8, 4);
        for _ in 0..50 {
            let q = rng.normal_vec_f32(8, 2.0);
            let k = rng.normal_vec_f32(8, 2.0);
            assert!(st.pair_weight(&q, &k) > 0.0);
        }
    }
}
