//! Cache-blocked chunked forward: the training-throughput evaluation of
//! the same recurrence.
//!
//! The sequence is cut into chunks of `chunk` tokens.  Inside a chunk the
//! causal weights are computed directly (O(c²·d) pairwise, contiguous in
//! cache); across chunks everything older flows through the O(1) kernel
//! state.  Per token that is O(c·d + S) work (S = state read cost), so
//! total cost stays linear in n with a knob trading recurrence overhead
//! against intra-chunk quadratic work — the same shape as the Pallas
//! kernel in `python/compile/kernels/chunked.py`, kept sequential here on
//! purpose so it can be diffed against the streaming form token by token.
//!
//! Non-causal attention has no intra/inter split (every query sees every
//! key), so it degenerates to absorb-all-then-query and `chunk` is
//! irrelevant; the causal path is the interesting one.

use crate::kernels::{floor_den, simd, streaming_forward, RecurrentAttention};

/// Full-sequence forward, chunked.  `q`/`k` are (n, d) row-major, `v` is
/// (n, dv); resets the kernel first.  Equivalent to
/// [`streaming_forward`] (and to the O(n²) oracle) up to float
/// reassociation — pinned by `prop_ho_chunk_size_invariance`.
pub fn chunked_forward<K: RecurrentAttention + ?Sized>(
    kernel: &mut K,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    chunk: usize,
    causal: bool,
) -> Vec<f32> {
    let (d, dv) = (kernel.d(), kernel.dv());
    assert_eq!(q.len(), n * d, "q shape");
    assert_eq!(k.len(), n * d, "k shape");
    assert_eq!(v.len(), n * dv, "v shape");
    if !causal {
        // streaming_forward counts the attention forward itself
        return streaming_forward(kernel, q, k, v, n, causal);
    }
    crate::kernels::counters::count_attn_forward();
    let chunk = chunk.max(1);
    kernel.reset();
    let isa = kernel.isa();
    let mut out = vec![0.0f32; n * dv];
    let mut num = vec![0.0f64; dv];
    // prepped-row buffers hoisted out of the chunk loop: two allocations
    // per call, zero per chunk
    let mut qp: Vec<f32> = Vec::with_capacity(chunk.min(n) * d);
    let mut kp: Vec<f32> = Vec::with_capacity(chunk.min(n) * d);
    let mut c0 = 0;
    while c0 < n {
        let c1 = (c0 + chunk).min(n);
        // per-row prep (LayerNorm / feature map) once per chunk, so the
        // O(c²) triangle below is pure dot products
        kernel.prep_rows_into(&q[c0 * d..c1 * d], c1 - c0, &mut qp);
        kernel.prep_rows_into(&k[c0 * d..c1 * d], c1 - c0, &mut kp);
        // query pass: recurrent prefix + direct intra-chunk triangle
        for i in c0..c1 {
            let qi = &qp[(i - c0) * d..(i - c0 + 1) * d];
            let mut den = kernel.query_raw_prepped(qi, &mut num);
            for j in c0..=i {
                let w = kernel.pair_weight_prepped(qi, &kp[(j - c0) * d..(j - c0 + 1) * d]);
                den += w;
                // lane-tiled but FMA-free: bit-identical to the scalar
                // accumulation at any ISA
                simd::axpy_ps(isa, &mut num, &v[j * dv..(j + 1) * dv], w);
            }
            let den = floor_den(den);
            for (o, &x) in out[i * dv..(i + 1) * dv].iter_mut().zip(num.iter()) {
                *o = (x / den) as f32;
            }
        }
        // state pass: fold the whole chunk into the recurrence, reusing
        // the rows prepped for the triangle (no second LayerNorm/φ pass)
        for j in c0..c1 {
            kernel.absorb_prepped(
                &kp[(j - c0) * d..(j - c0 + 1) * d],
                &v[j * dv..(j + 1) * dv],
            );
        }
        c0 = c1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{HoState, LinearState};
    use crate::rng::Rng;

    #[test]
    fn chunked_equals_streaming_for_every_chunk_size() {
        let mut rng = Rng::new(21);
        let (n, d, dv) = (23, 5, 6);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = HoState::paper(d, dv);
        let want = streaming_forward(&mut st, &q, &k, &v, n, true);
        for chunk in [1, 2, 7, 23, 64] {
            let got = chunked_forward(&mut st, &q, &k, &v, n, chunk, true);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "chunk {chunk}");
            }
        }
    }

    #[test]
    fn works_for_linear_kernel_too() {
        let mut rng = Rng::new(22);
        let (n, d, dv) = (17, 4, 4);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let mut st = LinearState::new(d, dv);
        let want = streaming_forward(&mut st, &q, &k, &v, n, true);
        let got = chunked_forward(&mut st, &q, &k, &v, n, 5, true);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
