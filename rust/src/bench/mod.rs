//! Benchmark harness (criterion is not in the offline vendor set).
//!
//! Measures wall-clock over warmup + timed iterations, reports mean/std/
//! min and writes CSV rows — the benches in `benches/` are `harness =
//! false` binaries built on this, so `cargo bench` runs them all.

use std::time::Instant;

use crate::metrics::Stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3} ms/iter (±{:.3}, min {:.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Stats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats.mean(),
        std_s: stats.std(),
        min_s: stats.min,
    }
}

/// Adaptive: pick iteration count so each case takes ~`budget_s` seconds.
pub fn bench_budget<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // one calibration run (counts as warmup)
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-7);
    let iters = ((budget_s / once).ceil() as usize).clamp(3, 1000);
    bench(name, 1, iters, f)
}

/// Write results as a machine-readable JSON array — one object per case
/// (name, mean_ms, std_ms, min_ms, iters) — so the perf trajectory can be
/// diffed across PRs (results/bench_*.json).
pub fn write_json(path: &std::path::Path, results: &[BenchResult]) -> anyhow::Result<()> {
    use crate::json::{obj, Json};
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            obj(vec![
                ("name", r.name.as_str().into()),
                ("mean_ms", (r.mean_s * 1e3).into()),
                ("std_ms", (r.std_s * 1e3).into()),
                ("min_ms", (r.min_s * 1e3).into()),
                ("iters", r.iters.into()),
            ])
        })
        .collect();
    std::fs::write(path, format!("{}\n", Json::Arr(rows)))?;
    Ok(())
}

/// Write results as CSV (name, mean_ms, std_ms, min_ms, iters).
pub fn write_csv(path: &std::path::Path, results: &[BenchResult]) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut s = String::from("name,mean_ms,std_ms,min_ms,iters\n");
    for r in results {
        s.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{}\n",
            r.name,
            r.mean_s * 1e3,
            r.std_s * 1e3,
            r.min_s * 1e3,
            r.iters
        ));
    }
    std::fs::write(path, s)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 2, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.mean_s < 0.1);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn budget_caps_iters() {
        let r = bench_budget("sleepy", 0.02, || std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(r.iters >= 3 && r.iters <= 10, "iters {}", r.iters);
    }
}
