//! Run configuration: a TOML-subset parser (the `toml` crate is not in the
//! offline vendor set) plus the typed configs the CLI and examples use.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string,
//! integer, float, bool and flat-array values, `#` comments.  That covers
//! every config this project ships; nested tables/dates are rejected
//! loudly rather than misparsed.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed flat-TOML document: section -> key -> value.
#[derive(Debug, Clone, Default)]
pub struct Toml {
    pub sections: HashMap<String, HashMap<String, Value>>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse TOML value: '{s}'")
}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // naive comment strip is wrong inside strings; handle that
                Some(i) if !raw[..i].contains('"') || raw[..i].matches('"').count() % 2 == 0 => {
                    &raw[..i]
                }
                _ => raw,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.starts_with("[[") {
                    bail!("line {}: unsupported table syntax '{line}'", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected key = value, got '{line}'", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let value = parse_value(&line[eq + 1..])
                .with_context(|| format!("line {}", lineno + 1))?;
            doc.sections.get_mut(&section).unwrap().insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: &Path) -> Result<Toml> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Toml::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }
}

/// Training-run configuration (CLI flags override file values).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// manifest model name, e.g. "ho2_small"
    pub model: String,
    pub task: String,
    pub steps: usize,
    pub lr: f64,
    /// linear warmup steps (0 = constant lr)
    pub warmup: usize,
    /// lr schedule after warmup: "constant" or "cosine" (decay to
    /// `min_lr` at `steps`)
    pub schedule: String,
    pub min_lr: f64,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub ckpt_every: usize,
    pub out_dir: String,
    /// micro-batches per step (gradient accumulation; 1 = whole batch).
    /// Bit-invariant: the gradient is identical for every value.
    pub accum: usize,
    /// worker cap for data-parallel gradients (0 = whole pool).  Also
    /// bit-invariant.
    pub grad_workers: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "ho2_small".into(),
            task: "copy".into(),
            steps: 300,
            lr: 3e-4,
            warmup: 20,
            schedule: "constant".into(),
            min_lr: 3e-5,
            seed: 42,
            log_every: 10,
            eval_every: 50,
            ckpt_every: 0,
            out_dir: "results".into(),
            accum: 1,
            grad_workers: 0,
        }
    }
}

impl TrainConfig {
    /// Merge values from a `[train]` section.
    pub fn apply_toml(&mut self, t: &Toml) -> Result<()> {
        let Some(sec) = t.sections.get("train") else {
            return Ok(());
        };
        for (k, v) in sec {
            match k.as_str() {
                "model" => self.model = v.as_str().context("model")?.into(),
                "task" => self.task = v.as_str().context("task")?.into(),
                "steps" => self.steps = v.as_i64().context("steps")? as usize,
                "lr" => self.lr = v.as_f64().context("lr")?,
                "warmup" => self.warmup = v.as_i64().context("warmup")? as usize,
                "schedule" => self.schedule = v.as_str().context("schedule")?.into(),
                "min_lr" => self.min_lr = v.as_f64().context("min_lr")?,
                "seed" => self.seed = v.as_i64().context("seed")? as u64,
                "log_every" => self.log_every = v.as_i64().context("log_every")? as usize,
                "eval_every" => {
                    self.eval_every = v.as_i64().context("eval_every")? as usize
                }
                "ckpt_every" => {
                    self.ckpt_every = v.as_i64().context("ckpt_every")? as usize
                }
                "out_dir" => self.out_dir = v.as_str().context("out_dir")?.into(),
                "accum" => self.accum = v.as_i64().context("accum")? as usize,
                "grad_workers" => {
                    self.grad_workers = v.as_i64().context("grad_workers")? as usize
                }
                _ => bail!("unknown [train] key '{k}'"),
            }
        }
        Ok(())
    }

    /// Learning rate at a step: linear warmup, then constant or cosine
    /// decay to `min_lr` at `steps`.
    pub fn lr_at(&self, step: usize) -> f64 {
        if self.warmup > 0 && step < self.warmup {
            return self.lr * (step + 1) as f64 / self.warmup as f64;
        }
        if self.schedule == "cosine" && self.steps > self.warmup {
            let t = (step - self.warmup) as f64 / (self.steps - self.warmup) as f64;
            let t = t.clamp(0.0, 1.0);
            return self.min_lr
                + 0.5 * (self.lr - self.min_lr) * (1.0 + (std::f64::consts::PI * t).cos());
        }
        self.lr
    }
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    pub ckpt: Option<String>,
    pub addr: String,
    pub max_tokens_default: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "ho2_small".into(),
            ckpt: None,
            addr: "127.0.0.1:8490".into(),
            max_tokens_default: 64,
            temperature: 0.8,
            top_k: 40,
            seed: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subset() {
        let doc = Toml::parse(
            r#"
# run config
top = "level"

[train]
model = "ho2_small"   # the paper's model
steps = 300
lr = 3e-4
warmup = 20
flag = true
ns = [64, 128, 256]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_str().unwrap(), "level");
        assert_eq!(doc.get("train", "steps").unwrap().as_i64().unwrap(), 300);
        assert!((doc.get("train", "lr").unwrap().as_f64().unwrap() - 3e-4).abs() < 1e-12);
        assert_eq!(doc.get("train", "flag").unwrap().as_bool(), Some(true));
        match doc.get("train", "ns").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn train_config_merge() {
        let doc = Toml::parse("[train]\nmodel = \"softmax_tiny\"\nsteps = 5\n").unwrap();
        let mut c = TrainConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.model, "softmax_tiny");
        assert_eq!(c.steps, 5);
        assert_eq!(c.task, "copy"); // untouched default
    }

    #[test]
    fn rejects_unknown_keys_and_tables() {
        let doc = Toml::parse("[train]\nbogus = 1\n").unwrap();
        assert!(TrainConfig::default().apply_toml(&doc).is_err());
        assert!(Toml::parse("[[arr_table]]\n").is_err());
        assert!(Toml::parse("key value\n").is_err());
    }

    #[test]
    fn warmup_schedule() {
        let c = TrainConfig { lr: 1.0, warmup: 10, ..Default::default() };
        assert!((c.lr_at(0) - 0.1).abs() < 1e-12);
        assert!((c.lr_at(9) - 1.0).abs() < 1e-12);
        assert!((c.lr_at(100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_schedule_decays_to_min() {
        let c = TrainConfig {
            lr: 1.0,
            min_lr: 0.1,
            warmup: 10,
            steps: 110,
            schedule: "cosine".into(),
            ..Default::default()
        };
        assert!((c.lr_at(9) - 1.0).abs() < 1e-12, "end of warmup = peak");
        let mid = c.lr_at(60);
        assert!((mid - 0.55).abs() < 1e-9, "midpoint {mid}");
        assert!((c.lr_at(110) - 0.1).abs() < 1e-9, "end = min_lr");
        // monotone decreasing after warmup
        let mut prev = f64::INFINITY;
        for s in 10..110 {
            let v = c.lr_at(s);
            assert!(v <= prev + 1e-12);
            prev = v;
        }
    }
}
