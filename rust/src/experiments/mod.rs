//! Paper-experiment drivers shared by the CLI, the examples and the
//! benches.  Every experiment id (Fig1, E1, E2, ...) in DESIGN.md §4 maps
//! to one function here; thin wrappers in `benches/`/`examples/` call them
//! and write CSV/JSONL into `results/`.

use std::path::Path;

use anyhow::Result;

use crate::kernels::{Evaluation, NativeBackend};
use crate::mathref;
use crate::rng::Rng;
use crate::runtime::{Runtime, Tensor};

/// Figure 1: exp(x) vs Taylor orders 1..3 on [-3, 3].
/// Returns CSV text (x, exp, order1, order2, order3).
pub fn fig1_taylor_csv(n_points: usize) -> String {
    let mut s = String::from("x,exp,order1,order2,order3\n");
    for i in 0..n_points {
        let x = -3.0 + 6.0 * i as f64 / (n_points - 1) as f64;
        s.push_str(&format!(
            "{:.4},{:.6},{:.6},{:.6},{:.6}\n",
            x,
            x.exp(),
            mathref::taylor_exp(x, 1),
            mathref::taylor_exp(x, 2),
            mathref::taylor_exp(x, 3),
        ));
    }
    s
}

/// One row of the E1 approximation-quality table.
#[derive(Debug, Clone)]
pub struct ApproxRow {
    pub alpha: f64,
    pub order: usize,
    /// relative L2 error of ho attention vs the alpha-rescaled LN softmax
    pub rel_err_vs_target: f64,
    /// relative L2 error vs the *standard* softmax attention
    pub rel_err_vs_std: f64,
}

/// E1: run the `approx_n256` artifact on random normal q/k/v and compare
/// every (alpha, order) grid point against its softmax target.
///
/// The artifact computes all outputs in one executable so every comparison
/// sees exactly the same inputs.
pub fn approx_quality(runtime: &Runtime, seed: u64) -> Result<Vec<ApproxRow>> {
    let exe = runtime.load("approx_n256")?;
    let a = &exe.artifact;
    let mut rng = Rng::new(seed);
    let inputs: Vec<Tensor> = a
        .inputs
        .iter()
        .map(|s| {
            let n: usize = s.shape.iter().product();
            Tensor::f32(s.shape.clone(), rng.normal_vec_f32(n, 1.0))
        })
        .collect();
    let outputs = exe.run(&inputs)?;

    // manifest meta carries the grids
    let alphas: Vec<f64> = a
        .meta
        .get("alphas")
        .and_then(|j| j.as_arr().map(|v| v.iter().filter_map(|x| x.as_f64()).collect()))
        .unwrap_or_else(|| vec![1.0, 2.0, 3.0, 4.0]);
    let orders: Vec<usize> = a
        .meta
        .get("orders")
        .and_then(|j| {
            j.as_arr()
                .map(|v| v.iter().filter_map(|x| x.as_i64().map(|i| i as usize)).collect())
        })
        .unwrap_or_else(|| vec![0, 1, 2]);

    // outputs: [softmax_std, then per alpha: softmax_ln_a, ho2_a_o0.. ]
    let std_out = &outputs[0];
    let mut rows = Vec::new();
    let mut idx = 1;
    for &alpha in &alphas {
        let target = &outputs[idx];
        idx += 1;
        for &order in &orders {
            let out = &outputs[idx];
            idx += 1;
            rows.push(ApproxRow {
                alpha,
                order,
                rel_err_vs_target: out.rel_l2(target)?,
                rel_err_vs_std: out.rel_l2(std_out)?,
            });
        }
    }
    Ok(rows)
}

/// E1 with no artifacts: the same (alpha, order) grid evaluated by the
/// native O(n) kernels — extended to Taylor order 3, the data point the
/// paper never ran (the artifact grid stops at 2) — targets computed by
/// the `mathref` softmax oracle with the matching LN + alpha rescaling
/// (logits qₙ·kₙ/(α√d) both sides).  Non-causal over an (n, d) head,
/// like the `approx_n256` artifact.
pub fn approx_quality_native(seed: u64, n: usize, d: usize) -> Result<Vec<ApproxRow>> {
    let alphas = [1.0, 2.0, 3.0, 4.0];
    let orders = [0usize, 1, 2, 3];
    let mut rng = Rng::new(seed);
    let q = rng.normal_vec_f32(n * d, 1.0);
    let k = rng.normal_vec_f32(n * d, 1.0);
    let v = rng.normal_vec_f32(n * d, 1.0);
    let shape = vec![n, d];
    let std_out = Tensor::f32(
        shape.clone(),
        mathref::softmax_attention(&q, &k, &v, n, n, d, d, false),
    );
    let mut qn = q.clone();
    let mut kn = k.clone();
    mathref::layernorm_noaffine(&mut qn, n, d, 1e-5);
    mathref::layernorm_noaffine(&mut kn, n, d, 1e-5);
    let mut rows = Vec::new();
    for &alpha in &alphas {
        // softmax over logits qₙ·kₙ/(α√d): fold √α into each side
        let s = (alpha as f32).sqrt();
        let qs: Vec<f32> = qn.iter().map(|x| x / s).collect();
        let ks: Vec<f32> = kn.iter().map(|x| x / s).collect();
        let target = Tensor::f32(
            shape.clone(),
            mathref::softmax_attention(&qs, &ks, &v, n, n, d, d, false),
        );
        for &order in &orders {
            let backend = NativeBackend { order, alpha, ..NativeBackend::paper() };
            let out = Tensor::f32(
                shape.clone(),
                backend.forward("ho2", &q, &k, &v, n, d, d, false)?,
            );
            rows.push(ApproxRow {
                alpha,
                order,
                rel_err_vs_target: out.rel_l2(&target)?,
                rel_err_vs_std: out.rel_l2(&std_out)?,
            });
        }
    }
    Ok(rows)
}

pub fn approx_rows_csv(rows: &[ApproxRow]) -> String {
    let mut s = String::from("alpha,order,rel_err_vs_target,rel_err_vs_std\n");
    for r in rows {
        s.push_str(&format!(
            "{},{},{:.6},{:.6}\n",
            r.alpha, r.order, r.rel_err_vs_target, r.rel_err_vs_std
        ));
    }
    s
}

/// Cross-check an attention artifact against the independent pure-rust
/// reference (`mathref`).  Returns max |diff|; used by the quickstart
/// example and integration tests to prove the whole AOT chain is faithful.
pub fn crosscheck_attention(
    runtime: &Runtime,
    artifact: &str,
    seed: u64,
    tol: f32,
) -> Result<f32> {
    let exe = runtime.load(artifact)?;
    let a = exe.artifact.clone();
    let kind = a
        .meta
        .get("kind")
        .and_then(|j| j.as_str())
        .unwrap_or("ho2")
        .to_string();
    let causal = a.meta.get("causal").and_then(|j| j.as_bool()).unwrap_or(true);
    let order = a.meta.get("order").and_then(|j| j.as_i64()).unwrap_or(2) as usize;
    let alpha = a.meta.get("alpha").and_then(|j| j.as_f64()).unwrap_or(3.0);

    let shape = a.inputs[0].shape.clone(); // (b, h, n, d)
    let (b, h, n, d) = (shape[0], shape[1], shape[2], shape[3]);
    let mut rng = Rng::new(seed);
    let count = b * h * n * d;
    let q = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));
    let k = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));
    let v = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));

    let out = exe.run(&[q.clone(), k.clone(), v.clone()])?.remove(0);
    let expect = mathref::attention_bhnd(
        &kind,
        q.as_f32()?,
        k.as_f32()?,
        v.as_f32()?,
        b * h,
        n,
        d,
        order,
        alpha,
        causal,
    );
    let expect_t = Tensor::f32(shape, expect);
    let err = out.max_abs_diff(&expect_t)?;
    anyhow::ensure!(
        err < tol,
        "artifact {artifact} disagrees with rust reference: max|diff| = {err} >= {tol}"
    );
    Ok(err)
}

/// Cross-check the native O(n) kernels — both evaluation strategies —
/// against the direct O(n²) `mathref` oracle, causal and non-causal.
/// The no-artifact twin of [`crosscheck_attention`]; returns the worst
/// max |diff| seen.  `kind` ∈ {"ho"/"ho2", "linear"} — for the Taylor
/// family every order 0–3 is swept (one generic φ-recurrence, the order
/// is just a config value), the elu+1 baseline has no order.  "softmax"
/// is rejected, because the native backend *is* the oracle there (no
/// linear-time form exists) and comparing it against itself would
/// always "pass".
pub fn crosscheck_native(kind: &str, seed: u64, tol: f32) -> Result<f32> {
    if kind == "softmax" {
        anyhow::bail!(
            "softmax has no independent native implementation (the backend falls back \
             to the oracle itself) — nothing to cross-check"
        );
    }
    let orders: &[usize] = if crate::model::is_ho(kind) { &[0, 1, 2, 3] } else { &[2] };
    let (bh, n, d) = (2, 96, 16);
    let mut rng = Rng::new(seed);
    let count = bh * n * d;
    let q = rng.normal_vec_f32(count, 1.0);
    let k = rng.normal_vec_f32(count, 1.0);
    let v = rng.normal_vec_f32(count, 1.0);
    let mut worst = 0.0f32;
    for &order in orders {
        for causal in [true, false] {
            let oracle = mathref::attention_bhnd(kind, &q, &k, &v, bh, n, d, order, 3.0, causal);
            for evaluation in [Evaluation::Streaming, Evaluation::Chunked] {
                let backend =
                    NativeBackend { evaluation, chunk: 17, order, ..NativeBackend::paper() };
                let out = backend.attention_bhnd(kind, &q, &k, &v, bh, n, d, causal)?;
                let err = out
                    .iter()
                    .zip(&oracle)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                anyhow::ensure!(
                    err < tol,
                    "native {kind} o{order} ({evaluation:?}, causal={causal}) disagrees \
                     with the O(n^2) oracle: max|diff| = {err} >= {tol}"
                );
                worst = worst.max(err);
            }
        }
    }
    Ok(worst)
}

/// Write a string to `results/<name>` (creating the directory).
pub fn write_results(dir: &Path, name: &str, content: &str) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_shape_and_anchor_points() {
        let csv = fig1_taylor_csv(7);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 8);
        // x = 0 row: everything equals 1
        let mid: Vec<f64> =
            lines[4].split(',').map(|s| s.parse().unwrap()).collect();
        assert_eq!(mid[0], 0.0);
        for v in &mid[1..] {
            assert!((v - 1.0).abs() < 1e-9);
        }
        // x = 3 row: order3 underestimates exp, order2 underestimates more
        let hi: Vec<f64> =
            lines[7].split(',').map(|s| s.parse().unwrap()).collect();
        assert!(hi[1] > hi[4] && hi[4] > hi[3] && hi[3] > hi[2]);
    }

    #[test]
    fn native_approx_quality_orders_correctly() {
        // E1's headline, computed with zero artifacts: higher Taylor order
        // => lower error vs the softmax target, for every alpha — now
        // including the order-3 point the paper never measured
        let rows = approx_quality_native(123, 64, 16).unwrap();
        assert_eq!(rows.len(), 16);
        for alpha in [1.0, 2.0, 3.0, 4.0] {
            let err = |o: usize| {
                rows.iter()
                    .find(|r| r.alpha == alpha && r.order == o)
                    .unwrap()
                    .rel_err_vs_target
            };
            assert!(err(3) < err(2), "alpha {alpha}: order3 !< order2");
            assert!(err(2) < err(1), "alpha {alpha}: order2 !< order1");
            assert!(err(1) < err(0), "alpha {alpha}: order1 !< order0");
        }
        // damping helps: the order-2 error shrinks as alpha grows
        let e2 = |a: f64| {
            rows.iter()
                .find(|r| r.alpha == a && r.order == 2)
                .unwrap()
                .rel_err_vs_target
        };
        assert!(e2(4.0) < e2(1.0));
    }

    #[test]
    fn native_crosscheck_all_kinds() {
        // "ho" sweeps Taylor orders 0-3 internally; "ho2" is the same
        // family (alias), so checking it separately would double the work
        for kind in ["ho", "linear"] {
            let err = crosscheck_native(kind, 7, 1e-4).unwrap();
            assert!(err < 1e-4, "{kind}: {err}");
        }
        // self-comparison is not a cross-check
        assert!(crosscheck_native("softmax", 7, 1e-4).is_err());
    }
}
