//! Minimal JSON parser/serializer.
//!
//! serde/serde_json are not in the offline vendor set, so the manifest and
//! metrics plumbing use this self-contained implementation.  It supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers, bools,
//! null) and preserves object insertion order (the manifest is
//! human-diffable that way).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}' in object"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Shape-like integer arrays ([2, 3, 4]) — common in the manifest.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|f| f as usize))
            .collect()
    }

    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience: build a Json object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// `Null`, so structs carrying an optional JSON payload can derive
/// `Default`.
impl Default for Json {
    fn default() -> Self {
        Json::Null
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => anyhow::bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if &self.b[self.i..self.i + 2] != b"\\u" {
                                    anyhow::bail!("lone high surrogate");
                                }
                                self.i += 2;
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                cp
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow::anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => anyhow::bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // copy a full utf-8 sequence
                    let len = utf8_len(c);
                    if len == 1 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        self.i = start + len;
                        s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Write one JSON value per line (metrics logs).
pub struct JsonlWriter {
    w: std::io::BufWriter<std::fs::File>,
}

impl JsonlWriter {
    pub fn create(path: &std::path::Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self {
            w: std::io::BufWriter::new(std::fs::File::create(path)?),
        })
    }

    /// Open for appending (resumed training runs keep their history).
    pub fn append(path: &std::path::Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(Self {
            w: std::io::BufWriter::new(
                std::fs::OpenOptions::new().create(true).append(true).open(path)?,
            ),
        })
    }

    pub fn write(&mut self, v: &Json) -> anyhow::Result<()> {
        use std::io::Write;
        writeln!(self.w, "{}", v.to_string())?;
        // metrics logs are low-frequency and users tail them live
        self.w.flush()?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        use std::io::Write;
        self.w.flush()?;
        Ok(())
    }
}

/// Group a flat list of (key, value) rows into a CSV string.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = header.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    s
}

#[allow(dead_code)]
pub type JsonMap = BTreeMap<String, Json>;

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": null, "d": true}"#)
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("c"), Some(&Json::Null));
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"[{"k": {"kk": [[]]}}, []]"#).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), 2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // non-ascii passthrough
        let v2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "héllo");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![2, 3, 4]);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }
}
