//! Deterministic PRNG — xoshiro256++ with splitmix64 seeding.
//!
//! The `rand` crate is not in the offline vendor set; this is the standard
//! xoshiro256++ generator (Blackman & Vigna) plus the distributions the
//! coordinator needs: uniform ints, standard normal (Box–Muller, cached
//! spare), categorical sampling from logits, and Fisher–Yates shuffle.
//! Everything in the system that uses randomness takes a seed, so runs are
//! exactly reproducible.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — unbiased via rejection.
    pub fn uniform_int(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        let span = hi - lo;
        let zone = u64::MAX - u64::MAX % span;
        loop {
            let r = self.next_u64();
            if r < zone {
                return lo + r % span;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form), cached spare.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Vector of normals scaled by `std`, as f32 (parameter init).
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_int(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized logits with temperature and
    /// optional top-k truncation (k = 0 means no truncation).
    /// temperature == 0.0 is greedy argmax.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32, top_k: usize) -> usize {
        assert!(!logits.is_empty());
        if temperature <= 0.0 {
            return argmax(logits);
        }
        // top-k filter: indices of the k largest logits (k=0 -> all)
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if top_k > 0 && top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
            idx.truncate(top_k);
        }
        let maxv = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - maxv) / temperature) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut r = self.uniform() * total;
        for (w, &i) in weights.iter().zip(&idx) {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        *idx.last().unwrap()
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(42);
        let mut s1 = a.split(1);
        let mut s2 = a.split(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            let k = r.uniform_int(5, 17);
            assert!((5..17).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn greedy_sampling() {
        let mut r = Rng::new(3);
        let logits = vec![0.1, 5.0, -2.0];
        assert_eq!(r.sample_logits(&logits, 0.0, 0), 1);
    }

    #[test]
    fn topk_restricts_support() {
        let mut r = Rng::new(3);
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        for _ in 0..200 {
            let s = r.sample_logits(&logits, 1.0, 2);
            assert!(s < 2, "sampled outside top-2");
        }
    }

    #[test]
    fn sampling_follows_distribution() {
        let mut r = Rng::new(11);
        let logits = vec![0.0, (3.0f32).ln()]; // p = [0.25, 0.75]
        let n = 40_000;
        let ones: usize = (0..n)
            .map(|_| r.sample_logits(&logits, 1.0, 0))
            .sum();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.02, "p {p}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
