//! Chunked prefill: absorb prompts through the recurrence in blocks.
//!
//! The old engine streamed one prompt token per engine step, so a P-token
//! prompt cost P engine steps before the first generated token (terrible
//! TTFT under load).  The recurrence doesn't care: absorbing k₁..kₚ is
//! the same state no matter how the sequence is sliced, so
//! [`Executor::absorb_slot`] folds a whole block of prompt tokens into a
//! slot's state in one call — `⌈P/chunk⌉` engine steps instead of `P`,
//! and the block runs through the same batched `block_qkv`/`block_finish`
//! halves as the full-sequence forward (better cache behavior than
//! one-row matmuls, and the per-token logits of interior prompt positions
//! are never computed at all).
//!
//! Token-for-token the absorbed state is bit-identical to the
//! token-at-a-time path (pinned in `rust/tests/serve_sched.rs`), so
//! chunking is purely a scheduling decision.

use anyhow::Result;

use crate::model::Executor;

/// Default prompt tokens absorbed per engine step — shared by
/// `ServeOpts::default()`, the `--prefill-chunk` flag default and the
/// generation path, so the three cannot drift apart.
pub const DEFAULT_PREFILL_CHUNK: usize = 64;

/// Chunked prompt absorption over an [`Executor`].
#[derive(Debug, Clone, Copy)]
pub struct Prefiller {
    chunk: usize,
}

impl Prefiller {
    /// `chunk` prompt tokens per engine step; 0/1 means token-at-a-time
    /// (the engine then routes prompts through the batched decode step).
    pub fn new(chunk: usize) -> Prefiller {
        Prefiller { chunk }
    }

    pub fn chunk(&self) -> usize {
        self.chunk
    }

    /// Whether this configuration does chunked absorption at all.
    pub fn chunked(&self) -> bool {
        self.chunk >= 2
    }

    /// Engine steps needed to absorb a `p`-token prompt.
    pub fn steps_for(&self, p: usize) -> usize {
        if self.chunked() {
            p.div_ceil(self.chunk)
        } else {
            p
        }
    }

    /// Absorb the next block of `prompt` into `slot`, advancing `*pos`
    /// and (when a recorder is given) appending the fed tokens to it —
    /// the serve engine tracks absorbed tokens for its session cache,
    /// the generation path doesn't need them.  Returns `Some(logits)` —
    /// the next-token logits at the final prompt position — once the
    /// prompt is fully absorbed, `None` while blocks remain.
    pub fn absorb_block(
        &self,
        exec: &mut (dyn Executor + '_),
        slot: usize,
        prompt: &[i32],
        pos: &mut usize,
        absorbed: Option<&mut Vec<i32>>,
    ) -> Result<Option<Vec<f32>>> {
        let take = (prompt.len() - *pos).min(self.chunk.max(1));
        let block = &prompt[*pos..*pos + take];
        let logits = exec.absorb_slot(slot, block)?;
        if let Some(absorbed) = absorbed {
            absorbed.extend_from_slice(block);
        }
        *pos += take;
        Ok(if *pos == prompt.len() { Some(logits) } else { None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_counts() {
        let p = Prefiller::new(64);
        assert!(p.chunked());
        assert_eq!(p.steps_for(1), 1);
        assert_eq!(p.steps_for(64), 1);
        assert_eq!(p.steps_for(65), 2);
        assert_eq!(p.steps_for(256), 4);
        let t = Prefiller::new(1);
        assert!(!t.chunked());
        assert_eq!(t.steps_for(256), 256);
        assert!(!Prefiller::new(0).chunked());
    }
}
