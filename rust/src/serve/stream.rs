//! Wire events: streaming deltas + the final response line.
//!
//! Every request's `respond` channel carries [`ServeEvent`]s.  A
//! non-streaming request receives exactly one `Done`; a `"stream": true`
//! request receives one `Delta` per generated token first.  On the TCP
//! front end the connection's writer thread serializes events with
//! [`event_json`]:
//!
//! ```text
//! {"id":7,"index":0,"token":104,"delta":"h"}      ← per token (stream)
//! {"id":7,"text":"hi","n_tokens":2,"ttft_s":..,"total_s":..}   ← final
//! {"id":8,"text":"","n_tokens":0,"ttft_s":-1,"total_s":-1,
//!  "error":"prompt (200) + max_tokens (64) exceeds model max_len (128)"}
//! ```
//!
//! The `error` field only appears on failures, so clients can
//! distinguish a rejected request from an empty completion (the old
//! protocol's `ttft_s: -1` sentinel is kept for compatibility).

use crate::json::{obj, Json};
use crate::serve::Response;

/// One engine → client event.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// One generated token of a streaming request.
    Delta { id: u64, index: usize, token_id: i32, text: String },
    /// The request finished (or failed — see [`Response::error`]).
    Done(Response),
    /// Reply to a `{"stats": true}` wire request: per-shard gauges +
    /// counters and the router's aggregate, pre-assembled by the router
    /// as one JSON object (serialized as a single line).
    Stats(Json),
}

/// Drain the longest cleanly-decodable UTF-8 prefix of `buf` (a
/// per-slot byte accumulator) as a String.  Byte-level models emit
/// multi-byte characters one byte per token; decoding each byte alone
/// would stream U+FFFD garbage that never matches the final text, so
/// the engine buffers bytes here and a delta's `text` stays empty until
/// its character completes.  A genuinely invalid byte is flushed lossily
/// rather than held forever; an incomplete trailing sequence is kept for
/// the next token (concatenated deltas are always a prefix of the final
/// `text`, which remains authoritative).
pub fn utf8_delta(buf: &mut Vec<u8>) -> String {
    let mut out = String::new();
    loop {
        match std::str::from_utf8(buf) {
            Ok(s) => {
                out.push_str(s);
                buf.clear();
                return out;
            }
            Err(e) => {
                let valid = e.valid_up_to();
                out.push_str(std::str::from_utf8(&buf[..valid]).expect("validated prefix"));
                match e.error_len() {
                    // incomplete trailing sequence: hold it for the next
                    // token (it may still complete into a character)
                    None => {
                        buf.drain(..valid);
                        return out;
                    }
                    // invalid sequence mid-buffer: replace exactly that
                    // maximal subpart — the same segmentation
                    // from_utf8_lossy uses for the final text — and keep
                    // scanning (a fresh lead byte after it stays held)
                    Some(bad) => {
                        out.push('\u{fffd}');
                        buf.drain(..valid + bad);
                    }
                }
            }
        }
    }
}

/// The final JSON line for a response.
pub fn response_json(resp: &Response) -> Json {
    let mut fields = vec![
        ("id", (resp.id as i64).into()),
        ("text", resp.text.as_str().into()),
        ("n_tokens", resp.token_ids.len().into()),
        ("ttft_s", resp.ttft_s.into()),
        ("total_s", resp.total_s.into()),
    ];
    if let Some(e) = &resp.error {
        fields.push(("error", e.as_str().into()));
    }
    obj(fields)
}

/// One wire line per event.
pub fn event_json(ev: &ServeEvent) -> Json {
    match ev {
        ServeEvent::Delta { id, index, token_id, text } => obj(vec![
            ("id", (*id as i64).into()),
            ("index", (*index).into()),
            ("token", (*token_id as i64).into()),
            ("delta", text.as_str().into()),
        ]),
        ServeEvent::Done(resp) => response_json(resp),
        ServeEvent::Stats(j) => j.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_line_omits_error_on_success() {
        let r = Response {
            id: 7,
            token_ids: vec![104, 105],
            text: "hi".into(),
            ttft_s: 0.25,
            total_s: 0.5,
            error: None,
        };
        let j = response_json(&r);
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(j.get("n_tokens").unwrap().as_i64().unwrap(), 2);
        assert!(j.get("error").is_none());
        // serialized line parses back
        let line = j.to_string();
        assert!(Json::parse(&line).unwrap().get("error").is_none());
    }

    #[test]
    fn error_line_is_distinguishable_on_the_wire() {
        let r = Response::error(8, "too big".into());
        let j = response_json(&r);
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "too big");
        assert_eq!(j.get("ttft_s").unwrap().as_f64().unwrap(), -1.0);
        assert_eq!(j.get("n_tokens").unwrap().as_i64().unwrap(), 0);
    }

    #[test]
    fn utf8_delta_holds_incomplete_sequences() {
        // 'é' = 0xC3 0xA9 arriving one byte per token
        let mut buf = Vec::new();
        buf.push(0xC3);
        assert_eq!(utf8_delta(&mut buf), "", "lead byte held, not replaced");
        assert_eq!(buf, vec![0xC3]);
        buf.push(0xA9);
        assert_eq!(utf8_delta(&mut buf), "é");
        assert!(buf.is_empty());
        // ascii streams through immediately
        buf.push(b'h');
        assert_eq!(utf8_delta(&mut buf), "h");
        // a valid prefix before an incomplete tail drains the prefix only
        buf.extend([b'a', 0xE2, 0x82]); // 'a' + 2/3 bytes of '€'
        assert_eq!(utf8_delta(&mut buf), "a");
        buf.push(0xAC);
        assert_eq!(utf8_delta(&mut buf), "€");
        // an invalid byte is flushed lossily instead of held forever
        buf.extend([0xFF, b'x']);
        assert_eq!(utf8_delta(&mut buf), "\u{fffd}x");
        assert!(buf.is_empty());
    }

    #[test]
    fn utf8_delta_invalid_flush_keeps_a_held_lead_byte() {
        // truncated '€' (0xE2 0x82) followed by 'é' (0xC3 0xA9), one
        // byte per token: the invalid subpart is replaced, but the 0xC3
        // lead byte after it must stay held — concat(deltas) must equal
        // from_utf8_lossy of the full byte sequence
        let mut buf = Vec::new();
        let mut streamed = String::new();
        for b in [0xE2u8, 0x82, 0xC3, 0xA9] {
            buf.push(b);
            streamed.push_str(&utf8_delta(&mut buf));
        }
        assert!(buf.is_empty());
        assert_eq!(streamed, String::from_utf8_lossy(&[0xE2, 0x82, 0xC3, 0xA9]));
        assert_eq!(streamed, "\u{fffd}é");
    }

    #[test]
    fn delta_lines_carry_index_and_text() {
        let ev = ServeEvent::Delta { id: 3, index: 5, token_id: 104, text: "h".into() };
        let j = event_json(&ev);
        assert_eq!(j.get("id").unwrap().as_i64().unwrap(), 3);
        assert_eq!(j.get("index").unwrap().as_i64().unwrap(), 5);
        assert_eq!(j.get("token").unwrap().as_i64().unwrap(), 104);
        assert_eq!(j.get("delta").unwrap().as_str().unwrap(), "h");
        assert!(j.get("text").is_none(), "deltas and finals are distinct shapes");
    }
}
