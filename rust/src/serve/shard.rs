//! Engine shards: one continuous-batching engine per core, each on its
//! own thread with its own slot pool, scheduler and **session cache
//! partition**.
//!
//! A shard is just the PR-4 [`Engine`] driven by an [`EngineMsg`] inbox
//! instead of a bare request channel: besides requests, the inbox
//! carries migration exports/imports (a session's few-KiB snapshot +
//! absorbed-token list changing partitions — the paper's O(1)-state
//! advantage makes this a constant-cost message, where a KV cache would
//! ship O(context)) and live stats probes.  The engine publishes its
//! load gauges ([`ShardLoad`]) after every loop iteration so the router
//! can place and shed work without locking any shard.
//!
//! [`Engine`]: crate::coordinator::server::Engine

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::coordinator::server::{Engine, ServeStats};
use crate::json::Json;
use crate::model::Executor;
use crate::serve::{Request, ServeOpts, SessionEntry};

/// One message into a shard's engine loop.
pub enum EngineMsg {
    /// A client request to schedule.
    Req(Request),
    /// Migration export: remove `id` from this shard's session cache and
    /// hand the entry back (`None` when the session is unknown or its
    /// current turn is still in flight — nothing cached to ship yet).
    /// `trace` is the router-minted id the flight recorder logs the
    /// `migrate_out` event under.
    Export { id: String, trace: u64, respond: Sender<Option<SessionEntry>> },
    /// Migration import: adopt a session exported from another shard
    /// (`trace`: same id as the paired export — one trace, two shards).
    Import { id: String, entry: SessionEntry, trace: u64 },
    /// Live per-shard stats as one JSON object.
    Stats { respond: Sender<Json> },
    /// Per-shard registry dump (counters, gauges, span histograms).
    Metrics { respond: Sender<Json> },
    /// Flight-recorder events for one trace id, oldest first (`id: 0` —
    /// no real trace is ever 0 — dumps the whole ring, the overload
    /// path).
    Trace { id: u64, respond: Sender<Json> },
}

/// Load gauges a shard's engine publishes every loop iteration; the
/// router reads them lock-free to place sessionless work, detect
/// saturation and enforce the global admission budget.
#[derive(Debug, Default)]
pub struct ShardLoad {
    /// fresh (never-run) waiters in the shard's queue
    pub queued: AtomicUsize,
    /// busy decode slots
    pub busy: AtomicUsize,
    /// sessions resident in the cache partition
    pub sessions: AtomicUsize,
}

/// Handle to a running shard: its inbox, its published load, and the
/// join handle that yields the final [`ServeStats`] at shutdown.
pub struct ShardHandle {
    pub id: usize,
    n_slots: usize,
    tx: Sender<EngineMsg>,
    pub load: Arc<ShardLoad>,
    join: JoinHandle<Result<ServeStats>>,
}

impl ShardHandle {
    /// Spawn shard `id`: the executor moves to a dedicated thread that
    /// builds and runs its own engine until every inbox sender drops.
    /// Shards of one router must be built from identically-initialized
    /// executors (same params) or migrated sessions would change model.
    pub fn spawn(
        id: usize,
        exec: Box<dyn Executor + Send>,
        seed: u64,
        opts: ServeOpts,
    ) -> Result<ShardHandle> {
        let n_slots = exec.n_slots();
        let (tx, rx) = channel();
        let load = Arc::new(ShardLoad::default());
        let published = load.clone();
        let join = std::thread::Builder::new()
            .name(format!("holt-shard-{id}"))
            .spawn(move || {
                let mut engine = Engine::with_opts(exec, seed, opts)?;
                engine.set_shard(id);
                engine.publish_load(published);
                engine.run_msgs(rx)
            })?;
        Ok(ShardHandle { id, n_slots, tx, load, join })
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Send into the shard's inbox; the message comes back if the shard
    /// thread has exited (so a request can be failed, not lost).
    pub fn send(&self, msg: EngineMsg) -> std::result::Result<(), EngineMsg> {
        self.tx.send(msg).map_err(|e| e.0)
    }

    pub fn queued(&self) -> usize {
        self.load.queued.load(Ordering::Relaxed)
    }

    pub fn busy(&self) -> usize {
        self.load.busy.load(Ordering::Relaxed)
    }

    pub fn sessions(&self) -> usize {
        self.load.sessions.load(Ordering::Relaxed)
    }

    /// Queue-first load ordering: a queued request waits a whole request
    /// service time, a busy slot only shares one step — so any queue
    /// depth dominates any slot occupancy when comparing shards.
    pub fn load_score(&self) -> usize {
        self.queued() * (self.n_slots.max(1) * 2) + self.busy()
    }

    /// Saturated: every slot busy *and* fresh work already waiting —
    /// the point where routing one more session there buys a full queue
    /// wait that a less-loaded shard would not charge.
    pub fn saturated(&self) -> bool {
        self.busy() >= self.n_slots && self.queued() > 0
    }

    /// Blocking migration export round trip (served within one engine
    /// step). `None`: session unknown/in-flight, or the shard died.
    pub fn export_session(&self, id: &str, trace: u64) -> Option<SessionEntry> {
        let (rtx, rrx) = channel();
        if self.send(EngineMsg::Export { id: id.to_string(), trace, respond: rtx }).is_err() {
            return None;
        }
        rrx.recv().ok().flatten()
    }

    /// Hand an exported session entry to this shard's cache partition.
    pub fn import_session(&self, id: &str, entry: SessionEntry, trace: u64) -> bool {
        self.send(EngineMsg::Import { id: id.to_string(), entry, trace }).is_ok()
    }

    /// Live stats round trip; `None` if the shard died.
    pub fn stats(&self) -> Option<Json> {
        let (rtx, rrx) = channel();
        if self.send(EngineMsg::Stats { respond: rtx }).is_err() {
            return None;
        }
        rrx.recv().ok()
    }

    /// Per-shard registry dump round trip; `None` if the shard died.
    pub fn metrics(&self) -> Option<Json> {
        let (rtx, rrx) = channel();
        if self.send(EngineMsg::Metrics { respond: rtx }).is_err() {
            return None;
        }
        rrx.recv().ok()
    }

    /// Flight-recorder events for `trace` on this shard (a JSON array,
    /// possibly empty); `None` if the shard died.
    pub fn trace(&self, trace: u64) -> Option<Json> {
        let (rtx, rrx) = channel();
        if self.send(EngineMsg::Trace { id: trace, respond: rtx }).is_err() {
            return None;
        }
        rrx.recv().ok()
    }

    /// Close the inbox and wait for the engine to drain and exit.
    pub fn finish(self) -> Result<ServeStats> {
        let ShardHandle { id, tx, join, .. } = self;
        drop(tx);
        join.join().map_err(|_| anyhow!("shard {id} thread panicked"))?
    }
}
