//! Session router over engine shards: hash affinity, snapshot
//! migration, and global admission control.
//!
//! Placement rules, in order:
//!
//! 1. **Global admission.** If fresh waiters across all shards reach the
//!    [`RouterOpts::global_queue`] budget, the request is shed with an
//!    explicit `overloaded` error line — before it can bury any shard's
//!    queue (each shard still enforces its own per-queue bound).
//! 2. **Session affinity.** A `session_id` is owned by exactly one shard
//!    at a time: its FNV-1a hash home, unless the router has re-homed it
//!    ([`Affinity`] tracks only those overrides).  Same id → same shard,
//!    so follow-up turns find their cached snapshot.
//! 3. **Migration.** When the home shard is saturated and a strictly
//!    less-loaded shard exists, the router ships the session's cached
//!    [`SessionEntry`] — the few-KiB O(1) snapshot plus its absorbed
//!    tokens, the same park format PR 4's preemption uses, bit-exact —
//!    from home to target and re-homes the session there.  A session
//!    whose turn is still in flight has nothing cached yet; it is
//!    re-homed without a shipment and simply re-prefills on the target
//!    (slower, never wrong).
//! 4. **Sessionless spread.** Requests without a `session_id` go to the
//!    least-loaded shard (round-robin among ties).
//!
//! The router runs single-threaded in front of the shard inboxes — one
//! owner for the affinity map, so "no session owned by two shards" holds
//! by construction (property-tested in `rust/tests/proptests.rs`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};

use anyhow::{ensure, Result};

use crate::coordinator::server::ServeStats;
use crate::json::{obj, Json, JsonlWriter};
use crate::model::Executor;
use crate::obs;
use crate::serve::shard::{EngineMsg, ShardHandle};
use crate::serve::{Request, Response, ServeEvent, ServeOpts};

/// Re-homed sessions tracked before the oldest overrides are dropped.
/// A dropped override just falls back to the hash home — worst case one
/// session-cache miss, never a correctness issue — so the map stays
/// bounded against wire-controlled session-id churn.
pub const MAX_AFFINITY_OVERRIDES: usize = 4096;

/// Router knobs (per-shard knobs live in [`ServeOpts`]).
#[derive(Debug, Clone)]
pub struct RouterOpts {
    /// Global fresh-waiter budget across all shards; at or above it new
    /// requests are shed with an `overloaded` error.
    pub global_queue: usize,
    /// `--metrics-log PATH`: append JSONL snapshots of the router line,
    /// overload flight-recorder dumps, and the final per-shard registry
    /// dumps here.
    pub metrics_log: Option<PathBuf>,
}

impl Default for RouterOpts {
    fn default() -> Self {
        RouterOpts { global_queue: 4096, metrics_log: None }
    }
}

/// One message into the router loop.
pub enum RouterMsg {
    /// Route a request to a shard (or shed it).
    Req(Request),
    /// `{"stats": true}` wire probe: reply with one JSON line of
    /// per-shard + aggregate stats on the request's event channel.
    Stats { respond: Sender<ServeEvent> },
    /// `{"metrics": true}` wire probe: per-shard registry dumps
    /// (counters, gauges, span histograms) as one JSON line.
    Metrics { respond: Sender<ServeEvent> },
    /// `{"trace": id}` wire probe: that trace's flight-recorder events
    /// across all shards, time-ordered, as one JSON line.
    Trace { id: u64, respond: Sender<ServeEvent> },
}

/// FNV-1a — a fixed, seedless hash so session → shard assignment is
/// deterministic across runs, processes and the affinity proptest.
pub fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The session → shard assignment: FNV-1a hash by default, plus a
/// bounded map of migration overrides.  Single-owner by construction —
/// `home` is a function, so a session id can never resolve to two
/// shards at once.
pub struct Affinity {
    n_shards: usize,
    capacity: usize,
    tick: u64,
    /// only re-homed sessions need an entry (hash homes are implicit)
    overrides: HashMap<String, (u64, usize)>,
}

impl Affinity {
    pub fn new(n_shards: usize) -> Affinity {
        Affinity::with_capacity(n_shards, MAX_AFFINITY_OVERRIDES)
    }

    pub fn with_capacity(n_shards: usize, capacity: usize) -> Affinity {
        assert!(n_shards > 0, "affinity over zero shards");
        Affinity { n_shards, capacity, tick: 0, overrides: HashMap::new() }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// The shard that owns `sid` right now.
    pub fn home(&self, sid: &str) -> usize {
        match self.overrides.get(sid) {
            Some(&(_, shard)) => shard,
            None => self.hash_home(sid),
        }
    }

    /// The default (pre-migration) assignment.
    pub fn hash_home(&self, sid: &str) -> usize {
        (fnv1a(sid) % self.n_shards as u64) as usize
    }

    /// Move `sid`'s ownership to `shard`.  Re-homing back to the hash
    /// home erases the override instead of storing a redundant one.
    pub fn rehome(&mut self, sid: &str, shard: usize) {
        assert!(shard < self.n_shards, "rehome to unknown shard {shard}");
        if shard == self.hash_home(sid) {
            self.overrides.remove(sid);
            return;
        }
        self.tick += 1;
        self.overrides.insert(sid.to_string(), (self.tick, shard));
        while self.overrides.len() > self.capacity {
            let oldest = self
                .overrides
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
                .expect("map is non-empty");
            self.overrides.remove(&oldest);
        }
    }

    /// Live override count (≤ the construction capacity).
    pub fn overrides(&self) -> usize {
        self.overrides.len()
    }
}

/// Aggregate counters the router itself owns (shard engines keep their
/// own [`ServeStats`]).
#[derive(Debug, Default, Clone)]
pub struct RouterReport {
    /// session entries actually shipped between cache partitions
    pub migrations: u64,
    /// requests shed by global admission (or a dead shard)
    pub rejected: u64,
}

/// The session router over N engine shards.
pub struct Router {
    shards: Vec<ShardHandle>,
    affinity: Affinity,
    opts: RouterOpts,
    report: RouterReport,
    rr: usize,
    /// Next trace id to mint (sequential from 1, deterministic — the
    /// trace-propagation test depends on knowing the ids in advance).
    next_trace: u64,
    routed: u64,
    metrics_writer: Option<JsonlWriter>,
}

impl Router {
    /// Spawn one shard per executor.  All executors must hold identical
    /// parameters (same checkpoint / init seed) — migration assumes a
    /// snapshot restores onto the same model bit-exactly.
    pub fn new(
        execs: Vec<Box<dyn Executor + Send>>,
        seed: u64,
        opts: ServeOpts,
        ropts: RouterOpts,
    ) -> Result<Router> {
        ensure!(!execs.is_empty(), "router needs at least one shard");
        let n = execs.len();
        let mut shards = Vec::with_capacity(n);
        for (i, exec) in execs.into_iter().enumerate() {
            // distinct sampling seeds per shard; params are the caller's
            shards.push(ShardHandle::spawn(i, exec, seed.wrapping_add(i as u64), opts.clone())?);
        }
        let metrics_writer = match &ropts.metrics_log {
            Some(path) => Some(JsonlWriter::create(path)?),
            None => None,
        };
        Ok(Router {
            shards,
            affinity: Affinity::new(n),
            opts: ropts,
            report: RouterReport::default(),
            rr: 0,
            next_trace: 0,
            routed: 0,
            metrics_writer,
        })
    }

    /// Mint the next trace id (sequential from 1).
    fn mint_trace(&mut self) -> u64 {
        self.next_trace += 1;
        self.next_trace
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn report(&self) -> &RouterReport {
        &self.report
    }

    /// The shard currently owning `sid`.
    pub fn shard_of(&self, sid: &str) -> usize {
        self.affinity.home(sid)
    }

    fn queued_total(&self) -> usize {
        self.shards.iter().map(|s| s.queued()).sum()
    }

    /// Least-loaded shard by [`ShardHandle::load_score`], rotating the
    /// scan start so equally-idle shards share sessionless load.
    fn least_loaded(&mut self, exclude: Option<usize>) -> usize {
        self.rr = (self.rr + 1) % self.shards.len();
        let n = self.shards.len();
        let mut best: Option<(usize, usize)> = None;
        for off in 0..n {
            let i = (self.rr + off) % n;
            if Some(i) == exclude {
                continue;
            }
            let score = self.shards[i].load_score();
            if best.is_none_or(|(b, _)| score < b) {
                best = Some((score, i));
            }
        }
        best.map(|(_, i)| i).unwrap_or(0)
    }

    /// Migrate `sid` from its current home to shard `to`: export the
    /// cached entry (if any), import it on the target, re-home.  Returns
    /// whether an entry actually shipped.  Public as the deterministic
    /// hook the bit-exactness tests drive directly.
    pub fn migrate(&mut self, sid: &str, to: usize) -> bool {
        let from = self.affinity.home(sid);
        if from == to || to >= self.shards.len() {
            return false;
        }
        // one trace id covers both halves of the shipment: the source
        // shard logs `migrate_out` and the target `migrate_in` under it
        let trace = self.mint_trace();
        let shipped = match self.shards[from].export_session(sid, trace) {
            Some(entry) => {
                let ok = self.shards[to].import_session(sid, entry, trace);
                if ok {
                    self.report.migrations += 1;
                }
                ok
            }
            // nothing cached yet (unknown session, or its turn is still
            // in flight) — future turns still move to the new home and
            // re-prefill there
            None => false,
        };
        self.affinity.rehome(sid, to);
        shipped
    }

    /// Admission control + placement for one request.
    pub fn route(&mut self, mut req: Request) {
        if req.trace == 0 {
            req.trace = self.mint_trace();
        }
        self.routed += 1;
        if self.routed % 256 == 0 {
            self.log_router_line("periodic");
        }
        let waiting = self.queued_total();
        if waiting >= self.opts.global_queue {
            self.report.rejected += 1;
            self.dump_on_overload();
            let msg = format!(
                "server overloaded: {waiting} requests already waiting across {} shards",
                self.shards.len()
            );
            let _ = req.respond.send(ServeEvent::Done(Response::error(req.id, msg)));
            return;
        }
        let target = match req.session_id.as_deref() {
            Some(sid) => {
                let home = self.affinity.home(sid);
                if self.shards[home].saturated() {
                    let alt = self.least_loaded(Some(home));
                    if self.shards[alt].load_score() < self.shards[home].load_score() {
                        self.migrate(sid, alt);
                        alt
                    } else {
                        home
                    }
                } else {
                    home
                }
            }
            None => self.least_loaded(None),
        };
        if let Err(EngineMsg::Req(req)) = self.shards[target].send(EngineMsg::Req(req)) {
            self.report.rejected += 1;
            let _ = req.respond.send(ServeEvent::Done(Response::error(
                req.id,
                format!("shard {target} unavailable"),
            )));
        }
    }

    /// One JSON object: router counters + per-shard live stats — the
    /// reply to a `{"stats": true}` wire request.
    pub fn stats_json(&self) -> Json {
        let per_shard: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                s.stats()
                    .unwrap_or_else(|| obj(vec![("error", "shard unavailable".into())]))
            })
            .collect();
        obj(vec![
            ("stats", true.into()),
            ("shards", self.shards.len().into()),
            ("queued_total", self.queued_total().into()),
            ("affinity_overrides", self.affinity.overrides().into()),
            ("migrations", (self.report.migrations as i64).into()),
            ("router_rejected", (self.report.rejected as i64).into()),
            ("per_shard", Json::Arr(per_shard)),
        ])
    }

    /// `{"metrics": true}` reply: per-shard registry dumps plus the
    /// router's own counters, one JSON object.
    pub fn metrics_json(&self) -> Json {
        let per_shard: Vec<Json> = self
            .shards
            .iter()
            .map(|s| {
                s.metrics()
                    .unwrap_or_else(|| obj(vec![("error", "shard unavailable".into())]))
            })
            .collect();
        obj(vec![
            ("metrics", true.into()),
            ("t_us", (obs::since_epoch_us() as i64).into()),
            ("shards", self.shards.len().into()),
            ("routed", (self.routed as i64).into()),
            ("traces_minted", (self.next_trace as i64).into()),
            ("migrations", (self.report.migrations as i64).into()),
            ("router_rejected", (self.report.rejected as i64).into()),
            ("per_shard", Json::Arr(per_shard)),
        ])
    }

    /// `{"trace": id}` reply: that trace's flight-recorder events from
    /// every shard, merged and sorted by the shared-epoch timestamp —
    /// one coherent cross-shard timeline.
    pub fn trace_json(&self, id: u64) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for s in &self.shards {
            if let Some(Json::Arr(evs)) = s.trace(id) {
                events.extend(evs);
            }
        }
        // same-µs events from different shards have no timestamp order
        // (per-shard `seq` doesn't compare across shards), so break ties
        // by lifecycle rank — e.g. a migration's export logs before its
        // import even when both land in the same microsecond
        let rank = |name: Option<&str>| match name {
            Some("admit") => 0i64,
            Some("resume") => 1,
            Some("park") => 2,
            Some("migrate_out") => 3,
            Some("migrate_in") => 4,
            Some("reject") => 5,
            Some("finish") => 6,
            _ => 7,
        };
        events.sort_by_key(|e| {
            (
                e.get("t_us").and_then(Json::as_i64).unwrap_or(0),
                rank(e.get("event").and_then(Json::as_str)),
                e.get("seq").and_then(Json::as_i64).unwrap_or(0),
            )
        });
        obj(vec![
            ("trace", (id as i64).into()),
            ("found", (!events.is_empty()).into()),
            ("events", Json::Arr(events)),
        ])
    }

    /// One light JSONL line (lock-free gauge reads only — no shard round
    /// trips) into the metrics log.
    fn log_router_line(&mut self, event: &str) {
        if let Some(w) = self.metrics_writer.as_mut() {
            let line = obj(vec![
                ("event", event.into()),
                ("t_us", (obs::since_epoch_us() as i64).into()),
                ("routed", (self.routed as i64).into()),
                ("queued_total", self.shards.iter().map(|s| s.queued()).sum::<usize>().into()),
                ("busy_total", self.shards.iter().map(|s| s.busy()).sum::<usize>().into()),
                ("migrations", (self.report.migrations as i64).into()),
                ("rejected", (self.report.rejected as i64).into()),
            ]);
            let _ = w.write(&line);
        }
    }

    /// On overload sheds, dump every shard's flight-recorder ring to the
    /// metrics log — rate-limited so a shed storm logs the first event
    /// and then one dump per 128 sheds.
    fn dump_on_overload(&mut self) {
        if self.metrics_writer.is_none() || self.report.rejected % 128 != 1 {
            return;
        }
        let rings: Vec<Json> = self
            .shards
            .iter()
            .map(|s| s.trace(0).unwrap_or(Json::Null))
            .collect();
        if let Some(w) = self.metrics_writer.as_mut() {
            let line = obj(vec![
                ("event", "overload_flight_dump".into()),
                ("t_us", (obs::since_epoch_us() as i64).into()),
                ("rejected", (self.report.rejected as i64).into()),
                ("flight", Json::Arr(rings)),
            ]);
            let _ = w.write(&line);
        }
    }

    /// Handle one router message.
    pub fn handle(&mut self, msg: RouterMsg) {
        match msg {
            RouterMsg::Req(req) => self.route(req),
            RouterMsg::Stats { respond } => {
                let _ = respond.send(ServeEvent::Stats(self.stats_json()));
            }
            RouterMsg::Metrics { respond } => {
                let _ = respond.send(ServeEvent::Stats(self.metrics_json()));
            }
            RouterMsg::Trace { id, respond } => {
                let _ = respond.send(ServeEvent::Stats(self.trace_json(id)));
            }
        }
    }

    /// Consume the inbox until every sender drops, then shut the shards
    /// down and return their final stats.
    pub fn run(mut self, rx: Receiver<RouterMsg>) -> Result<(Vec<ServeStats>, RouterReport)> {
        for msg in rx {
            self.handle(msg);
        }
        self.log_router_line("final");
        let mut log = self.metrics_writer.take();
        let (per_shard, report) = self.finish()?;
        if let Some(w) = log.as_mut() {
            // final per-shard registry dumps, one line per shard
            for (i, s) in per_shard.iter().enumerate() {
                let line = obj(vec![
                    ("event", "shard_final".into()),
                    ("shard", i.into()),
                    ("metrics", s.metrics.clone()),
                ]);
                let _ = w.write(&line);
            }
            let _ = w.flush();
        }
        Ok((per_shard, report))
    }

    /// Close every shard inbox, join the engines, return final stats.
    pub fn finish(self) -> Result<(Vec<ServeStats>, RouterReport)> {
        let Router { shards, report, .. } = self;
        let mut per_shard = Vec::with_capacity(shards.len());
        for s in shards {
            per_shard.push(s.finish()?);
        }
        Ok((per_shard, report))
    }
}
