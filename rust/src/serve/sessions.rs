//! Byte-budgeted LRU session cache over O(1)-state snapshots.
//!
//! When a request carries a `session_id`, the engine retains its final
//! decode state ([`SessionSnapshot`], a few KiB — constant in history
//! length, which is what makes caching *every* finished conversation
//! affordable) together with the exact token sequence that state has
//! absorbed.  A follow-up request on the same session whose prompt
//! extends that history (client sends the full conversation, as chat
//! protocols do) restores the snapshot and prefills only the new suffix
//! — the whole shared prefix is never recomputed.
//!
//! The restored path is bit-identical to a from-scratch full-history
//! prefill when the snapshot dtype is lossless (pinned in
//! `rust/tests/serve_sched.rs`); narrow dtypes (`--state-dtype f16`,
//! …) trade bounded drift for more resident sessions per byte.
//!
//! The cache is bounded by *bytes*, not entries (`--session-cache-mb`):
//! the binding constraint on resident sessions is memory, and encoded
//! snapshot sizes vary 8× across dtypes, so an entry count would either
//! waste the budget or blow it.  Least-recently-used eviction (lookup
//! hits and inserts both refresh recency); an entry larger than the
//! whole budget is never cached.

use std::collections::HashMap;

use crate::model::SessionSnapshot;

/// A finished request's resumable state.
#[derive(Debug, Clone)]
pub struct SessionEntry {
    /// Final decode state (all (layer, head) kernel states + position),
    /// encoded in the engine's configured
    /// [`StateDtype`](crate::state::StateDtype).
    pub snapshot: SessionSnapshot,
    /// Exactly the tokens that state has absorbed, in order — the
    /// reusable-prefix check compares a follow-up prompt against this.
    pub tokens: Vec<i32>,
}

impl SessionEntry {
    /// Resident footprint in bytes (encoded snapshot + token history) —
    /// the unit the cache budget accounts in.
    pub fn bytes(&self) -> usize {
        self.snapshot.bytes() + self.tokens.len() * std::mem::size_of::<i32>()
    }
}

/// `session_id` → [`SessionEntry`], LRU-bounded by total bytes.
pub struct SessionCache {
    budget: usize,
    used: usize,
    tick: u64,
    map: HashMap<String, (u64, SessionEntry)>,
}

impl SessionCache {
    /// `budget` = resident-byte bound across all entries; 0 disables the
    /// cache (every lookup misses, inserts are dropped).
    pub fn new(budget: usize) -> SessionCache {
        SessionCache { budget, used: 0, tick: 0, map: HashMap::new() }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently resident (always ≤ budget).
    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// A usable hit: the session exists *and* its absorbed tokens are a
    /// strict prefix of `prompt` (strict — at least one new token must be
    /// absorbed to produce next-token logits).  Hits refresh LRU recency.
    pub fn lookup(&mut self, id: &str, prompt: &[i32]) -> Option<&SessionEntry> {
        self.tick += 1;
        let tick = self.tick;
        let (last_use, entry) = self.map.get_mut(id)?;
        if entry.tokens.len() < prompt.len() && prompt[..entry.tokens.len()] == entry.tokens[..] {
            *last_use = tick;
            Some(&*entry)
        } else {
            None
        }
    }

    /// Remove and return the session's entry — the migration export: the
    /// home shard gives up ownership before the entry is shipped to
    /// another shard's cache, so a session is never resident in two
    /// partitions at once.
    pub fn remove(&mut self, id: &str) -> Option<SessionEntry> {
        let (_, entry) = self.map.remove(id)?;
        self.used -= entry.bytes();
        Some(entry)
    }

    /// Insert/replace the session's entry, evicting least-recently-used
    /// entries until the byte budget holds.  An entry that alone exceeds
    /// the whole budget is not cached (the alternative — evicting
    /// everything and still failing — helps nobody).
    pub fn insert(&mut self, id: String, entry: SessionEntry) {
        let bytes = entry.bytes();
        if bytes > self.budget {
            // also drop any stale entry under this id: the caller's
            // newest state is unretainable, so serving the old one on a
            // future lookup would silently rewind the session
            self.remove(&id);
            return;
        }
        self.tick += 1;
        if let Some((_, old)) = self.map.insert(id, (self.tick, entry)) {
            self.used -= old.bytes();
        }
        self.used += bytes;
        while self.used > self.budget {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
                .expect("over budget implies non-empty");
            self.remove(&oldest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: Vec<i32>) -> SessionEntry {
        SessionEntry { snapshot: SessionSnapshot::default(), tokens }
    }

    /// Resident bytes of a one-token entry — the unit the budget tests
    /// count in (entries with equal token counts have equal footprints).
    fn unit() -> usize {
        entry(vec![0]).bytes()
    }

    #[test]
    fn hit_requires_strict_prefix() {
        let mut c = SessionCache::new(4 * unit());
        c.insert("s".into(), entry(vec![257, 1, 2]));
        assert!(c.lookup("s", &[257, 1, 2, 3]).is_some(), "strict prefix hits");
        assert!(c.lookup("s", &[257, 1, 2]).is_none(), "identical prompt has no new token");
        assert!(c.lookup("s", &[257, 9, 2, 3]).is_none(), "diverged history misses");
        assert!(c.lookup("s", &[257]).is_none(), "shorter prompt misses");
        assert!(c.lookup("t", &[257, 1, 2, 3]).is_none(), "unknown id misses");
    }

    #[test]
    fn byte_budget_evicts_least_recently_used() {
        // budget fits exactly two one-token entries
        let mut c = SessionCache::new(2 * unit());
        c.insert("a".into(), entry(vec![1]));
        c.insert("b".into(), entry(vec![2]));
        assert_eq!(c.used_bytes(), 2 * unit());
        // touch a so b becomes the LRU entry
        assert!(c.lookup("a", &[1, 9]).is_some());
        c.insert("c".into(), entry(vec![3]));
        assert_eq!(c.len(), 2);
        assert_eq!(c.used_bytes(), 2 * unit(), "eviction must release bytes");
        assert!(c.lookup("b", &[2, 9]).is_none(), "b was evicted");
        assert!(c.lookup("a", &[1, 9]).is_some());
        assert!(c.lookup("c", &[3, 9]).is_some());
    }

    #[test]
    fn one_large_entry_displaces_many_small() {
        // the byte budget is the invariant, not an entry count: a
        // 3-token entry costs more than a 1-token one, so inserting it
        // evicts as many old entries as its footprint requires
        let mut c = SessionCache::new(entry(vec![1]).bytes() + entry(vec![1, 2, 3]).bytes());
        c.insert("a".into(), entry(vec![1]));
        c.insert("b".into(), entry(vec![2]));
        assert_eq!(c.len(), 2);
        c.insert("big".into(), entry(vec![7, 8, 9]));
        assert_eq!(c.len(), 2, "one small entry had to go");
        assert!(c.lookup("a", &[1, 9]).is_none(), "a was the LRU entry");
        assert!(c.lookup("b", &[2, 9]).is_some());
        assert!(c.lookup("big", &[7, 8, 9, 1]).is_some());
        assert!(c.used_bytes() <= c.budget());
    }

    #[test]
    fn reinsert_replaces_and_refreshes() {
        let mut c = SessionCache::new(2 * entry(vec![1, 5]).bytes());
        c.insert("a".into(), entry(vec![1]));
        c.insert("b".into(), entry(vec![2]));
        c.insert("a".into(), entry(vec![1, 5]));
        assert_eq!(c.len(), 2);
        assert_eq!(
            c.used_bytes(),
            entry(vec![1]).bytes() + entry(vec![1, 5]).bytes(),
            "replacement must release the old entry's bytes"
        );
        let hit = c.lookup("a", &[1, 5, 9]).unwrap();
        assert_eq!(hit.tokens, vec![1, 5]);
        c.insert("d".into(), entry(vec![4]));
        assert!(c.lookup("b", &[2, 9]).is_none(), "b was the LRU entry");
    }

    #[test]
    fn remove_exports_exactly_once() {
        let mut c = SessionCache::new(4 * unit());
        c.insert("a".into(), entry(vec![1, 2]));
        let before = c.used_bytes();
        assert!(before > 0);
        let got = c.remove("a").expect("entry present");
        assert_eq!(got.tokens, vec![1, 2]);
        assert_eq!(c.used_bytes(), 0, "export must release {before} bytes");
        assert!(c.remove("a").is_none(), "second export finds nothing");
        assert!(c.lookup("a", &[1, 2, 3]).is_none(), "ownership was given up");
    }

    #[test]
    fn oversized_entry_is_not_cached_and_drops_stale_state() {
        let mut c = SessionCache::new(unit());
        c.insert("a".into(), entry(vec![1]));
        assert_eq!(c.len(), 1);
        // a newer state for the same session that no longer fits must
        // not leave the stale small entry behind
        c.insert("a".into(), entry(vec![1, 2, 3, 4, 5, 6, 7, 8]));
        assert!(c.is_empty(), "unretainable update must also drop the stale entry");
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn zero_budget_disables() {
        let mut c = SessionCache::new(0);
        c.insert("a".into(), entry(vec![1]));
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert!(c.lookup("a", &[1, 2]).is_none());
    }
}
