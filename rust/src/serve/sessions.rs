//! Bounded LRU session cache over O(1)-state snapshots.
//!
//! When a request carries a `session_id`, the engine retains its final
//! decode state ([`SessionSnapshot`], a few KiB — constant in history
//! length, which is what makes caching *every* finished conversation
//! affordable) together with the exact token sequence that state has
//! absorbed.  A follow-up request on the same session whose prompt
//! extends that history (client sends the full conversation, as chat
//! protocols do) restores the snapshot and prefills only the new suffix
//! — the whole shared prefix is never recomputed.
//!
//! The restored path is bit-identical to a from-scratch full-history
//! prefill (pinned ≤ 1e-4 in `rust/tests/serve_sched.rs`): the snapshot
//! is an exact serialization of the recurrent state, not an
//! approximation.
//!
//! The cache is strictly bounded: `capacity` entries, least-recently-used
//! eviction (lookup hits and inserts both refresh recency).

use std::collections::HashMap;

use crate::model::SessionSnapshot;

/// A finished request's resumable state.
#[derive(Debug, Clone)]
pub struct SessionEntry {
    /// Final decode state (all (layer, head) kernel states + position).
    pub snapshot: SessionSnapshot,
    /// Exactly the tokens that state has absorbed, in order — the
    /// reusable-prefix check compares a follow-up prompt against this.
    pub tokens: Vec<i32>,
}

/// `session_id` → [`SessionEntry`], LRU-bounded.
pub struct SessionCache {
    capacity: usize,
    tick: u64,
    map: HashMap<String, (u64, SessionEntry)>,
}

impl SessionCache {
    /// `capacity` = 0 disables the cache (every lookup misses, inserts
    /// are dropped).
    pub fn new(capacity: usize) -> SessionCache {
        SessionCache { capacity, tick: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// A usable hit: the session exists *and* its absorbed tokens are a
    /// strict prefix of `prompt` (strict — at least one new token must be
    /// absorbed to produce next-token logits).  Hits refresh LRU recency.
    pub fn lookup(&mut self, id: &str, prompt: &[i32]) -> Option<&SessionEntry> {
        self.tick += 1;
        let tick = self.tick;
        let (last_use, entry) = self.map.get_mut(id)?;
        if entry.tokens.len() < prompt.len() && prompt[..entry.tokens.len()] == entry.tokens[..] {
            *last_use = tick;
            Some(&*entry)
        } else {
            None
        }
    }

    /// Remove and return the session's entry — the migration export: the
    /// home shard gives up ownership before the entry is shipped to
    /// another shard's cache, so a session is never resident in two
    /// partitions at once.
    pub fn remove(&mut self, id: &str) -> Option<SessionEntry> {
        self.map.remove(id).map(|(_, entry)| entry)
    }

    /// Insert/replace the session's entry, evicting the least recently
    /// used entry when over capacity.
    pub fn insert(&mut self, id: String, entry: SessionEntry) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(id, (self.tick, entry));
        while self.map.len() > self.capacity {
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
                .expect("map is non-empty");
            self.map.remove(&oldest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: Vec<i32>) -> SessionEntry {
        SessionEntry { snapshot: SessionSnapshot::default(), tokens }
    }

    #[test]
    fn hit_requires_strict_prefix() {
        let mut c = SessionCache::new(4);
        c.insert("s".into(), entry(vec![257, 1, 2]));
        assert!(c.lookup("s", &[257, 1, 2, 3]).is_some(), "strict prefix hits");
        assert!(c.lookup("s", &[257, 1, 2]).is_none(), "identical prompt has no new token");
        assert!(c.lookup("s", &[257, 9, 2, 3]).is_none(), "diverged history misses");
        assert!(c.lookup("s", &[257]).is_none(), "shorter prompt misses");
        assert!(c.lookup("t", &[257, 1, 2, 3]).is_none(), "unknown id misses");
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = SessionCache::new(2);
        c.insert("a".into(), entry(vec![1]));
        c.insert("b".into(), entry(vec![2]));
        // touch a so b becomes the LRU entry
        assert!(c.lookup("a", &[1, 9]).is_some());
        c.insert("c".into(), entry(vec![3]));
        assert_eq!(c.len(), 2);
        assert!(c.lookup("b", &[2, 9]).is_none(), "b was evicted");
        assert!(c.lookup("a", &[1, 9]).is_some());
        assert!(c.lookup("c", &[3, 9]).is_some());
    }

    #[test]
    fn reinsert_replaces_and_refreshes() {
        let mut c = SessionCache::new(2);
        c.insert("a".into(), entry(vec![1]));
        c.insert("b".into(), entry(vec![2]));
        c.insert("a".into(), entry(vec![1, 5]));
        assert_eq!(c.len(), 2);
        let hit = c.lookup("a", &[1, 5, 9]).unwrap();
        assert_eq!(hit.tokens, vec![1, 5]);
        c.insert("d".into(), entry(vec![4]));
        assert!(c.lookup("b", &[2, 9]).is_none(), "b was the LRU entry");
    }

    #[test]
    fn remove_exports_exactly_once() {
        let mut c = SessionCache::new(4);
        c.insert("a".into(), entry(vec![1, 2]));
        let got = c.remove("a").expect("entry present");
        assert_eq!(got.tokens, vec![1, 2]);
        assert!(c.remove("a").is_none(), "second export finds nothing");
        assert!(c.lookup("a", &[1, 2, 3]).is_none(), "ownership was given up");
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = SessionCache::new(0);
        c.insert("a".into(), entry(vec![1]));
        assert!(c.is_empty());
        assert!(c.lookup("a", &[1, 2]).is_none());
    }
}
