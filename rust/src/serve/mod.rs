//! `serve/` — the scheduling subsystem behind the continuous-batching
//! engine.
//!
//! The paper's serving claim (via Katharopoulos et al., "Transformers are
//! RNNs") is that linear/higher-order attention makes a decoding sequence
//! an RNN with **constant per-sequence state** — a few KiB per slot
//! instead of a KV cache that grows with context.  That changes which
//! serving tricks are cheap:
//!
//! * **Preemption is ~free.**  Snapshotting a sequence costs
//!   `state_bytes_per_slot` (`Executor::snapshot_slot`), so a scheduler
//!   can park a long-running request mid-generation and hand its slot to
//!   a waiter, then resume the parked work later with zero recompute.
//!   With a KV cache this costs O(context) memory traffic per preemption.
//! * **Multi-turn resumption is ~free.**  Retaining a finished request's
//!   final state in a session cache costs a few KiB; a follow-up that
//!   extends the conversation restores it and skips re-prefilling the
//!   whole history.
//! * **Prefill batches through the same recurrence.**  A prompt can be
//!   absorbed in chunks (64 tokens per engine step instead of one),
//!   cutting prefill engine-steps ~64× — `Executor::absorb_slot`.
//!
//! The pieces, each its own module:
//!
//! * [`scheduler`] — policy-driven admission (FIFO / priority /
//!   fair-share by client id), queue bookkeeping, and the park/resume
//!   state for preempted slots.
//! * [`prefill`] — chunked prompt absorption over
//!   `Executor::absorb_slot`.
//! * [`sessions`] — a bounded LRU cache of finished requests' final
//!   [`SessionSnapshot`]s keyed by `session_id`.
//! * [`stream`] — the wire events (`ServeEvent`): per-token deltas for
//!   `"stream": true` requests plus the final response line, and their
//!   JSON framing.
//! * [`shard`] — one engine per core on its own thread, driven by an
//!   [`EngineMsg`] inbox (requests + migration exports/imports + stats
//!   probes), publishing lock-free load gauges.
//! * [`router`] — session affinity (FNV-1a hash + bounded migration
//!   overrides), snapshot migration between saturated shards, and the
//!   global fresh-waiter admission budget.
//!
//! The [`Engine`](crate::coordinator::server::Engine) in
//! `coordinator/server.rs` owns one of each and keeps only the
//! token-granularity step loop; the sharded TCP front end puts a
//! [`Router`] in front of N such engines.

pub mod prefill;
pub mod router;
pub mod scheduler;
pub mod sessions;
pub mod shard;
pub mod stream;

pub use self::prefill::{Prefiller, DEFAULT_PREFILL_CHUNK};
pub use self::router::{Affinity, Router, RouterMsg, RouterOpts, RouterReport};
pub use self::scheduler::{ParkedWork, Policy, QueueEntry, Scheduler};
pub use self::sessions::{SessionCache, SessionEntry};
pub use self::shard::{EngineMsg, ShardHandle, ShardLoad};
pub use self::stream::ServeEvent;

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::state::StateDtype;

/// One inbound generation request.
pub struct Request {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    /// Larger = served sooner under [`Policy::Priority`].
    pub priority: i64,
    /// Fair-share accounting key under [`Policy::FairShare`] (e.g. a user
    /// or API-key id).  Empty string = the anonymous client.
    pub client: String,
    /// Soft wall-clock budget (ms since admission): a running request past
    /// its deadline becomes preemptible whenever others wait.
    pub deadline_ms: Option<u64>,
    /// Session key for the O(1)-state session cache: the final decode
    /// state is retained at completion, and a follow-up with the same id
    /// whose prompt extends the absorbed history skips re-prefilling it.
    pub session_id: Option<String>,
    /// Emit one [`ServeEvent::Delta`] per generated token before the
    /// final [`ServeEvent::Done`].
    pub stream: bool,
    /// Trace id minted by the router (0 = not yet routed): every
    /// flight-recorder lifecycle event for this request carries it, so
    /// `{"trace": id}` reconstructs the request's path across shards.
    pub trace: u64,
    pub enqueued: Instant,
    pub respond: Sender<ServeEvent>,
}

impl Request {
    /// A request with default sampling and scheduling parameters.
    pub fn new(id: u64, prompt_ids: Vec<i32>, respond: Sender<ServeEvent>) -> Request {
        Request {
            id,
            prompt_ids,
            max_tokens: 64,
            temperature: 0.8,
            top_k: 40,
            priority: 0,
            client: String::new(),
            deadline_ms: None,
            session_id: None,
            stream: false,
            trace: 0,
            enqueued: Instant::now(),
            respond,
        }
    }
}

/// The engine's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub token_ids: Vec<i32>,
    pub text: String,
    /// queue + prefill time until the first generated token (-1 when the
    /// request was rejected — see `error`)
    pub ttft_s: f64,
    pub total_s: f64,
    /// `Some` iff the request failed (oversized prompt, malformed JSON);
    /// serialized as an `"error"` field on the wire so failures are
    /// distinguishable from successes.
    pub error: Option<String>,
}

impl Response {
    /// An error response (rejection / parse failure) for request `id`.
    pub fn error(id: u64, message: String) -> Response {
        Response {
            id,
            token_ids: Vec::new(),
            text: String::new(),
            ttft_s: -1.0,
            total_s: -1.0,
            error: Some(message),
        }
    }
}

/// Engine scheduling knobs (`holt serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Admission policy over the waiting queue.
    pub policy: Policy,
    /// Prompt tokens absorbed per engine step during prefill; ≥ 2 enables
    /// chunked prefill where the executor supports it (native backend),
    /// 0/1 keeps the token-at-a-time path.
    pub prefill_chunk: usize,
    /// Session-cache byte budget (finished-request snapshots,
    /// LRU-evicted by resident bytes — `--session-cache-mb`); 0 disables
    /// the cache.
    pub session_cache_bytes: usize,
    /// Wire dtype for *cached* session snapshots (`--state-dtype`, also
    /// settable per model via the `_s{dtype}` preset suffix).  Migration
    /// ships whatever the cache holds, verbatim.  In-flight preemption
    /// parks are always f64 — they are transient, never the memory
    /// bottleneck, and the preempt/resume bit-exactness pin depends on
    /// it.
    pub state_dtype: StateDtype,
    /// Decode-token quantum after which a running request becomes
    /// preemptible when the queue has waiters; 0 disables the quantum
    /// (per-request `deadline_ms` budgets still trigger preemption).
    pub preempt_tokens: usize,
    /// Waiting-queue bound: arrivals beyond this many waiters are
    /// rejected with an error response (admission-control backpressure —
    /// pipelined connections no longer block per request, so the queue
    /// itself must be bounded).  Parked preempted work is exempt.
    pub queue_capacity: usize,
    /// Stream responses (per-token deltas) for requests that don't say.
    pub stream_default: bool,
    /// Per-shard flight-recorder capacity (lifecycle events retained in
    /// the ring; `--flight-recorder N`).
    pub flight_capacity: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            policy: Policy::Fifo,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            session_cache_bytes: 16 << 20,
            state_dtype: StateDtype::F64,
            preempt_tokens: 0,
            queue_capacity: 1024,
            stream_default: false,
            flight_capacity: 256,
        }
    }
}
