//! Admission/preemption scheduling policy over the waiting queue.
//!
//! The queue holds [`QueueEntry`]s in arrival order (a `VecDeque`, fixing
//! the LIFO starvation bug of the old `Vec::push`/`Vec::pop` pending
//! list); [`Scheduler::pop_next`] selects which waiter gets the next free
//! slot according to the configured [`Policy`]:
//!
//! * [`Policy::Fifo`] — strict arrival order.
//! * [`Policy::Priority`] — highest [`Request::priority`] first, arrival
//!   order within a priority level.
//! * [`Policy::FairShare`] — least-served client id first (decode tokens
//!   charged via [`Scheduler::charge`]), arrival order within a client.
//!
//! Preempted slots are *parked*: the engine snapshots the slot's O(1)
//! state (`Executor::snapshot_slot` — a few KiB, the paper-specific win;
//! a KV-cache model would pay O(context) per preemption) and re-queues
//! the request at the tail with its [`ParkedWork`] attached.  When a
//! parked entry is popped again, the engine restores the snapshot into a
//! fresh slot and decoding continues bit-exactly where it left off — no
//! prefix replay.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::model::SessionSnapshot;
use crate::serve::Request;

/// Waiting-queue admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    Priority,
    FairShare,
}

impl Policy {
    /// Parse a `--policy` flag value.
    pub fn parse(s: &str) -> Result<Policy> {
        match s {
            "fifo" => Ok(Policy::Fifo),
            "priority" => Ok(Policy::Priority),
            "fair" | "fair-share" => Ok(Policy::FairShare),
            _ => bail!("--policy must be 'fifo', 'priority' or 'fair', got '{s}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::Priority => "priority",
            Policy::FairShare => "fair",
        }
    }
}

/// Mid-generation state of a preempted request: the slot's serialized
/// O(1) decode state plus the sampling-loop bookkeeping the engine needs
/// to resume exactly where it stopped.
pub struct ParkedWork {
    /// The slot's full decode state at preemption.
    pub snapshot: SessionSnapshot,
    /// Every token absorbed into the state so far (prompt + generated
    /// tokens already fed back) — retained for the session cache.
    pub absorbed: Vec<i32>,
    pub generated: Vec<i32>,
    /// Last sampled token, not yet absorbed — fed on the first resumed
    /// decode step.
    pub last_token: i32,
    pub first_token_at: Option<Instant>,
    /// Undelivered streaming bytes (an incomplete UTF-8 sequence held by
    /// [`crate::serve::stream::utf8_delta`] at preemption time).
    pub utf8_buf: Vec<u8>,
}

/// One waiter: a request, its arrival sequence number, and (for parked
/// preempted work) the state to resume from.
pub struct QueueEntry {
    pub req: Request,
    pub seq: u64,
    pub resume: Option<ParkedWork>,
}

/// Fair-share accounting cap: distinct client ids tracked at once.  The
/// `client` field is wire-controlled, so the map must be bounded like
/// every other serve/ structure; when full, the least-served id is
/// forgotten (it simply counts as new again).
const MAX_TRACKED_CLIENTS: usize = 1024;

/// The policy-driven waiting queue.
pub struct Scheduler {
    policy: Policy,
    queue: VecDeque<QueueEntry>,
    next_seq: u64,
    /// queued entries that are fresh arrivals (resume is None) — kept as
    /// a counter so the queue-capacity admission check is O(1), not a
    /// scan per arrival
    fresh: usize,
    /// decode tokens served per client id (fair-share accounting),
    /// bounded by [`MAX_TRACKED_CLIENTS`]
    served: HashMap<String, u64>,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Scheduler {
        Scheduler {
            policy,
            queue: VecDeque::new(),
            next_seq: 0,
            fresh: 0,
            served: HashMap::new(),
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Queue a fresh arrival (tail — FIFO arrival order).
    pub fn enqueue(&mut self, req: Request) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.fresh += 1;
        self.queue.push_back(QueueEntry { req, seq, resume: None });
    }

    /// Park preempted work at the tail with a *new* sequence number, so
    /// under FIFO the waiters that triggered the preemption run first.
    /// Returns that sequence number — the engine excludes it from the
    /// admission that follows, so a non-FIFO policy cannot hand the freed
    /// slot straight back to the evictee (a wasted snapshot/restore).
    pub fn park(&mut self, req: Request, work: ParkedWork) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(QueueEntry { req, seq, resume: Some(work) });
        seq
    }

    /// Put an entry back at the head (slot allocation raced and failed);
    /// it keeps its original sequence number.
    pub fn requeue_front(&mut self, entry: QueueEntry) {
        if entry.resume.is_none() {
            self.fresh += 1;
        }
        self.queue.push_front(entry);
    }

    pub fn has_waiters(&self) -> bool {
        !self.queue.is_empty()
    }

    /// Waiters that are fresh arrivals (not parked preempted work) —
    /// the population the queue-capacity bound applies to: parked
    /// entries already passed admission once and must never be refused.
    /// O(1): maintained as a counter alongside the queue.
    pub fn fresh_waiters(&self) -> usize {
        self.fresh
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Charge `tokens` decode tokens to `client` (fair-share accounting;
    /// cheap no-op bookkeeping under the other policies).
    pub fn charge(&mut self, client: &str, tokens: u64) {
        if self.policy != Policy::FairShare {
            return;
        }
        // fast path: no per-token String allocation once the id is known
        if let Some(n) = self.served.get_mut(client) {
            *n += tokens;
            return;
        }
        if self.served.len() >= MAX_TRACKED_CLIENTS {
            // forget the least-served id so a flood of wire-controlled
            // unique client names cannot grow the map without bound
            if let Some(min) = self
                .served
                .iter()
                .min_by_key(|(_, &n)| n)
                .map(|(k, _)| k.clone())
            {
                self.served.remove(&min);
            }
        }
        *self.served.entry(client.to_string()).or_insert(0) += tokens;
    }

    /// Tokens served to `client` so far.
    pub fn served(&self, client: &str) -> u64 {
        self.served.get(client).copied().unwrap_or(0)
    }

    /// Pop the next entry to admit, per policy.  O(queue) for the
    /// non-FIFO policies — queues are short relative to decode work.
    pub fn pop_next(&mut self) -> Option<QueueEntry> {
        self.pop_next_excluding(None)
    }

    /// [`Scheduler::pop_next`] skipping the entry with sequence number
    /// `exclude` (the just-parked evictee during a preemption sweep).
    /// Returns `None` when every remaining entry is excluded.
    pub fn pop_next_excluding(&mut self, exclude: Option<u64>) -> Option<QueueEntry> {
        let mut candidates = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, e)| Some(e.seq) != exclude);
        let idx = match self.policy {
            Policy::Fifo => candidates.next()?.0,
            Policy::Priority => {
                candidates.max_by_key(|(_, e)| (e.req.priority, std::cmp::Reverse(e.seq)))?.0
            }
            Policy::FairShare => {
                let served = &self.served;
                candidates
                    .min_by_key(|(_, e)| {
                        (served.get(&e.req.client).copied().unwrap_or(0), e.seq)
                    })?
                    .0
            }
        };
        let entry = self.queue.remove(idx);
        if let Some(e) = &entry {
            if e.resume.is_none() {
                self.fresh -= 1;
            }
        }
        entry
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(id: u64, priority: i64, client: &str) -> Request {
        let (tx, _rx) = channel();
        // the receiver is dropped — scheduler tests never deliver events
        Request {
            priority,
            client: client.to_string(),
            ..Request::new(id, vec![257], tx)
        }
    }

    fn pop_ids(s: &mut Scheduler) -> Vec<u64> {
        let mut ids = Vec::new();
        while let Some(e) = s.pop_next() {
            ids.push(e.req.id);
        }
        ids
    }

    #[test]
    fn fifo_pops_in_arrival_order() {
        let mut s = Scheduler::new(Policy::Fifo);
        for id in [1, 2, 3, 4] {
            s.enqueue(req(id, 0, ""));
        }
        assert_eq!(pop_ids(&mut s), vec![1, 2, 3, 4]);
        assert!(!s.has_waiters());
    }

    #[test]
    fn priority_pops_highest_first_fifo_within_level() {
        let mut s = Scheduler::new(Policy::Priority);
        s.enqueue(req(1, 0, ""));
        s.enqueue(req(2, 5, ""));
        s.enqueue(req(3, 5, ""));
        s.enqueue(req(4, 1, ""));
        assert_eq!(pop_ids(&mut s), vec![2, 3, 4, 1]);
    }

    #[test]
    fn fair_share_prefers_least_served_client() {
        let mut s = Scheduler::new(Policy::FairShare);
        s.charge("a", 100);
        s.enqueue(req(1, 0, "a"));
        s.enqueue(req(2, 0, "b"));
        s.enqueue(req(3, 0, "a"));
        assert_eq!(s.served("a"), 100);
        assert_eq!(s.served("b"), 0);
        // b has been served least; a's two requests keep arrival order
        assert_eq!(pop_ids(&mut s), vec![2, 1, 3]);
    }

    #[test]
    fn charge_is_fair_share_only() {
        let mut s = Scheduler::new(Policy::Fifo);
        s.charge("a", 7);
        assert_eq!(s.served("a"), 0, "non-fair policies skip the bookkeeping");
    }

    #[test]
    fn fair_share_accounting_is_bounded() {
        // the client id comes off the wire — the map must not grow
        // without bound under a flood of unique names
        let mut s = Scheduler::new(Policy::FairShare);
        for i in 0..(MAX_TRACKED_CLIENTS + 50) {
            s.charge(&format!("client{i}"), (i + 1) as u64);
        }
        let tracked = (0..MAX_TRACKED_CLIENTS + 50)
            .filter(|&i| s.served(&format!("client{i}")) > 0)
            .count();
        assert!(tracked <= MAX_TRACKED_CLIENTS, "tracked {tracked} client ids");
        // the heaviest client is still remembered
        let last = format!("client{}", MAX_TRACKED_CLIENTS + 49);
        assert_eq!(s.served(&last), (MAX_TRACKED_CLIENTS + 50) as u64);
    }

    fn parked(tok: i32) -> ParkedWork {
        ParkedWork {
            snapshot: crate::model::SessionSnapshot::default(),
            absorbed: vec![257, tok],
            generated: vec![tok],
            last_token: tok,
            first_token_at: None,
            utf8_buf: Vec::new(),
        }
    }

    #[test]
    fn parked_work_goes_to_the_tail_under_fifo() {
        let mut s = Scheduler::new(Policy::Fifo);
        s.enqueue(req(1, 0, ""));
        s.park(req(2, 0, ""), parked(65));
        assert_eq!(s.len(), 2);
        assert_eq!(s.fresh_waiters(), 1, "parked work is not a fresh waiter");
        let first = s.pop_next().unwrap();
        assert_eq!(s.fresh_waiters(), 0);
        assert_eq!(first.req.id, 1, "the waiter that triggered preemption runs first");
        assert!(first.resume.is_none());
        let second = s.pop_next().unwrap();
        assert_eq!(second.req.id, 2);
        assert!(second.resume.is_some(), "parked entries carry their snapshot");
    }

    #[test]
    fn excluding_the_evictee_prevents_self_readmission() {
        // under priority, a parked high-priority evictee would be the
        // policy's next pick — the exclusion hands the slot to a real
        // waiter instead, and the evictee is eligible again afterwards
        let mut s = Scheduler::new(Policy::Priority);
        s.enqueue(req(1, 0, ""));
        let evictee_seq = s.park(req(2, 9, ""), parked(65));
        let admitted = s.pop_next_excluding(Some(evictee_seq)).unwrap();
        assert_eq!(admitted.req.id, 1, "the waiter wins the freed slot");
        let next = s.pop_next_excluding(Some(evictee_seq));
        assert!(next.is_none(), "only the excluded evictee remains");
        assert!(s.has_waiters());
        assert_eq!(s.pop_next().unwrap().req.id, 2, "evictee eligible without exclusion");
    }

    #[test]
    fn requeue_front_restores_arrival_position() {
        let mut s = Scheduler::new(Policy::Fifo);
        s.enqueue(req(1, 0, ""));
        s.enqueue(req(2, 0, ""));
        let e = s.pop_next().unwrap();
        s.requeue_front(e);
        assert_eq!(pop_ids(&mut s), vec![1, 2]);
    }

    #[test]
    fn policy_parses() {
        assert_eq!(Policy::parse("fifo").unwrap(), Policy::Fifo);
        assert_eq!(Policy::parse("priority").unwrap(), Policy::Priority);
        assert_eq!(Policy::parse("fair").unwrap(), Policy::FairShare);
        assert_eq!(Policy::parse("fair-share").unwrap(), Policy::FairShare);
        assert!(Policy::parse("lifo").is_err());
        assert_eq!(Policy::FairShare.name(), "fair");
    }
}
