//! Continuous-batching serve loop (the vLLM-style coordinator, for a model
//! whose "KV cache" is O(1) per sequence).
//!
//! The engine owns an [`Executor`] — native pure-Rust or PJRT artifact —
//! and keeps only the **token-granularity step loop**; scheduling lives
//! in the [`crate::serve`] subsystem it is built on:
//!
//! * admission/preemption — [`Scheduler`] (FIFO / priority / fair-share,
//!   per-request deadlines).  When the queue has waiters and a running
//!   request exceeds its token/time budget, the engine snapshots the
//!   slot's O(1) state (a few KiB — the paper-specific win; a KV cache
//!   would pay O(context)) and parks it for later bit-exact resumption.
//! * prefill — [`Prefiller`]: prompts absorb in chunks (default 64
//!   tokens per engine step) through [`Executor::absorb_slot`] instead
//!   of one token per step, so a P-token prompt costs ⌈P/64⌉ steps.
//! * sessions — [`SessionCache`]: a finished request's final snapshot is
//!   retained under its `session_id`; a follow-up whose prompt extends
//!   the absorbed history restores it and skips re-prefilling.
//! * streaming — requests with `"stream": true` get one
//!   [`ServeEvent::Delta`] per generated token before the final line.
//!
//! Front ends:
//! * [`serve_tcp`] — JSON-lines-over-TCP: `{"prompt": ..., "max_tokens":
//!   ..}` per line, one JSON response line per request (see
//!   [`crate::serve::stream`] for the full wire protocol).  Requests on
//!   one connection pipeline: the reader hands every parsed line to the
//!   engine immediately and a writer thread delivers responses as they
//!   finish.
//! * [`run_synthetic`] / [`run_synthetic_sessions`] — in-process load
//!   drivers used by `holt serve --synthetic`, the E4 bench and the
//!   serve_decode example.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::json::{obj, Json};
use crate::model::{Executor, SKIP};
use crate::obs::{Counter, FlightEvent, FlightRecorder, Gauge, Histo, HistoSnapshot, Registry};
use crate::rng::Rng;
use crate::serve::{
    stream, EngineMsg, ParkedWork, Prefiller, QueueEntry, Router, RouterMsg, Scheduler,
    ServeEvent, SessionCache, SessionEntry, ShardLoad,
};
pub use crate::serve::{Policy, Request, Response, RouterOpts, ServeOpts};
use crate::tokenizer::{ByteTokenizer, EOS, PAD};

/// One in-flight request bound to a decode slot.
struct Active {
    req: Request,
    slot: usize,
    /// next prompt index to absorb (prefill cursor)
    prompt_pos: usize,
    /// every token fed into the slot's state so far, in order — the
    /// session cache stores this next to the final snapshot
    absorbed: Vec<i32>,
    generated: Vec<i32>,
    last_token: i32,
    first_token_at: Option<Instant>,
    admitted_at: Instant,
    /// decode tokens since (re)admission — the preemption quantum clock
    decoded_since_admit: usize,
    /// next-token logits produced by chunked prefill this step, sampled
    /// without a decode_step
    pending_logits: Option<Vec<f32>>,
    /// streamed bytes awaiting a complete UTF-8 character (see
    /// [`stream::utf8_delta`])
    utf8_buf: Vec<u8>,
}

/// The engine's registry handles: every live statistic the engine keeps
/// is one of these cells — `{"stats": true}`, `{"metrics": true}` and
/// the final [`ServeStats`] snapshot all read the same registry, and
/// recording on the decode hot path is a Relaxed atomic (allocation-free
/// after registration, pinned in `alloc_decode.rs`).
struct EngineMetrics {
    registry: Arc<Registry>,
    completed: Counter,
    rejected: Counter,
    generated_tokens: Counter,
    engine_steps: Counter,
    prefill_tokens: Counter,
    preemptions: Counter,
    resumes: Counter,
    session_hits: Counter,
    session_misses: Counter,
    migrations_in: Counter,
    migrations_out: Counter,
    slots_busy: Gauge,
    queue_depth: Gauge,
    sessions_cached: Gauge,
    // per-stage span histograms (µs)
    prefill_us: Histo,
    decode_step_us: Histo,
    sample_us: Histo,
    park_us: Histo,
    restore_us: Histo,
    migrate_us: Histo,
    ttft_us: Histo,
    request_us: Histo,
}

impl EngineMetrics {
    /// Register every metric eagerly on a fresh per-engine registry, so
    /// exports list all keys (`prefill_us`, `decode_step_us`,
    /// `migrate_us`, …) even before the first sample — the CI smoke
    /// greps the `{"metrics": true}` reply for them.
    fn new() -> Self {
        let registry = Arc::new(Registry::new());
        EngineMetrics {
            completed: registry.counter("completed"),
            rejected: registry.counter("rejected"),
            generated_tokens: registry.counter("generated_tokens"),
            engine_steps: registry.counter("engine_steps"),
            prefill_tokens: registry.counter("prefill_tokens"),
            preemptions: registry.counter("preemptions"),
            resumes: registry.counter("resumes"),
            session_hits: registry.counter("session_hits"),
            session_misses: registry.counter("session_misses"),
            migrations_in: registry.counter("migrations_in"),
            migrations_out: registry.counter("migrations_out"),
            slots_busy: registry.gauge("slots_busy"),
            queue_depth: registry.gauge("queue_depth"),
            sessions_cached: registry.gauge("sessions_cached"),
            prefill_us: registry.histo("prefill_us"),
            decode_step_us: registry.histo("decode_step_us"),
            sample_us: registry.histo("sample_us"),
            park_us: registry.histo("park_us"),
            restore_us: registry.histo("restore_us"),
            migrate_us: registry.histo("migrate_us"),
            ttft_us: registry.histo("ttft_us"),
            request_us: registry.histo("request_us"),
            registry,
        }
    }
}

/// Final serving statistics — a **snapshot of the engine's registry**
/// taken when the run drains (the engine keeps no live counters outside
/// the registry), JSON-serializable via [`ServeStats::to_json`] so
/// benches land in `results/bench_serve.json`.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    /// requests refused at arrival (bad budget / queue full) — offered
    /// load = completed + rejected, so overload benches stay honest
    pub rejected: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    /// prompt tokens absorbed through chunked prefill (0 ⇒ the
    /// token-at-a-time path served every prompt)
    pub prefill_tokens: u64,
    /// effective prefill chunk (1 = token-at-a-time)
    pub prefill_chunk: usize,
    /// slots snapshotted + parked for waiters
    pub preemptions: u64,
    /// parked requests restored into a fresh slot
    pub resumes: u64,
    /// admissions that restored a cached session (prefix prefill skipped)
    pub session_hits: u64,
    /// requests that carried a session_id but found no reusable entry
    pub session_misses: u64,
    /// session entries this engine adopted from another shard
    pub migrations_in: u64,
    /// session entries this engine exported to another shard
    pub migrations_out: u64,
    pub ttft: HistoSnapshot,
    pub per_request: HistoSnapshot,
    /// the full registry dump (counters, gauges, span histograms) at
    /// drain time — what `--metrics-log` writes per shard
    pub metrics: Json,
    pub wall_s: f64,
    /// which executor ran ("native" / "artifact")
    pub backend: String,
    pub model: String,
    pub n_slots: usize,
    /// scheduler policy name ("fifo" / "priority" / "fair")
    pub policy: String,
    /// per-slot decode state footprint (bytes) — O(1) in context for
    /// ho2/linear, max_len-sized KV cache for softmax
    pub state_bytes_per_slot: usize,
    /// wire dtype cached session snapshots are encoded in
    /// (`--state-dtype`; "f64" = the lossless default)
    pub state_dtype: String,
    /// session-cache byte budget (`--session-cache-mb`)
    pub session_cache_bytes: usize,
    /// resident sessions a GiB of cache holds at the active dtype
    /// (encoded snapshot + header; analytic, so it is exact even for a
    /// run too small to fill a GiB)
    pub sessions_per_gib: f64,
    /// park (state encode on preemption / session retain) latencies, µs
    pub park: HistoSnapshot,
    /// restore (state decode on resume / session hit) latencies, µs
    pub restore: HistoSnapshot,
    /// comparative per-dtype footprint block (encoded bytes,
    /// sessions-per-GiB, density vs the f64 baseline) — the before/after
    /// record `bench_serve.json` carries for every run
    pub state_footprint: Json,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    pub fn report(&self) -> String {
        format!(
            "backend={} model={} slots={} policy={} state/slot={:.1}KiB\n\
             requests={} (+{} rejected) tokens={} steps={} wall={:.2}s throughput={:.1} tok/s\n\
             prefill: chunk={} tokens={}  preempt/resume={}/{}  sessions hit/miss={}/{} \
             migrations in/out={}/{}\n  \
             ttft: {}\n  request latency: {}",
            self.backend,
            self.model,
            self.n_slots,
            self.policy,
            self.state_bytes_per_slot as f64 / 1024.0,
            self.completed,
            self.rejected,
            self.generated_tokens,
            self.engine_steps,
            self.wall_s,
            self.tokens_per_sec(),
            self.prefill_chunk,
            self.prefill_tokens,
            self.preemptions,
            self.resumes,
            self.session_hits,
            self.session_misses,
            self.migrations_in,
            self.migrations_out,
            self.ttft.summary(),
            self.per_request.summary(),
        )
    }

    /// Machine-readable record for `results/bench_serve.json`.  The
    /// percentile fields come from [`HistoSnapshot::push_ms_fields`]:
    /// explicit `*_samples` counts, and `null` — not a fake `0.0` —
    /// when no request completed.
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut fields) = obj(vec![
            ("backend", self.backend.as_str().into()),
            ("model", self.model.as_str().into()),
            ("n_slots", self.n_slots.into()),
            ("policy", self.policy.as_str().into()),
            ("state_bytes_per_slot", self.state_bytes_per_slot.into()),
            ("state_dtype", self.state_dtype.as_str().into()),
            ("session_cache_bytes", self.session_cache_bytes.into()),
            ("sessions_per_gib", self.sessions_per_gib.into()),
            ("requests_completed", (self.completed as i64).into()),
            ("requests_rejected", (self.rejected as i64).into()),
            ("generated_tokens", (self.generated_tokens as i64).into()),
            ("engine_steps", (self.engine_steps as i64).into()),
            ("prefill_chunk", self.prefill_chunk.into()),
            ("prefill_tokens", (self.prefill_tokens as i64).into()),
            ("preemptions", (self.preemptions as i64).into()),
            ("resumes", (self.resumes as i64).into()),
            ("session_hits", (self.session_hits as i64).into()),
            ("session_misses", (self.session_misses as i64).into()),
            ("migrations_in", (self.migrations_in as i64).into()),
            ("migrations_out", (self.migrations_out as i64).into()),
            ("wall_s", self.wall_s.into()),
            ("tok_per_s", self.tokens_per_sec().into()),
        ]) else {
            unreachable!("obj builds an object")
        };
        self.ttft.push_ms_fields("ttft", &mut fields);
        self.per_request.push_ms_fields("latency", &mut fields);
        self.park.push_ms_fields("park", &mut fields);
        self.restore.push_ms_fields("restore", &mut fields);
        fields.push(("state_footprint".to_string(), self.state_footprint.clone()));
        fields.push(("metrics".to_string(), self.metrics.clone()));
        Json::Obj(fields)
    }
}

/// Resident sessions one GiB holds when snapshots are encoded as
/// `dtype` for a state of `state_elems` f64 elements (payload + the
/// snapshot header [`SessionSnapshot::bytes`] counts).
fn sessions_per_gib(dtype: crate::state::StateDtype, state_elems: usize) -> f64 {
    let entry = dtype.encoded_len(state_elems)
        + std::mem::size_of::<crate::model::SessionSnapshot>();
    (1u64 << 30) as f64 / entry as f64
}

/// The per-dtype footprint comparison `bench_serve.json` records on
/// every run: encoded bytes per session, sessions-per-GiB, and density
/// relative to the f64 baseline — analytic from the executor's state
/// size, so one run reports the whole dtype sweep (the acceptance
/// check reads the ≥3× f16-vs-f64 ratio straight off this block).
fn state_footprint_json(state_elems: usize) -> Json {
    let f64_per_gib = sessions_per_gib(crate::state::StateDtype::F64, state_elems);
    let mut fields: Vec<(String, Json)> = vec![(
        "state_elements".to_string(),
        Json::Num(state_elems as f64),
    )];
    for dtype in crate::state::StateDtype::ALL {
        let per_gib = sessions_per_gib(dtype, state_elems);
        fields.push((
            dtype.name().to_string(),
            obj(vec![
                ("encoded_bytes", dtype.encoded_len(state_elems).into()),
                ("sessions_per_gib", per_gib.into()),
                ("density_vs_f64", (per_gib / f64_per_gib).into()),
            ]),
        ));
    }
    Json::Obj(fields)
}

/// The continuous-batching engine over any [`Executor`], scheduled by
/// the [`crate::serve`] subsystem.
pub struct Engine<'a> {
    exec: Box<dyn Executor + 'a>,
    slots: Vec<Option<Active>>,
    rng: Rng,
    vocab: usize,
    max_len: usize,
    opts: ServeOpts,
    scheduler: Scheduler,
    prefiller: Prefiller,
    sessions: SessionCache,
    /// chunked prefill active (opts allow it AND the executor supports it)
    chunked: bool,
    /// snapshot/restore available (preemption + session cache gate)
    snapshots: bool,
    /// when running as a shard: load gauges published every loop
    /// iteration for the router's lock-free placement decisions
    load: Option<Arc<ShardLoad>>,
    /// the one registry behind every statistic this engine keeps
    metrics: EngineMetrics,
    /// bounded ring of request lifecycle events (admit / park / resume /
    /// migrate / reject / finish), timestamped on the shared process
    /// epoch so cross-shard traces sort into one timeline
    flight: FlightRecorder,
    /// shard id for flight-recorder events (0 unless [`Engine::set_shard`])
    shard: usize,
}

impl<'a> Engine<'a> {
    /// Engine with default scheduling ([`ServeOpts::default`]).
    pub fn new(exec: Box<dyn Executor + 'a>, seed: u64) -> Result<Self> {
        Engine::with_opts(exec, seed, ServeOpts::default())
    }

    pub fn with_opts(exec: Box<dyn Executor + 'a>, seed: u64, opts: ServeOpts) -> Result<Self> {
        anyhow::ensure!(
            exec.supports_decode(),
            "model '{}' cannot decode on the {} backend",
            exec.model().name,
            exec.backend_name()
        );
        let n = exec.n_slots();
        let vocab = exec.model().config.vocab_size;
        let max_len = exec.model().config.max_len;
        let chunked = opts.prefill_chunk >= 2 && exec.supports_chunked_prefill();
        let snapshots = exec.supports_snapshot();
        Ok(Engine {
            exec,
            slots: (0..n).map(|_| None).collect(),
            rng: Rng::new(seed),
            vocab,
            max_len,
            scheduler: Scheduler::new(opts.policy),
            prefiller: Prefiller::new(opts.prefill_chunk),
            sessions: SessionCache::new(if snapshots { opts.session_cache_bytes } else { 0 }),
            chunked,
            snapshots,
            metrics: EngineMetrics::new(),
            flight: FlightRecorder::new(0, opts.flight_capacity),
            shard: 0,
            opts,
            load: None,
        })
    }

    /// Tag this engine (and its flight-recorder events) with a shard id
    /// — called by [`crate::serve::ShardHandle::spawn`] before running.
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
        self.flight.set_shard(shard);
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn opts(&self) -> &ServeOpts {
        &self.opts
    }

    fn has_active(&self) -> bool {
        self.slots.iter().any(Option::is_some)
    }

    /// Publish load gauges into `load` after every loop iteration (set
    /// by [`crate::serve::ShardHandle::spawn`] before the engine runs).
    pub fn publish_load(&mut self, load: Arc<ShardLoad>) {
        self.load = Some(load);
    }

    fn busy_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn publish(&self) {
        if let Some(l) = &self.load {
            l.queued.store(self.scheduler.fresh_waiters(), Ordering::Relaxed);
            l.busy.store(self.busy_slots(), Ordering::Relaxed);
            l.sessions.store(self.sessions.len(), Ordering::Relaxed);
        }
        self.metrics.slots_busy.set(self.busy_slots() as f64);
        self.metrics.queue_depth.set(self.scheduler.len() as f64);
        self.metrics.sessions_cached.set(self.sessions.len() as f64);
    }

    /// Migration export: give up this engine's cached entry for `id`
    /// (None when unknown or the session's turn is still in flight — the
    /// cache only holds finished turns).
    pub fn export_session(&mut self, id: &str) -> Option<SessionEntry> {
        self.sessions.remove(id)
    }

    /// Migration import: adopt an entry exported from another engine's
    /// cache partition.
    pub fn import_session(&mut self, id: &str, entry: SessionEntry) {
        self.sessions.insert(id.to_string(), entry);
    }

    /// Live stats snapshot: gauges (busy slots, queue depth, cache
    /// residency) + the registry's counters so far — the per-shard half
    /// of a `{"stats": true}` wire reply.
    fn live_stats(&self) -> Json {
        let m = &self.metrics;
        obj(vec![
            ("n_slots", self.n_slots().into()),
            ("slots_busy", self.busy_slots().into()),
            ("queue_depth", self.scheduler.len().into()),
            ("fresh_waiters", self.scheduler.fresh_waiters().into()),
            ("sessions_cached", self.sessions.len().into()),
            ("completed", (m.completed.get() as i64).into()),
            ("rejected", (m.rejected.get() as i64).into()),
            ("generated_tokens", (m.generated_tokens.get() as i64).into()),
            ("preemptions", (m.preemptions.get() as i64).into()),
            ("resumes", (m.resumes.get() as i64).into()),
            ("session_hits", (m.session_hits.get() as i64).into()),
            ("session_misses", (m.session_misses.get() as i64).into()),
            ("migrations_in", (m.migrations_in.get() as i64).into()),
            ("migrations_out", (m.migrations_out.get() as i64).into()),
        ])
    }

    /// The `{"metrics": true}` per-shard half: the full registry dump
    /// with the shard id prepended.
    fn metrics_json(&self) -> Json {
        let Json::Obj(mut kv) = self.metrics.registry.to_json() else {
            unreachable!("registry dump is an object")
        };
        kv.insert(0, ("shard".to_string(), self.shard.into()));
        Json::Obj(kv)
    }

    /// Final [`ServeStats`]: one read of every registry cell at drain
    /// time (plus the engine's static config fields).
    fn snapshot_stats(&self, wall_s: f64) -> ServeStats {
        let m = &self.metrics;
        ServeStats {
            completed: m.completed.get(),
            rejected: m.rejected.get(),
            generated_tokens: m.generated_tokens.get(),
            engine_steps: m.engine_steps.get(),
            prefill_tokens: m.prefill_tokens.get(),
            prefill_chunk: if self.chunked { self.prefiller.chunk() } else { 1 },
            preemptions: m.preemptions.get(),
            resumes: m.resumes.get(),
            session_hits: m.session_hits.get(),
            session_misses: m.session_misses.get(),
            migrations_in: m.migrations_in.get(),
            migrations_out: m.migrations_out.get(),
            ttft: m.ttft_us.snapshot(),
            per_request: m.request_us.snapshot(),
            metrics: m.registry.to_json(),
            wall_s,
            backend: self.exec.backend_name().to_string(),
            model: self.exec.model().name.clone(),
            n_slots: self.n_slots(),
            policy: self.scheduler.policy().name().to_string(),
            state_bytes_per_slot: self.exec.state_bytes_per_slot(),
            state_dtype: self.opts.state_dtype.name().to_string(),
            session_cache_bytes: self.opts.session_cache_bytes,
            sessions_per_gib: sessions_per_gib(
                self.opts.state_dtype,
                self.exec.state_bytes_per_slot() / 8,
            ),
            park: m.park_us.snapshot(),
            restore: m.restore_us.snapshot(),
            state_footprint: state_footprint_json(self.exec.state_bytes_per_slot() / 8),
        }
    }

    /// Handle one inbox message (see [`EngineMsg`]).
    fn handle_msg(&mut self, msg: EngineMsg) {
        match msg {
            EngineMsg::Req(req) => self.accept(req),
            EngineMsg::Export { id, trace, respond } => {
                let entry = {
                    let _span = self.metrics.migrate_us.span();
                    self.export_session(&id)
                };
                if entry.is_some() {
                    self.metrics.migrations_out.inc();
                    self.flight.record(FlightEvent::MigrateOut, trace, 0);
                }
                let _ = respond.send(entry);
            }
            EngineMsg::Import { id, entry, trace } => {
                {
                    let _span = self.metrics.migrate_us.span();
                    self.import_session(&id, entry);
                }
                self.metrics.migrations_in.inc();
                self.flight.record(FlightEvent::MigrateIn, trace, 0);
            }
            EngineMsg::Stats { respond } => {
                let _ = respond.send(self.live_stats());
            }
            EngineMsg::Metrics { respond } => {
                let _ = respond.send(self.metrics_json());
            }
            EngineMsg::Trace { id, respond } => {
                // id 0: full ring dump (the router's overload path)
                let j = if id == 0 {
                    self.flight.to_json()
                } else {
                    Json::Arr(self.flight.for_trace(id).iter().map(|r| r.to_json()).collect())
                };
                let _ = respond.send(j);
            }
        }
    }

    /// Accept one inbound request: invalid budgets and queue overflow
    /// are rejected on arrival — producing the error needs no slot, so a
    /// saturated server must not make a doomed request wait in the queue
    /// for one — everything else goes to the scheduler.
    fn accept(&mut self, req: Request) {
        // the sampling loop always produces at least one token, so a
        // 0-token budget cannot be honored (it used to be silently
        // over-served; clamped negatives land here too)
        let msg = if req.max_tokens == 0 {
            Some("max_tokens must be at least 1".to_string())
        } else if req.prompt_ids.len() + req.max_tokens > self.max_len {
            Some(format!(
                "prompt ({}) + max_tokens ({}) exceeds model max_len ({})",
                req.prompt_ids.len(),
                req.max_tokens,
                self.max_len
            ))
        } else if self.scheduler.fresh_waiters() >= self.opts.queue_capacity {
            // pipelined connections submit without per-request blocking,
            // so the waiting queue itself enforces the backpressure
            // (parked preempted work is exempt from the bound)
            Some(format!(
                "server overloaded: {} requests already waiting",
                self.scheduler.fresh_waiters()
            ))
        } else {
            None
        };
        match msg {
            Some(msg) => {
                self.metrics.rejected.inc();
                self.flight.record(FlightEvent::Reject, req.trace, req.id);
                let _ = req.respond.send(ServeEvent::Done(Response::error(req.id, msg)));
            }
            None => self.scheduler.enqueue(req),
        }
    }

    /// Admit the scheduler's next pick into a free slot, skipping the
    /// entry with sequence `exclude` (a just-parked evictee — see
    /// [`Engine::preempt_for_waiters`]).  Returns whether an entry was
    /// admitted; `false` means no eligible waiter or no free slot.
    fn admit_next(&mut self, exclude: Option<u64>) -> Result<bool> {
        if self.exec.free_slots() == 0 {
            return Ok(false);
        }
        let Some(entry) = self.scheduler.pop_next_excluding(exclude) else {
            return Ok(false);
        };
        let Some(slot) = self.exec.alloc_slot() else {
            // free_slots raced — put the pick back at its arrival position
            self.scheduler.requeue_front(entry);
            return Ok(false);
        };
        let QueueEntry { req, resume, .. } = entry;
        let mut a = Active {
            req,
            slot,
            prompt_pos: 0,
            absorbed: Vec::new(),
            generated: Vec::new(),
            last_token: PAD,
            first_token_at: None,
            admitted_at: Instant::now(),
            decoded_since_admit: 0,
            pending_logits: None,
            utf8_buf: Vec::new(),
        };
        if let Some(w) = resume {
            // parked preempted work: restore the snapshot (always f64 —
            // parks are transient, and the bit-exact resume pin depends
            // on it) and continue decoding exactly where it stopped —
            // no prefix replay
            {
                let _span = self.metrics.restore_us.span();
                self.exec.restore_slot(slot, &w.snapshot)?;
            }
            a.prompt_pos = a.req.prompt_ids.len();
            a.absorbed = w.absorbed;
            a.generated = w.generated;
            a.last_token = w.last_token;
            a.first_token_at = w.first_token_at;
            a.utf8_buf = w.utf8_buf;
            self.metrics.resumes.inc();
            self.flight.record(FlightEvent::Resume, a.req.trace, a.req.id);
        } else {
            if let Some(sid) = a.req.session_id.clone() {
                // multi-turn follow-up: restore the cached final state and
                // prefill only the new suffix of the conversation
                if let Some(e) = self.sessions.lookup(&sid, &a.req.prompt_ids) {
                    let snap = e.snapshot.clone();
                    let tokens = e.tokens.clone();
                    // rehydrates the f64 live state whatever dtype the
                    // cache holds (the restore side of `--state-dtype`)
                    {
                        let _span = self.metrics.restore_us.span();
                        self.exec.restore_slot(slot, &snap)?;
                    }
                    a.prompt_pos = tokens.len();
                    a.absorbed = tokens;
                    self.metrics.session_hits.inc();
                } else {
                    self.metrics.session_misses.inc();
                }
            }
            self.flight.record(FlightEvent::Admit, a.req.trace, a.req.id);
        }
        self.exec.tag_slot(slot, a.req.trace);
        self.slots[slot] = Some(a);
        Ok(true)
    }

    /// One engine step, three phases: (1) chunked prefill absorbs up to
    /// `prefill_chunk` prompt tokens per prefilling slot; (2) one batched
    /// decode step feeds every slot that needs a token (prompt
    /// token-at-a-time on backends without absorb, last sampled token in
    /// decode phase); (3) sample / advance / finish per slot.
    fn step(&mut self) -> Result<()> {
        let b = self.n_slots();
        self.metrics.engine_steps.inc();

        if self.chunked {
            for slot_idx in 0..b {
                let Some(a) = self.slots[slot_idx].as_mut() else {
                    continue;
                };
                if a.prompt_pos >= a.req.prompt_ids.len() {
                    continue;
                }
                let before = a.prompt_pos;
                let done = {
                    let _span = self.metrics.prefill_us.span();
                    self.prefiller.absorb_block(
                        self.exec.as_mut(),
                        slot_idx,
                        &a.req.prompt_ids,
                        &mut a.prompt_pos,
                        Some(&mut a.absorbed),
                    )?
                };
                self.metrics.prefill_tokens.add((a.prompt_pos - before) as u64);
                if let Some(logits) = done {
                    a.pending_logits = Some(logits);
                }
            }
        }

        let mut feed = vec![PAD; b];
        let mut fed: Vec<Option<i32>> = vec![None; b];
        let mut any = false;
        for a in self.slots.iter().flatten() {
            let tok = if a.prompt_pos < a.req.prompt_ids.len() {
                if self.chunked {
                    // mid chunked prefill — this slot sits the decode out
                    feed[a.slot] = SKIP;
                    continue;
                }
                a.req.prompt_ids[a.prompt_pos]
            } else if a.pending_logits.is_some() {
                // prompt finished via absorb this step; sample below
                feed[a.slot] = SKIP;
                continue;
            } else {
                a.last_token
            };
            feed[a.slot] = tok;
            fed[a.slot] = Some(tok);
            any = true;
        }
        // borrow the batched logits in place — no per-step or per-slot
        // copies on the decode hot path
        let logits = if any {
            let _span = self.metrics.decode_step_us.span();
            Some(self.exec.decode_step(&feed)?)
        } else {
            None
        };
        let lf = match &logits {
            Some(t) => Some(t.as_f32()?),
            None => None,
        };

        let v = self.vocab;
        let tok = ByteTokenizer::new();
        for slot_idx in 0..b {
            let Some(mut a) = self.slots[slot_idx].take() else {
                continue;
            };
            if let Some(t) = fed[slot_idx] {
                a.absorbed.push(t);
            }
            if a.prompt_pos < a.req.prompt_ids.len() {
                if fed[slot_idx].is_none() {
                    // mid chunked prefill — more blocks next step
                    self.slots[slot_idx] = Some(a);
                    continue;
                }
                a.prompt_pos += 1;
                if a.prompt_pos < a.req.prompt_ids.len() {
                    self.slots[slot_idx] = Some(a);
                    continue;
                }
                // prompt fully consumed this step: fall through to sample
            }
            let pending = a.pending_logits.take();
            let row: &[f32] = match &pending {
                Some(r) => r,
                None => {
                    let lf = lf.expect("decode ran for this slot");
                    &lf[slot_idx * v..(slot_idx + 1) * v]
                }
            };
            let next = {
                let _span = self.metrics.sample_us.span();
                self.rng.sample_logits(row, a.req.temperature, a.req.top_k) as i32
            };
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            let hit_eos = next == EOS;
            if !hit_eos {
                a.generated.push(next);
                a.last_token = next;
                a.decoded_since_admit += 1;
                self.scheduler.charge(&a.req.client, 1);
                if a.req.stream {
                    // buffer bytes until a UTF-8 character completes —
                    // decoding each byte alone would stream U+FFFD for
                    // every multi-byte character (specials add no bytes)
                    if (0..256).contains(&next) {
                        a.utf8_buf.push(next as u8);
                    }
                    let _ = a.req.respond.send(ServeEvent::Delta {
                        id: a.req.id,
                        index: a.generated.len() - 1,
                        token_id: next,
                        text: stream::utf8_delta(&mut a.utf8_buf),
                    });
                }
            }
            let over_budget = a.generated.len() >= a.req.max_tokens
                || self.exec.pos(slot_idx) >= self.max_len - 1;
            if hit_eos || over_budget {
                self.finish(slot_idx, a, &tok);
            } else {
                self.slots[slot_idx] = Some(a);
            }
        }
        Ok(())
    }

    /// Complete one request: retain its session state, deliver the
    /// response, free the slot.
    fn finish(&mut self, slot_idx: usize, a: Active, tok: &ByteTokenizer) {
        let Active { req, absorbed, generated, first_token_at, .. } = a;
        let now = Instant::now();
        let ttft = first_token_at
            .map(|t| t.duration_since(req.enqueued))
            .unwrap_or_default();
        self.metrics.completed.inc();
        self.metrics.generated_tokens.add(generated.len() as u64);
        self.metrics.ttft_us.record(ttft.as_micros() as u64);
        self.metrics.request_us.record(now.duration_since(req.enqueued).as_micros() as u64);
        self.flight.record(FlightEvent::Finish, req.trace, req.id);
        if self.snapshots && self.sessions.budget() > 0 {
            if let Some(sid) = req.session_id.clone() {
                // the final O(1) state costs a few KiB to keep — a
                // follow-up extending `absorbed` skips this whole prefix;
                // cached copies carry the configured `--state-dtype`
                // (parks stay f64 — only retained sessions pay the
                // quantization for density)
                let _span = self.metrics.park_us.span();
                if let Ok(snapshot) = self.exec.snapshot_slot(slot_idx) {
                    let snapshot = snapshot.transcode(self.opts.state_dtype);
                    self.sessions.insert(sid, SessionEntry { snapshot, tokens: absorbed });
                }
            }
        }
        let resp = Response {
            id: req.id,
            text: tok.decode(&generated),
            token_ids: generated,
            ttft_s: ttft.as_secs_f64(),
            total_s: now.duration_since(req.enqueued).as_secs_f64(),
            error: None,
        };
        let _ = req.respond.send(ServeEvent::Done(resp));
        self.exec.release_slot(slot_idx);
    }

    /// Preemptive scheduling: while waiters queue and slots are over
    /// budget (the `--preempt-tokens` decode quantum, or the request's
    /// own `deadline_ms` — deadlines work even with the quantum
    /// disabled), snapshot the O(1) state, park the work at the queue
    /// tail and hand the slot to the scheduler's next pick.  Bounded to
    /// one sweep of the slots per engine step, and a slot must have
    /// decoded at least one token since admission — both prevent
    /// park/admit livelock.
    fn preempt_for_waiters(&mut self) -> Result<()> {
        if !self.snapshots {
            return Ok(());
        }
        for _ in 0..self.n_slots() {
            if !self.scheduler.has_waiters() {
                break;
            }
            // the slot deepest into its quantum yields first
            let mut pick: Option<(usize, usize)> = None;
            for (i, s) in self.slots.iter().enumerate() {
                let Some(a) = s else { continue };
                if a.prompt_pos < a.req.prompt_ids.len()
                    || a.pending_logits.is_some()
                    || a.decoded_since_admit == 0
                {
                    continue; // still prefilling / hasn't run yet
                }
                let over_quantum = self.opts.preempt_tokens > 0
                    && a.decoded_since_admit >= self.opts.preempt_tokens;
                let over_deadline = a
                    .req
                    .deadline_ms
                    .is_some_and(|d| a.admitted_at.elapsed().as_millis() as u64 > d);
                if (over_quantum || over_deadline)
                    && pick.is_none_or(|(_, n)| a.decoded_since_admit > n)
                {
                    pick = Some((i, a.decoded_since_admit));
                }
            }
            let Some((slot_idx, _)) = pick else { break };
            let (snapshot, a) = {
                let _span = self.metrics.park_us.span();
                let snapshot = self.exec.snapshot_slot(slot_idx)?;
                let a = self.slots[slot_idx].take().expect("picked an active slot");
                self.exec.release_slot(slot_idx);
                (snapshot, a)
            };
            self.metrics.preemptions.inc();
            let (trace, rid) = (a.req.trace, a.req.id);
            let parked_seq = self.scheduler.park(
                a.req,
                ParkedWork {
                    snapshot,
                    absorbed: a.absorbed,
                    generated: a.generated,
                    last_token: a.last_token,
                    first_token_at: a.first_token_at,
                    utf8_buf: a.utf8_buf,
                },
            );
            self.flight.record(FlightEvent::Park, trace, rid);
            // hand the freed slot to an actual waiter: the evictee is
            // excluded so a non-FIFO policy can't pick it right back
            // (it becomes eligible again at the next admission)
            if !self.admit_next(Some(parked_seq))? {
                break;
            }
        }
        Ok(())
    }

    /// Main loop: drain `rx` into the scheduler, admit per policy, step
    /// while anything is active, preempt for waiters, block when idle.
    /// Exits when `rx` disconnects and all work drains.
    pub fn run(&mut self, rx: Receiver<Request>) -> Result<ServeStats> {
        self.run_inner(rx, EngineMsg::Req)
    }

    /// [`Engine::run`] over a full [`EngineMsg`] inbox — how a shard
    /// thread runs the engine, so migration exports/imports and stats
    /// probes interleave with requests at loop granularity.
    pub fn run_msgs(&mut self, rx: Receiver<EngineMsg>) -> Result<ServeStats> {
        self.run_inner(rx, |m| m)
    }

    /// One loop for both entry points: `into_msg` lifts whatever the
    /// channel carries into an [`EngineMsg`].
    fn run_inner<T, F: Fn(T) -> EngineMsg>(
        &mut self,
        rx: Receiver<T>,
        into_msg: F,
    ) -> Result<ServeStats> {
        let t0 = Instant::now();
        let mut disconnected = false;
        loop {
            loop {
                match rx.try_recv() {
                    Ok(r) => {
                        let m = into_msg(r);
                        self.handle_msg(m);
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            while self.admit_next(None)? {}
            if !self.has_active() {
                if disconnected {
                    break;
                }
                // idle: publish the (empty) load, block for the next
                // message
                self.publish();
                match rx.recv() {
                    Ok(r) => {
                        let m = into_msg(r);
                        self.handle_msg(m);
                    }
                    Err(_) => disconnected = true,
                }
                continue;
            }
            self.publish();
            self.step()?;
            self.preempt_for_waiters()?;
        }
        self.publish();
        Ok(self.snapshot_stats(t0.elapsed().as_secs_f64()))
    }
}

/// Serve over TCP with JSON-lines framing (default scheduling, one
/// shard).  Blocks forever.
pub fn serve_tcp(exec: Box<dyn Executor + Send>, addr: &str, seed: u64) -> Result<()> {
    serve_tcp_opts(exec, addr, seed, ServeOpts::default())
}

/// [`serve_tcp`] with explicit [`ServeOpts`] (scheduler policy, prefill
/// chunk, session cache, preemption quantum, stream default).  One
/// shard; even so the router front end answers `{"stats": true}` probes.
pub fn serve_tcp_opts(
    exec: Box<dyn Executor + Send>,
    addr: &str,
    seed: u64,
    opts: ServeOpts,
) -> Result<()> {
    serve_tcp_sharded(vec![exec], addr, seed, opts, RouterOpts::default())
}

/// Sharded TCP serving: one engine per executor, each on its own core,
/// behind a session [`Router`] (see `serve/router.rs` for placement,
/// migration and load-shedding semantics).  All executors must hold
/// identical parameters.  Blocks forever.
pub fn serve_tcp_sharded(
    execs: Vec<Box<dyn Executor + Send>>,
    addr: &str,
    seed: u64,
    opts: ServeOpts,
    ropts: RouterOpts,
) -> Result<()> {
    ensure!(!execs.is_empty(), "serve needs at least one shard");
    let (tx, rx) = channel::<RouterMsg>();
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "[serve] {} backend, model {} — listening on {addr} with {} shard(s) \
         (JSON lines: {{\"prompt\": ..}} or {{\"stats\": true}}; \
         policy={} chunk={} session_cache/shard={}MiB state_dtype={} preempt={} global_queue={})",
        execs[0].backend_name(),
        execs[0].model().name,
        execs.len(),
        opts.policy.name(),
        opts.prefill_chunk,
        opts.session_cache_bytes >> 20,
        opts.state_dtype,
        opts.preempt_tokens,
        ropts.global_queue,
    );

    // acceptor threads feed the router channel
    let accept_tx = tx.clone();
    let stream_default = opts.stream_default;
    std::thread::spawn(move || {
        let mut next_id = 0u64;
        for conn in listener.incoming().flatten() {
            next_id += 1;
            let tx = accept_tx.clone();
            let base_id = next_id * 1_000_000;
            std::thread::spawn(move || {
                let _ = handle_conn(conn, tx, base_id, stream_default);
            });
        }
    });
    drop(tx);

    let router = Router::new(execs, seed, opts, ropts)?;
    let (per_shard, report) = router.run(rx)?;
    eprintln!(
        "[serve] router exited (migrations={} shed={})",
        report.migrations, report.rejected
    );
    for (i, stats) in per_shard.iter().enumerate() {
        eprintln!("[serve] shard {i}\n{}", stats.report());
    }
    Ok(())
}

/// One TCP connection: a reader loop that hands every parsed request to
/// the engine immediately (so pipelined JSON lines batch together — no
/// per-request blocking recv) and a writer thread that serializes engine
/// events back in completion order.  The writer exits when the reader is
/// done *and* every in-flight request has delivered its final event
/// (each request holds a clone of the event sender until then).
fn handle_conn(
    conn: TcpStream,
    tx: Sender<RouterMsg>,
    base_id: u64,
    stream_default: bool,
) -> Result<()> {
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    let (etx, erx) = channel::<ServeEvent>();
    // once a write fails the client is gone: the writer stops and the
    // reader must stop submitting its remaining pipelined lines, or the
    // engine decodes completions nobody will receive
    let client_gone = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let writer_gone = client_gone.clone();
    let writer_handle = std::thread::spawn(move || {
        for ev in erx {
            if writeln!(writer, "{}", stream::event_json(&ev)).is_err() {
                writer_gone.store(true, std::sync::atomic::Ordering::Relaxed);
                break;
            }
        }
    });
    let tok = ByteTokenizer::new();
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if client_gone.load(std::sync::atomic::Ordering::Relaxed) {
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        // every line — parseable or not — consumes an id, so pipelined
        // clients can correlate an error line to the request it answers
        n += 1;
        let req_json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ =
                    etx.send(ServeEvent::Done(Response::error(base_id + n, format!("{e}"))));
                continue;
            }
        };
        if req_json.get("stats").and_then(|j| j.as_bool()) == Some(true) {
            // observability probe, answered by the router itself — does
            // not consume a scheduling slot on any shard
            if tx.send(RouterMsg::Stats { respond: etx.clone() }).is_err() {
                break; // router gone
            }
            continue;
        }
        if req_json.get("metrics").and_then(|j| j.as_bool()) == Some(true) {
            // full registry dump (router aggregates + per-shard)
            if tx.send(RouterMsg::Metrics { respond: etx.clone() }).is_err() {
                break; // router gone
            }
            continue;
        }
        if let Some(id) = req_json.get("trace").and_then(|j| j.as_i64()) {
            // flight-recorder lookup: every lifecycle event logged under
            // this router-minted trace id, across all shards, in order
            if tx
                .send(RouterMsg::Trace { id: id.max(0) as u64, respond: etx.clone() })
                .is_err()
            {
                break; // router gone
            }
            continue;
        }
        let prompt = req_json.get("prompt").and_then(|j| j.as_str()).unwrap_or("");
        let mut req =
            Request::new(base_id + n, tok.encode_with_specials(prompt, false), etx.clone());
        if let Some(v) = req_json.get("max_tokens").and_then(|j| j.as_i64()) {
            req.max_tokens = v.max(0) as usize;
        }
        if let Some(v) = req_json.get("temperature").and_then(|j| j.as_f64()) {
            req.temperature = v as f32;
        }
        if let Some(v) = req_json.get("top_k").and_then(|j| j.as_i64()) {
            req.top_k = v.max(0) as usize;
        }
        if let Some(v) = req_json.get("priority").and_then(|j| j.as_i64()) {
            req.priority = v;
        }
        if let Some(v) = req_json.get("client").and_then(|j| j.as_str()) {
            req.client = v.to_string();
        }
        if let Some(v) = req_json.get("deadline_ms").and_then(|j| j.as_i64()) {
            req.deadline_ms = Some(v.max(0) as u64);
        }
        if let Some(v) = req_json.get("session_id").and_then(|j| j.as_str()) {
            req.session_id = Some(v.to_string());
        }
        req.stream = req_json
            .get("stream")
            .and_then(|j| j.as_bool())
            .unwrap_or(stream_default);
        if tx.send(RouterMsg::Req(req)).is_err() {
            break; // router gone
        }
    }
    drop(etx);
    let _ = writer_handle.join();
    Ok(())
}

/// Synthetic load with default scheduling — see [`run_synthetic_opts`].
pub fn run_synthetic(
    exec: Box<dyn Executor + '_>,
    n_requests: usize,
    prompt_len: usize,
    max_tokens: usize,
    gap_ms: u64,
    seed: u64,
) -> Result<ServeStats> {
    run_synthetic_opts(exec, n_requests, prompt_len, max_tokens, gap_ms, seed, ServeOpts::default())
}

/// Synthetic load: `n_requests` prompts drawn from the embedded corpus,
/// arrivals spaced `gap_ms` apart (client ids cycle over four synthetic
/// tenants so fair-share has something to balance), all through the
/// continuous-batching engine under `opts`.  Returns aggregate stats
/// (E4 bench / serve example / `results/bench_serve.json`).
pub fn run_synthetic_opts(
    exec: Box<dyn Executor + '_>,
    n_requests: usize,
    prompt_len: usize,
    max_tokens: usize,
    gap_ms: u64,
    seed: u64,
    opts: ServeOpts,
) -> Result<ServeStats> {
    let (tx, rx) = channel::<Request>();
    let (rtx, _rrx) = channel::<ServeEvent>();
    let corpus = crate::data::charlm::CORPUS.as_bytes();
    let prompt_len = prompt_len.min(corpus.len().saturating_sub(1));
    let mut rng = Rng::new(seed ^ 0x10ad);
    std::thread::spawn(move || {
        for i in 0..n_requests {
            let start = rng.uniform_int(0, (corpus.len() - prompt_len) as u64) as usize;
            let prompt_ids: Vec<i32> = std::iter::once(crate::tokenizer::BOS)
                .chain(corpus[start..start + prompt_len].iter().map(|&b| b as i32))
                .collect();
            let mut req = Request::new(i as u64, prompt_ids, rtx.clone());
            req.max_tokens = max_tokens;
            req.client = format!("tenant{}", i % 4);
            if tx.send(req).is_err() {
                return;
            }
            if gap_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(gap_ms));
            }
        }
    });
    let mut engine = Engine::with_opts(exec, seed, opts)?;
    engine.run(rx)
}

/// Multi-turn synthetic load for the session cache: `n_sessions`
/// conversations of `turns` turns each.  Every follow-up prompt is the
/// previous prompt + the previous completion + a little fresh corpus
/// text, sent under the same `session_id` — so turns ≥ 2 exercise the
/// restore-and-skip-prefix path (`stats.session_hits`).
pub fn run_synthetic_sessions(
    exec: Box<dyn Executor + '_>,
    n_sessions: usize,
    turns: usize,
    prompt_len: usize,
    max_tokens: usize,
    seed: u64,
    opts: ServeOpts,
) -> Result<ServeStats> {
    let max_len = exec.model().config.max_len;
    let (tx, rx) = channel::<Request>();
    let corpus = crate::data::charlm::CORPUS.as_bytes();
    let prompt_len = prompt_len.min(corpus.len().saturating_sub(1));
    let mut rng = Rng::new(seed ^ 0x5e55);
    let starts: Vec<usize> = (0..n_sessions)
        .map(|_| rng.uniform_int(0, (corpus.len() - prompt_len) as u64) as usize)
        .collect();
    std::thread::spawn(move || {
        let mut histories: Vec<Vec<i32>> = starts
            .iter()
            .map(|&s| {
                std::iter::once(crate::tokenizer::BOS)
                    .chain(corpus[s..s + prompt_len].iter().map(|&b| b as i32))
                    .collect()
            })
            .collect();
        for turn in 0..turns {
            let (etx, erx) = channel::<ServeEvent>();
            let mut sent = 0usize;
            for (s, history) in histories.iter().enumerate() {
                if history.len() + max_tokens > max_len {
                    continue; // conversation outgrew the context window
                }
                let mut req =
                    Request::new((turn * n_sessions + s) as u64, history.clone(), etx.clone());
                req.max_tokens = max_tokens;
                req.client = format!("sess{s}");
                req.session_id = Some(format!("sess{s}"));
                if tx.send(req).is_err() {
                    return;
                }
                sent += 1;
            }
            drop(etx);
            let mut done = 0usize;
            for ev in erx {
                let ServeEvent::Done(resp) = ev else { continue };
                let s = (resp.id as usize) % n_sessions;
                if resp.error.is_none() {
                    // extend the conversation: completion + 4 fresh bytes
                    histories[s].extend(&resp.token_ids);
                    let at = starts[s] % (corpus.len() - 4);
                    histories[s].extend(corpus[at..at + 4].iter().map(|&b| b as i32));
                }
                done += 1;
                if done == sent {
                    break;
                }
            }
        }
    });
    let mut engine = Engine::with_opts(exec, seed, opts)?;
    engine.run(rx)
}

/// Knobs for the multi-shard overload bench ([`run_overload_sharded`]).
#[derive(Debug, Clone)]
pub struct OverloadOpts {
    /// total requests offered across the run
    pub requests: usize,
    /// distinct synthetic sessions; per-request session rank is drawn
    /// Zipf(`zipf_s`), so a few sessions are hot (stressing affinity +
    /// migration) and a long tail is cold (stressing cache eviction)
    pub sessions: usize,
    pub prompt_len: usize,
    pub max_tokens: usize,
    /// Zipf skew exponent (1.0–1.5 typical; higher = hotter head)
    pub zipf_s: f64,
    /// pause between offered requests (0 = open the firehose, letting
    /// admission control and load shedding do the pacing)
    pub gap_ms: u64,
}

impl Default for OverloadOpts {
    fn default() -> Self {
        OverloadOpts {
            requests: 256,
            sessions: 64,
            prompt_len: 24,
            max_tokens: 8,
            zipf_s: 1.1,
            gap_ms: 0,
        }
    }
}

/// What the overload bench measured: aggregate counters over the whole
/// run plus every shard's own [`ServeStats`].
pub struct OverloadReport {
    pub shards: usize,
    pub offered: usize,
    pub sessions: usize,
    /// wall clock from first offered request to last delivered response
    pub wall_s: f64,
    /// successful responses seen by the synthetic clients
    pub completed: u64,
    /// error responses seen by the clients (router shed + per-shard
    /// queue-bound rejections + oversized prompts)
    pub rejected: u64,
    /// session entries shipped between shard cache partitions
    pub migrations: u64,
    /// requests shed by the router's global admission budget
    pub router_rejected: u64,
    pub generated_tokens: u64,
    /// ttft/latency samples pooled across shards (histogram merge, so
    /// percentiles are over the pool, not averaged per-shard quantiles)
    pub ttft: HistoSnapshot,
    pub latency: HistoSnapshot,
    pub per_shard: Vec<ServeStats>,
}

impl OverloadReport {
    /// Aggregate decode throughput over the bench's own wall clock (the
    /// per-shard `tok_per_s` figures use each engine's idle-inclusive
    /// wall and understate a loaded run).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    /// One record for `results/bench_serve.json`: aggregate p50/p95/p99 +
    /// tok/s + migration/shed counters, with the per-shard stats inline.
    pub fn to_json(&self) -> Json {
        let Json::Obj(mut fields) = obj(vec![
            ("shards", self.shards.into()),
            ("offered", self.offered.into()),
            ("sessions", self.sessions.into()),
            ("wall_s", self.wall_s.into()),
            ("completed", (self.completed as i64).into()),
            ("rejected", (self.rejected as i64).into()),
            ("migrations", (self.migrations as i64).into()),
            ("router_rejected", (self.router_rejected as i64).into()),
            ("generated_tokens", (self.generated_tokens as i64).into()),
            ("tok_per_s", self.tokens_per_sec().into()),
        ]) else {
            unreachable!("obj builds an object")
        };
        self.ttft.push_ms_fields("ttft", &mut fields);
        self.latency.push_ms_fields("latency", &mut fields);
        fields.push((
            "per_shard".to_string(),
            Json::Arr(self.per_shard.iter().map(|s| s.to_json()).collect()),
        ));
        Json::Obj(fields)
    }

    pub fn report(&self) -> String {
        format!(
            "shards={} offered={} completed={} rejected={} (router shed {}) \
             migrations={} tokens={} wall={:.2}s aggregate={:.1} tok/s\n  \
             ttft: {}\n  request latency: {}",
            self.shards,
            self.offered,
            self.completed,
            self.rejected,
            self.router_rejected,
            self.migrations,
            self.generated_tokens,
            self.wall_s,
            self.tokens_per_sec(),
            self.ttft.summary(),
            self.latency.summary(),
        )
    }
}

/// The multi-shard overload bench behind `holt serve --synthetic
/// --shards N`: one engine shard per executor behind a [`Router`],
/// offered `bench.requests` requests over `bench.sessions` synthetic
/// sessions with Zipf-skewed reuse and mixed priorities.  Hot sessions
/// revisit their shard (session-cache hits), hash-unlucky hot shards
/// saturate and trigger snapshot migration, and offered load beyond the
/// admission budgets is shed — all counted in the returned
/// [`OverloadReport`].
pub fn run_overload_sharded(
    execs: Vec<Box<dyn Executor + Send>>,
    seed: u64,
    opts: ServeOpts,
    ropts: RouterOpts,
    bench: OverloadOpts,
) -> Result<OverloadReport> {
    ensure!(!execs.is_empty(), "overload bench needs at least one shard");
    ensure!(bench.sessions > 0, "overload bench needs at least one session");
    ensure!(bench.sessions < (1 << 24), "session ranks are packed into 24 bits of the id");
    let shards = execs.len();
    let max_len = execs[0].model().config.max_len;
    let corpus = crate::data::charlm::CORPUS.as_bytes();
    let prompt_len = bench.prompt_len.min(corpus.len().saturating_sub(1));
    let base_prompt = move |rank: usize| -> Vec<i32> {
        let start = rank.wrapping_mul(2_654_435_761) % (corpus.len() - prompt_len);
        std::iter::once(crate::tokenizer::BOS)
            .chain(corpus[start..start + prompt_len].iter().map(|&b| b as i32))
            .collect()
    };

    // Zipf CDF over session ranks: weight(r) = 1/(r+1)^s
    let mut cdf = Vec::with_capacity(bench.sessions);
    let mut total = 0.0f64;
    for r in 0..bench.sessions {
        total += 1.0 / ((r + 1) as f64).powf(bench.zipf_s);
        cdf.push(total);
    }

    let mut router = Router::new(execs, seed, opts, ropts)?;

    // Conversation histories shared between the offer loop (reads the
    // current history as the next prompt) and the collector (appends
    // each completion).  A data race between a completion landing and
    // the next turn being offered only re-sends an already-absorbed
    // prefix — a session-cache hit either way, never a wrong result.
    let histories: Arc<Mutex<HashMap<usize, Vec<i32>>>> = Arc::new(Mutex::new(HashMap::new()));
    let (etx, erx) = channel::<ServeEvent>();
    let coll_histories = histories.clone();
    let collector = std::thread::spawn(move || {
        let mut completed = 0u64;
        let mut rejected = 0u64;
        for ev in erx {
            let ServeEvent::Done(resp) = ev else { continue };
            if resp.error.is_some() {
                rejected += 1;
                continue;
            }
            completed += 1;
            let rank = (resp.id & 0x00ff_ffff) as usize;
            let mut h = coll_histories.lock().expect("histories lock");
            if let Some(hist) = h.get_mut(&rank) {
                hist.extend(&resp.token_ids);
            }
        }
        (completed, rejected)
    });

    let mut rng = Rng::new(seed ^ 0x0eb1_0ad);
    let t0 = Instant::now();
    for i in 0..bench.requests {
        let u = rng.uniform() * total;
        let rank = cdf.partition_point(|&c| c < u).min(bench.sessions - 1);
        let prompt = {
            let mut h = histories.lock().expect("histories lock");
            let hist = h.entry(rank).or_insert_with(|| base_prompt(rank));
            if hist.len() + bench.max_tokens > max_len {
                // conversation outgrew the context window: restart it
                *hist = base_prompt(rank);
            }
            hist.clone()
        };
        let mut req = Request::new(((i as u64) << 24) | rank as u64, prompt, etx.clone());
        req.max_tokens = bench.max_tokens;
        req.priority = rng.uniform_int(0, 4) as i64 - 1; // mixed -1..=2
        req.client = format!("tenant{}", rank % 8);
        req.session_id = Some(format!("z{rank}"));
        router.route(req);
        if bench.gap_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(bench.gap_ms));
        }
    }
    drop(etx);
    let (completed, rejected) = collector
        .join()
        .map_err(|_| anyhow::anyhow!("overload collector thread panicked"))?;
    let wall_s = t0.elapsed().as_secs_f64();

    let migrations = router.report().migrations;
    let router_rejected = router.report().rejected;
    let (per_shard, _) = router.finish()?;
    let mut ttft = HistoSnapshot::new();
    let mut latency = HistoSnapshot::new();
    let mut generated_tokens = 0u64;
    for s in &per_shard {
        ttft.merge(&s.ttft);
        latency.merge(&s.per_request);
        generated_tokens += s.generated_tokens;
    }
    Ok(OverloadReport {
        shards,
        offered: bench.requests,
        sessions: bench.sessions,
        wall_s,
        completed,
        rejected,
        migrations,
        router_rejected,
        generated_tokens,
        ttft,
        latency,
        per_shard,
    })
}
