//! Continuous-batching serve loop (the vLLM-style coordinator, for a model
//! whose "KV cache" is O(1) per sequence).
//!
//! The engine owns an [`Executor`] — native pure-Rust or PJRT artifact —
//! and schedules at **token granularity**: every engine step runs one
//! decode step over all B slots; requests join the batch the moment a
//! slot is free (mid-flight of everyone else) and leave on EOS/limit.
//! Prefill is streamed through the same recurrence — a prompt token per
//! step — so a long prompt never head-of-line-blocks other slots'
//! decoding.
//!
//! Front ends:
//! * [`serve_tcp`] — JSON-lines-over-TCP: `{"prompt": ..., "max_tokens":
//!   ..}` per line, one JSON response line per request.
//! * [`run_synthetic`] — in-process load driver used by `holt serve
//!   --synthetic`, the E4 bench and the serve_decode example.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::json::{obj, Json};
use crate::metrics::Latencies;
use crate::model::Executor;
use crate::rng::Rng;
use crate::tokenizer::{ByteTokenizer, EOS, PAD};

/// One inbound generation request.
pub struct Request {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub enqueued: Instant,
    pub respond: Sender<Response>,
}

/// The engine's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub token_ids: Vec<i32>,
    pub text: String,
    /// queue + prefill time until the first generated token
    pub ttft_s: f64,
    pub total_s: f64,
}

struct Active {
    req: Request,
    slot: usize,
    /// next prompt index to feed (prefill cursor)
    prompt_pos: usize,
    generated: Vec<i32>,
    last_token: i32,
    first_token_at: Option<Instant>,
}

/// Aggregate serving statistics — everything the perf trajectory needs,
/// JSON-serializable via [`ServeStats::to_json`] so benches land in
/// `results/bench_serve.json`.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    pub generated_tokens: u64,
    pub engine_steps: u64,
    pub ttft: Latencies,
    pub per_request: Latencies,
    pub wall_s: f64,
    /// which executor ran ("native" / "artifact")
    pub backend: String,
    pub model: String,
    pub n_slots: usize,
    /// per-slot decode state footprint (bytes) — O(1) in context for
    /// ho2/linear, max_len-sized KV cache for softmax
    pub state_bytes_per_slot: usize,
}

impl ServeStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    pub fn report(&self) -> String {
        format!(
            "backend={} model={} slots={} state/slot={:.1}KiB\n\
             requests={} tokens={} steps={} wall={:.2}s throughput={:.1} tok/s\n  \
             ttft: {}\n  request latency: {}",
            self.backend,
            self.model,
            self.n_slots,
            self.state_bytes_per_slot as f64 / 1024.0,
            self.completed,
            self.generated_tokens,
            self.engine_steps,
            self.wall_s,
            self.tokens_per_sec(),
            self.ttft.summary(),
            self.per_request.summary(),
        )
    }

    /// Machine-readable record for `results/bench_serve.json`.
    pub fn to_json(&self) -> Json {
        // one sort per recorder for both percentile reads
        let ttft = self.ttft.percentiles_us(&[50.0, 95.0]);
        let lat = self.per_request.percentiles_us(&[50.0, 95.0]);
        obj(vec![
            ("backend", self.backend.as_str().into()),
            ("model", self.model.as_str().into()),
            ("n_slots", self.n_slots.into()),
            ("state_bytes_per_slot", self.state_bytes_per_slot.into()),
            ("requests_completed", (self.completed as i64).into()),
            ("generated_tokens", (self.generated_tokens as i64).into()),
            ("engine_steps", (self.engine_steps as i64).into()),
            ("wall_s", self.wall_s.into()),
            ("tok_per_s", self.tokens_per_sec().into()),
            ("ttft_p50_ms", (ttft[0] as f64 / 1e3).into()),
            ("ttft_p95_ms", (ttft[1] as f64 / 1e3).into()),
            ("latency_p50_ms", (lat[0] as f64 / 1e3).into()),
            ("latency_p95_ms", (lat[1] as f64 / 1e3).into()),
        ])
    }
}

/// The continuous-batching engine over any [`Executor`].
pub struct Engine<'a> {
    exec: Box<dyn Executor + 'a>,
    slots: Vec<Option<Active>>,
    rng: Rng,
    vocab: usize,
    max_len: usize,
}

impl<'a> Engine<'a> {
    pub fn new(exec: Box<dyn Executor + 'a>, seed: u64) -> Result<Self> {
        anyhow::ensure!(
            exec.supports_decode(),
            "model '{}' cannot decode on the {} backend",
            exec.model().name,
            exec.backend_name()
        );
        let n = exec.n_slots();
        let vocab = exec.model().config.vocab_size;
        let max_len = exec.model().config.max_len;
        Ok(Engine {
            exec,
            slots: (0..n).map(|_| None).collect(),
            rng: Rng::new(seed),
            vocab,
            max_len,
        })
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    fn has_active(&self) -> bool {
        self.slots.iter().any(Option::is_some)
    }

    /// Try to admit one request; gives the request back when no slot is
    /// free.  Oversized prompts are rejected immediately (error response).
    fn admit(&mut self, req: Request) -> Option<Request> {
        if req.prompt_ids.len() + req.max_tokens > self.max_len {
            // reject oversized requests right away
            let _ = req.respond.send(Response {
                id: req.id,
                token_ids: vec![],
                text: String::new(),
                ttft_s: -1.0,
                total_s: -1.0,
            });
            return None; // consumed
        }
        let Some(slot) = self.exec.alloc_slot() else {
            return Some(req);
        };
        self.slots[slot] = Some(Active {
            slot,
            prompt_pos: 0,
            generated: Vec::with_capacity(req.max_tokens),
            last_token: PAD,
            first_token_at: None,
            req,
        });
        None
    }

    /// One engine step: build the feed vector, run the executor's decode
    /// step (which advances every active slot), sample/advance request
    /// state.  Returns finished responses.
    fn step(&mut self, stats: &mut ServeStats) -> Result<Vec<Response>> {
        let b = self.n_slots();
        let mut feed = vec![PAD; b];
        for s in self.slots.iter().flatten() {
            feed[s.slot] = if s.prompt_pos < s.req.prompt_ids.len() {
                s.req.prompt_ids[s.prompt_pos]
            } else {
                s.last_token
            };
        }
        let logits = self.exec.decode_step(&feed)?;
        stats.engine_steps += 1;
        let lf = logits.as_f32()?;

        let mut done = Vec::new();
        for slot_idx in 0..b {
            let Some(mut a) = self.slots[slot_idx].take() else {
                continue;
            };
            if a.prompt_pos < a.req.prompt_ids.len() {
                a.prompt_pos += 1;
                if a.prompt_pos < a.req.prompt_ids.len() {
                    self.slots[slot_idx] = Some(a);
                    continue;
                }
                // prompt fully consumed this step: fall through to sample
            }
            let row = &lf[slot_idx * self.vocab..(slot_idx + 1) * self.vocab];
            let next =
                self.rng.sample_logits(row, a.req.temperature, a.req.top_k) as i32;
            if a.first_token_at.is_none() {
                a.first_token_at = Some(Instant::now());
            }
            let hit_eos = next == EOS;
            if !hit_eos {
                a.generated.push(next);
                a.last_token = next;
            }
            let over_budget = a.generated.len() >= a.req.max_tokens
                || self.exec.pos(slot_idx) >= self.max_len - 1;
            if hit_eos || over_budget {
                let now = Instant::now();
                let ttft = a
                    .first_token_at
                    .map(|t| t.duration_since(a.req.enqueued))
                    .unwrap_or_default();
                stats.completed += 1;
                stats.generated_tokens += a.generated.len() as u64;
                stats.ttft.push(ttft);
                stats.per_request.push(now.duration_since(a.req.enqueued));
                let resp = Response {
                    id: a.req.id,
                    text: ByteTokenizer::new().decode(&a.generated),
                    token_ids: a.generated,
                    ttft_s: ttft.as_secs_f64(),
                    total_s: now.duration_since(a.req.enqueued).as_secs_f64(),
                };
                let _ = a.req.respond.send(resp.clone());
                self.exec.release_slot(slot_idx);
                done.push(resp);
            } else {
                self.slots[slot_idx] = Some(a);
            }
        }
        Ok(done)
    }

    /// Main loop: admit from `rx`, step while anything is active, block
    /// when idle.  Exits when `rx` disconnects and all slots drain.
    pub fn run(&mut self, rx: Receiver<Request>) -> Result<ServeStats> {
        let mut stats = ServeStats {
            backend: self.exec.backend_name().to_string(),
            model: self.exec.model().name.clone(),
            n_slots: self.n_slots(),
            state_bytes_per_slot: self.exec.state_bytes_per_slot(),
            ..ServeStats::default()
        };
        let t0 = Instant::now();
        let mut pending: Vec<Request> = Vec::new();
        let mut disconnected = false;
        loop {
            // admit as many queued requests as possible
            loop {
                if pending.is_empty() {
                    match rx.try_recv() {
                        Ok(r) => pending.push(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
                let Some(r) = pending.pop() else { break };
                if let Some(back) = self.admit(r) {
                    pending.push(back); // no free slot — retry next step
                    break;
                }
            }
            if !self.has_active() {
                if disconnected {
                    break;
                }
                // idle: block for the next request
                match rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
                continue;
            }
            self.step(&mut stats)?;
        }
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }
}

/// Serve over TCP with JSON-lines framing.  Blocks forever.
pub fn serve_tcp(exec: Box<dyn Executor + '_>, addr: &str, seed: u64) -> Result<()> {
    let (tx, rx) = channel::<Request>();
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "[serve] {} backend, model {} — listening on {addr} (JSON lines: {{\"prompt\": ..}})",
        exec.backend_name(),
        exec.model().name
    );

    // acceptor threads feed the engine channel
    let accept_tx = tx.clone();
    std::thread::spawn(move || {
        let mut next_id = 0u64;
        for conn in listener.incoming().flatten() {
            next_id += 1;
            let tx = accept_tx.clone();
            let base_id = next_id * 1_000_000;
            std::thread::spawn(move || {
                let _ = handle_conn(conn, tx, base_id);
            });
        }
    });
    drop(tx);

    let mut engine = Engine::new(exec, seed)?;
    let stats = engine.run(rx)?;
    eprintln!("[serve] engine exited\n{}", stats.report());
    Ok(())
}

fn handle_conn(conn: TcpStream, tx: Sender<Request>, base_id: u64) -> Result<()> {
    let peer = conn.peer_addr()?;
    let mut writer = conn.try_clone()?;
    let reader = BufReader::new(conn);
    let tok = ByteTokenizer::new();
    let mut n = 0u64;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req_json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", format!("{e}").into())]))?;
                continue;
            }
        };
        let prompt = req_json.get("prompt").and_then(|j| j.as_str()).unwrap_or("");
        let max_tokens = req_json
            .get("max_tokens")
            .and_then(|j| j.as_i64())
            .unwrap_or(64) as usize;
        let temperature = req_json
            .get("temperature")
            .and_then(|j| j.as_f64())
            .unwrap_or(0.8) as f32;
        let top_k =
            req_json.get("top_k").and_then(|j| j.as_i64()).unwrap_or(40) as usize;
        n += 1;
        let (rtx, rrx) = channel();
        tx.send(Request {
            id: base_id + n,
            prompt_ids: tok.encode_with_specials(prompt, false),
            max_tokens,
            temperature,
            top_k,
            enqueued: Instant::now(),
            respond: rtx,
        })
        .map_err(|_| anyhow::anyhow!("engine gone"))?;
        let resp = rrx.recv()?;
        writeln!(
            writer,
            "{}",
            obj(vec![
                ("id", (resp.id as i64).into()),
                ("text", resp.text.as_str().into()),
                ("n_tokens", resp.token_ids.len().into()),
                ("ttft_s", resp.ttft_s.into()),
                ("total_s", resp.total_s.into()),
            ])
        )?;
    }
    let _ = peer;
    Ok(())
}

/// Synthetic load: `n_requests` prompts drawn from the embedded corpus,
/// arrivals spaced `gap_ms` apart, all through the continuous-batching
/// engine.  Returns aggregate stats (E4 bench / serve example /
/// `results/bench_serve.json`).
pub fn run_synthetic(
    exec: Box<dyn Executor + '_>,
    n_requests: usize,
    prompt_len: usize,
    max_tokens: usize,
    gap_ms: u64,
    seed: u64,
) -> Result<ServeStats> {
    let (tx, rx) = channel::<Request>();
    let (rtx, _rrx) = channel::<Response>();
    let corpus = crate::data::charlm::CORPUS.as_bytes();
    let prompt_len = prompt_len.min(corpus.len().saturating_sub(1));
    let mut rng = Rng::new(seed ^ 0x10ad);
    std::thread::spawn(move || {
        for i in 0..n_requests {
            let start = rng.uniform_int(0, (corpus.len() - prompt_len) as u64) as usize;
            let prompt_ids: Vec<i32> = std::iter::once(crate::tokenizer::BOS)
                .chain(corpus[start..start + prompt_len].iter().map(|&b| b as i32))
                .collect();
            if tx
                .send(Request {
                    id: i as u64,
                    prompt_ids,
                    max_tokens,
                    temperature: 0.8,
                    top_k: 40,
                    enqueued: Instant::now(),
                    respond: rtx.clone(),
                })
                .is_err()
            {
                return;
            }
            if gap_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(gap_ms));
            }
        }
    });
    let mut engine = Engine::new(exec, seed)?;
    engine.run(rx)
}
