//! L3 coordinator — the system around the paper's attention.
//!
//! The paper's contribution is numeric (L1/L2), so the coordinator is the
//! production harness a user would actually run:
//!
//! * [`trainer`] — training orchestrator behind the `TrainBackend`
//!   trait: native hand-derived backward + AdamW, or the fused-AdamW
//!   artifact; data feed, lr schedule, eval, metrics (JSONL),
//!   checkpointing.
//! * [`state`] — the recurrent decode-state manager.  Because HO linear
//!   attention is an RNN with O(1) state, the serving "KV cache" is a
//!   fixed set of slots; this module owns slot allocation/reset and
//!   per-slot positions.
//! * [`generation`] — autoregressive sampling driver over the decode
//!   artifact (greedy / temperature / top-k).
//! * [`server`] — continuous-batching serve loop (vLLM-style, at token
//!   granularity) with a JSON-lines TCP front end and synthetic
//!   load-driver modes for benches.  Scheduling (policy admission,
//!   chunked prefill, O(1)-state preemption, the session cache,
//!   streaming) lives in [`crate::serve`]; the engine here keeps only
//!   the step loop.

pub mod generation;
pub mod server;
pub mod state;
pub mod trainer;
