//! Autoregressive generation over an [`Executor`].
//!
//! Drives one decode slot token by token for a single prompt (the
//! `holt generate` path) — backend-agnostic: hand it a
//! [`NativeExecutor`](crate::model::NativeExecutor) for the zero-setup
//! pure-Rust path or an [`ArtifactExecutor`](crate::model::ArtifactExecutor)
//! for PJRT.  Batched multi-request decoding lives in
//! [`server`](crate::coordinator::server); this module also hosts the raw
//! artifact decode-step plumbing the artifact executor and the E4 bench
//! share.

use anyhow::{anyhow, bail, Result};

use crate::coordinator::state::StateManager;
use crate::model::Executor;
use crate::params::ParamStore;
use crate::rng::Rng;
use crate::runtime::{Executable, ModelEntry, Tensor};
use crate::serve::{Prefiller, DEFAULT_PREFILL_CHUNK};
use crate::tokenizer::{ByteTokenizer, EOS, PAD};

/// Sampling parameters.
#[derive(Debug, Clone, Copy)]
pub struct SampleOpts {
    pub temperature: f32,
    pub top_k: usize,
    pub max_tokens: usize,
}

impl Default for SampleOpts {
    fn default() -> Self {
        SampleOpts { temperature: 0.8, top_k: 40, max_tokens: 64 }
    }
}

/// Parameters converted to PJRT literals once, reused every decode step.
///
/// §Perf (EXPERIMENTS.md): parameters are constant during decoding, but the
/// naive path cloned every leaf and re-built its literal per token — for
/// the small model that is ~13 MB of copies per generated token.  Caching
/// the literals removes that entirely; only the (much smaller) recurrent
/// state, token and pos tensors are converted per step.
pub struct CachedParams {
    lits: Vec<xla::Literal>,
    pub n_leaves: usize,
}

impl CachedParams {
    pub fn new(params: &ParamStore) -> Result<Self> {
        let lits: Result<Vec<xla::Literal>> =
            params.leaves.iter().map(|t| t.to_literal()).collect();
        Ok(CachedParams { lits: lits?, n_leaves: params.len() })
    }
}

/// Run one batched decode step through the decode artifact: feeds
/// `token[b]` at `pos[b]` for every slot, updates the state manager,
/// returns logits (B, V).  (Position advancement is the caller's business
/// — [`crate::model::ArtifactExecutor`] advances active slots only.)
pub fn decode_step(
    exe: &Executable,
    params: &CachedParams,
    sm: &mut StateManager,
    tokens: &[i32],
) -> Result<Tensor> {
    let b = sm.n_slots();
    if tokens.len() != b {
        bail!("token vector length {} != slots {}", tokens.len(), b);
    }
    // per-step literals: state + token + pos (params come from the cache)
    let state_lits: Result<Vec<xla::Literal>> =
        sm.leaves.iter().map(|t| t.to_literal()).collect();
    let state_lits = state_lits?;
    let token_lit = Tensor::i32(vec![b], tokens.to_vec()).to_literal()?;
    let pos_lit = sm.pos_tensor().to_literal()?;

    let mut lits: Vec<&xla::Literal> =
        Vec::with_capacity(params.lits.len() + state_lits.len() + 2);
    lits.extend(params.lits.iter());
    lits.extend(state_lits.iter());
    lits.push(&token_lit);
    lits.push(&pos_lit);

    let mut out = exe.run_literals(&lits)?;
    let logits = out.remove(0);
    sm.update_from(out)?;
    Ok(logits)
}

/// A loaded generation stack: any [`Executor`] plus sampling.
pub struct Generator<'a> {
    exec: Box<dyn Executor + 'a>,
    vocab: usize,
    max_len: usize,
}

impl<'a> Generator<'a> {
    pub fn new(exec: Box<dyn Executor + 'a>) -> Result<Self> {
        anyhow::ensure!(
            exec.supports_decode(),
            "model '{}' cannot decode on the {} backend \
             (softmax needs the artifact KV cache; artifact models need a decode artifact)",
            exec.model().name,
            exec.backend_name()
        );
        let vocab = exec.model().config.vocab_size;
        let max_len = exec.model().config.max_len;
        Ok(Generator { exec, vocab, max_len })
    }

    pub fn model(&self) -> &ModelEntry {
        self.exec.model()
    }

    pub fn backend_name(&self) -> &'static str {
        self.exec.backend_name()
    }

    /// Per-slot decode state footprint (bytes) — for the CLI report.
    pub fn state_bytes_per_slot(&self) -> usize {
        self.exec.state_bytes_per_slot()
    }

    /// Generate a completion for one prompt (one slot does the work;
    /// other slots stay free).  Returns (token ids, text).
    pub fn generate(
        &mut self,
        prompt: &str,
        opts: SampleOpts,
        rng: &mut Rng,
    ) -> Result<(Vec<i32>, String)> {
        let tok = ByteTokenizer::new();
        let prompt_ids = tok.encode_with_specials(prompt, false);
        if prompt_ids.len() + opts.max_tokens > self.max_len {
            bail!(
                "prompt ({}) + max_tokens ({}) exceeds model max_len ({})",
                prompt_ids.len(),
                opts.max_tokens,
                self.max_len
            );
        }
        let slot = self
            .exec
            .alloc_slot()
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        // release the slot even when a decode step errors — a long-lived
        // Generator must not leak slots on transient failures
        let result = self.decode_in_slot(slot, &prompt_ids, opts, rng);
        self.exec.release_slot(slot);
        let out_ids = result?;
        let text = tok.decode(&out_ids);
        Ok((out_ids, text))
    }

    /// Prefill + sampling loop over an already-allocated slot.
    fn decode_in_slot(
        &mut self,
        slot: usize,
        prompt_ids: &[i32],
        opts: SampleOpts,
        rng: &mut Rng,
    ) -> Result<Vec<i32>> {
        let b = self.exec.n_slots();
        let mut feed = vec![PAD; b];
        let v = self.vocab;

        // prefill: teacher-force the prompt through the recurrence; only
        // the final prompt position's logits row is ever sampled from
        let mut last_logits: Option<Vec<f32>> = None;
        if self.exec.supports_chunked_prefill() {
            // absorb the prompt in blocks (bit-identical to the token
            // loop), through the same Prefiller the serve engine uses
            let prefiller = Prefiller::new(DEFAULT_PREFILL_CHUNK);
            let mut pos = 0;
            while pos < prompt_ids.len() {
                if let Some(logits) =
                    prefiller.absorb_block(self.exec.as_mut(), slot, prompt_ids, &mut pos, None)?
                {
                    last_logits = Some(logits);
                }
            }
        } else {
            for (i, &t) in prompt_ids.iter().enumerate() {
                feed[slot] = t;
                let logits = self.exec.decode_step(&feed)?;
                if i + 1 == prompt_ids.len() {
                    last_logits = Some(logits.as_f32()?[slot * v..(slot + 1) * v].to_vec());
                }
            }
        }

        let mut out_ids = Vec::with_capacity(opts.max_tokens);
        let mut logits = last_logits.expect("non-empty prompt (BOS at least)");
        for _ in 0..opts.max_tokens {
            let next = rng.sample_logits(&logits, opts.temperature, opts.top_k) as i32;
            if next == EOS {
                break;
            }
            out_ids.push(next);
            feed[slot] = next;
            let l = self.exec.decode_step(&feed)?;
            logits = l.as_f32()?[slot * v..(slot + 1) * v].to_vec();
        }
        Ok(out_ids)
    }
}
