//! Autoregressive generation over the decode artifact.
//!
//! Drives the recurrent `decode_*` entry point token by token for a single
//! prompt (the `holt generate` path).  Batched multi-request decoding
//! lives in [`server`](crate::coordinator::server); this module also hosts
//! the shared decode-step plumbing both use.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::coordinator::state::StateManager;
use crate::params::ParamStore;
use crate::rng::Rng;
use crate::runtime::{Executable, ModelEntry, Runtime, Tensor};
use crate::tokenizer::{ByteTokenizer, EOS, PAD};

/// Sampling parameters.
#[derive(Debug, Clone, Copy)]
pub struct SampleOpts {
    pub temperature: f32,
    pub top_k: usize,
    pub max_tokens: usize,
}

impl Default for SampleOpts {
    fn default() -> Self {
        SampleOpts { temperature: 0.8, top_k: 40, max_tokens: 64 }
    }
}

/// Parameters converted to PJRT literals once, reused every decode step.
///
/// §Perf (EXPERIMENTS.md): parameters are constant during decoding, but the
/// naive path cloned every leaf and re-built its literal per token — for
/// the small model that is ~13 MB of copies per generated token.  Caching
/// the literals removes that entirely; only the (much smaller) recurrent
/// state, token and pos tensors are converted per step.
pub struct CachedParams {
    lits: Vec<xla::Literal>,
    pub n_leaves: usize,
}

impl CachedParams {
    pub fn new(params: &ParamStore) -> Result<Self> {
        let lits: Result<Vec<xla::Literal>> =
            params.leaves.iter().map(|t| t.to_literal()).collect();
        Ok(CachedParams { lits: lits?, n_leaves: params.len() })
    }
}

/// Run one batched decode step: feeds `token[b]` at `pos[b]` for every
/// slot, updates the state manager, returns logits (B, V).
pub fn decode_step(
    exe: &Executable,
    params: &CachedParams,
    sm: &mut StateManager,
    tokens: &[i32],
) -> Result<Tensor> {
    let b = sm.n_slots();
    if tokens.len() != b {
        bail!("token vector length {} != slots {}", tokens.len(), b);
    }
    // per-step literals: state + token + pos (params come from the cache)
    let state_lits: Result<Vec<xla::Literal>> =
        sm.leaves.iter().map(|t| t.to_literal()).collect();
    let state_lits = state_lits?;
    let token_lit = Tensor::i32(vec![b], tokens.to_vec()).to_literal()?;
    let pos_lit = sm.pos_tensor().to_literal()?;

    let mut lits: Vec<&xla::Literal> =
        Vec::with_capacity(params.lits.len() + state_lits.len() + 2);
    lits.extend(params.lits.iter());
    lits.extend(state_lits.iter());
    lits.push(&token_lit);
    lits.push(&pos_lit);

    let mut out = exe.run_literals(&lits)?;
    let logits = out.remove(0);
    sm.update_from(out)?;
    Ok(logits)
}

/// A loaded generation stack: model + decode executable + cached params.
pub struct Generator<'rt> {
    pub model: ModelEntry,
    params: CachedParams,
    exe: Arc<Executable>,
    pub vocab: usize,
    _rt: &'rt Runtime,
}

impl<'rt> Generator<'rt> {
    pub fn new(runtime: &'rt Runtime, model_name: &str, params: ParamStore) -> Result<Self> {
        let model = runtime.manifest.model(model_name)?.clone();
        params.check_spec(&model.param_spec)?;
        let name = model
            .artifacts
            .get("decode")
            .ok_or_else(|| anyhow::anyhow!("model '{}' has no decode artifact", model.name))?;
        let exe = runtime.load(name)?;
        let vocab = model.config.vocab_size;
        let params = CachedParams::new(&params)?;
        Ok(Generator { model, params, exe, vocab, _rt: runtime })
    }

    /// Generate a completion for one prompt (slot 0 does the work; other
    /// slots idle on PAD).  Returns (token ids, text).
    pub fn generate(
        &self,
        prompt: &str,
        opts: SampleOpts,
        rng: &mut Rng,
    ) -> Result<(Vec<i32>, String)> {
        let tok = ByteTokenizer::new();
        let prompt_ids = tok.encode_with_specials(prompt, false);
        let max_len = self.model.config.max_len;
        if prompt_ids.len() + opts.max_tokens > max_len {
            bail!(
                "prompt ({}) + max_tokens ({}) exceeds model max_len ({max_len})",
                prompt_ids.len(),
                opts.max_tokens
            );
        }
        let mut sm = StateManager::new(&self.model.state_spec)?;
        let slot = sm.alloc().unwrap();
        let b = sm.n_slots();
        let mut feed = vec![PAD; b];

        // prefill: teacher-force the prompt through the recurrence
        let mut last_logits: Option<Vec<f32>> = None;
        for &t in &prompt_ids {
            feed[slot] = t;
            let logits = decode_step(&self.exe, &self.params, &mut sm, &feed)?;
            sm.advance(slot);
            let v = self.vocab;
            last_logits =
                Some(logits.as_f32()?[slot * v..(slot + 1) * v].to_vec());
        }

        let mut out_ids = Vec::with_capacity(opts.max_tokens);
        let mut logits = last_logits.expect("non-empty prompt (BOS at least)");
        for _ in 0..opts.max_tokens {
            let next = rng.sample_logits(&logits, opts.temperature, opts.top_k) as i32;
            if next == EOS {
                break;
            }
            out_ids.push(next);
            feed[slot] = next;
            let l = decode_step(&self.exe, &self.params, &mut sm, &feed)?;
            sm.advance(slot);
            let v = self.vocab;
            logits = l.as_f32()?[slot * v..(slot + 1) * v].to_vec();
        }
        let text = tok.decode(&out_ids);
        Ok((out_ids, text))
    }
}
