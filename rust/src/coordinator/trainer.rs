//! Training orchestrator — one run loop, two engines behind a trait.
//!
//! [`TrainBackend`] is the training-side sibling of the serving
//! [`Executor`](crate::model::Executor) trait: the run loop
//! ([`run_training`] — lr schedule, periodic eval, JSONL metrics,
//! checkpointing) is written against it only, and *how* a step happens
//! is an implementation detail:
//!
//! * [`NativeTrainer`] — pure Rust: `model::grad::loss_and_grad` (the
//!   hand-derived backward through the O(n) recurrence) plus a native
//!   AdamW step over [`ParamStore`] moments.  No artifacts, no PJRT, no
//!   Python — `holt train --backend native` works on a clean checkout.
//! * [`ArtifactTrainer`] — the original PJRT path, behavior unchanged:
//!   one fused `train_*` artifact call per step.
//!
//! Checkpoints (params + m + v + step) are identical between the two —
//! same leaf names, shapes and order — so a run can move between
//! backends across restarts.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::data::{self, Batch};
use crate::json::{obj, Json, JsonlWriter};
use crate::metrics::Timer;
use crate::model::{grad, native_model_entry};
use crate::obs;
use crate::params::{self, ParamStore};
use crate::rng::Rng;
use crate::runtime::{Executable, ModelEntry, Runtime, Tensor};

/// One step's scalar outputs.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    /// Global L2 norm of the step's gradient, before the AdamW update.
    /// `NaN` on the artifact backend — the fused train artifact applies
    /// the gradient without exposing it.
    pub grad_norm: f64,
    pub step_time_s: f64,
}

/// A training engine: owns parameters + AdamW moments, advances one
/// fused step at a time, and can evaluate and checkpoint itself.
pub trait TrainBackend {
    /// The model being trained (config, specs, parameter counts).
    fn model(&self) -> &ModelEntry;

    /// `"native"` or `"artifact"` — for logs and bench records.
    fn backend_name(&self) -> &'static str;

    /// Steps taken so far.
    fn step(&self) -> u64;

    /// Execute one AdamW step on a batch; updates state in place.
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats>;

    /// Teacher-forced logits (B, T, V) on a batch — the eval path.
    fn forward(&self, batch: &Batch) -> Result<Tensor>;

    /// Whether [`TrainBackend::forward`] can run (the artifact path
    /// needs a `fwd` artifact; native always can).
    fn supports_eval(&self) -> bool;

    /// Weighted accuracy on an eval batch.
    fn eval_accuracy(&self, batch: &Batch) -> Result<f64> {
        batch.accuracy(&self.forward(batch)?)
    }

    /// Snapshot params + moments + step.
    fn checkpoint(&self) -> Checkpoint;

    /// Batch shape to train with (from the model config).
    fn train_shape(&self) -> (usize, usize) {
        let cfg = &self.model().config;
        (cfg.train_batch, cfg.train_len)
    }
}

// ---------------------------------------------------------------------------
// native
// ---------------------------------------------------------------------------

/// Pure-Rust trainer: hand-derived backward + native AdamW.
///
/// A step is explicit micro-batch gradient accumulation over
/// data-parallel gradient workers ([`grad::loss_and_grad_accum`]): the
/// batch is split per sequence, per-sequence gradients are computed on
/// up to `grad_workers` pool workers and merged by a fixed-shape tree
/// reduction, so the loss curve is bit-identical for every
/// (`accum`, `grad_workers`) setting.
pub struct NativeTrainer {
    pub model: ModelEntry,
    pub params: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: u64,
    /// Micro-batch count per step (gradient accumulation splits; 1 =
    /// whole batch at once).  Purely a memory/scheduling knob — the
    /// gradient is bit-identical for every value.
    pub accum: usize,
    /// Worker cap for data-parallel per-sequence gradients (0 = whole
    /// pool).  Also bit-invariant.
    pub grad_workers: usize,
    /// per-leaf weight decay (GPT-2 convention: matrix leaves only,
    /// embeddings exempt) — precomputed from the param spec
    decay: Vec<f32>,
}

impl NativeTrainer {
    /// Fresh parameters for a native model name (`ho2_tiny`,
    /// `linear_small`, `ho2_tiny_a1_o1`, …).
    pub fn new(model_name: &str, seed: u64) -> Result<Self> {
        Self::from_entry(native_model_entry(model_name)?, seed)
    }

    /// Fresh parameters for an explicit entry (tests use custom tiny
    /// configs).
    pub fn from_entry(model: ModelEntry, seed: u64) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let params = ParamStore::init(&model.param_spec, &mut rng);
        let m = params.zeros_like();
        let v = params.zeros_like();
        Self::with_state(model, params, m, v, 0)
    }

    /// Resume from a checkpoint written by either backend.
    pub fn from_checkpoint(model_name: &str, ckpt: &Checkpoint) -> Result<Self> {
        let model = native_model_entry(model_name)?;
        let params = ckpt.section("params")?.clone();
        params
            .check_spec(&model.param_spec)
            .context("checkpoint/model mismatch")?;
        let m = ckpt.section("m")?.clone();
        let v = ckpt.section("v")?.clone();
        Self::with_state(model, params, m, v, ckpt.step)
    }

    fn with_state(
        model: ModelEntry,
        params: ParamStore,
        m: ParamStore,
        v: ParamStore,
        step: u64,
    ) -> Result<Self> {
        params.check_spec(&model.param_spec)?;
        for (name, t) in params.names.iter().zip(&params.leaves) {
            anyhow::ensure!(t.as_f32().is_ok(), "parameter leaf '{name}' is not f32");
        }
        let cfg = &model.config;
        anyhow::ensure!(
            cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0,
            "bad head split: d_model {} / n_heads {}",
            cfg.d_model,
            cfg.n_heads
        );
        let decay = params::adamw_decay_mask(&model.param_spec);
        Ok(NativeTrainer { model, params, m, v, step, accum: 1, grad_workers: 0, decay })
    }
}

impl TrainBackend for NativeTrainer {
    fn model(&self) -> &ModelEntry {
        &self.model
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn step(&self) -> u64 {
        self.step
    }

    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let timer = Timer::start();
        let (loss, grads) = grad::loss_and_grad_accum(
            &self.model.config,
            &self.params,
            batch,
            self.accum,
            self.grad_workers,
        )?;
        // global gradient L2 — the standard training-health signal
        // (read-only over the already-reduced gradient, so it cannot
        // perturb the bit-reproducible update)
        let mut sq = 0.0f64;
        for leaf in &grads.leaves {
            for &g in leaf.as_f32()? {
                sq += g as f64 * g as f64;
            }
        }
        self.step += 1;
        params::adamw_step(
            &mut self.params,
            &grads,
            &mut self.m,
            &mut self.v,
            self.step,
            lr,
            &self.decay,
        )?;
        Ok(StepStats {
            step: self.step,
            loss: loss as f32,
            grad_norm: sq.sqrt(),
            step_time_s: timer.secs(),
        })
    }

    fn forward(&self, batch: &Batch) -> Result<Tensor> {
        let (b, t) = (batch.batch_size(), batch.seq_len());
        let logits = grad::forward_logits(
            &self.model.config,
            &self.params,
            batch.tokens.as_i32()?,
            b,
            t,
        )?;
        Ok(Tensor::f32(vec![b, t, self.model.config.vocab_size], logits))
    }

    fn supports_eval(&self) -> bool {
        true
    }

    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            step: self.step,
            sections: vec![
                ("params".into(), self.params.clone()),
                ("m".into(), self.m.clone()),
                ("v".into(), self.v.clone()),
            ],
        }
    }
}

// ---------------------------------------------------------------------------
// artifact (PJRT)
// ---------------------------------------------------------------------------

/// PJRT trainer over the fused `train_*` artifact — the pre-trait
/// behavior, unchanged.  Compiled executables are `Arc`-shared with the
/// [`Runtime`]'s cache, so the trainer does not borrow the runtime.
pub struct ArtifactTrainer {
    pub model: ModelEntry,
    pub params: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: u64,
    train_exe: Arc<Executable>,
    fwd_exe: Option<Arc<Executable>>,
}

impl ArtifactTrainer {
    /// Initialize fresh parameters for `model_name` (manifest init spec).
    pub fn new(runtime: &Runtime, model_name: &str, seed: u64) -> Result<Self> {
        let model = runtime.manifest.model(model_name)?.clone();
        let mut rng = Rng::new(seed);
        let params = ParamStore::init(&model.param_spec, &mut rng);
        let m = params.zeros_like();
        let v = params.zeros_like();
        Self::with_state(runtime, model, params, m, v, 0)
    }

    /// Resume from a checkpoint.
    pub fn from_checkpoint(
        runtime: &Runtime,
        model_name: &str,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        let model = runtime.manifest.model(model_name)?.clone();
        let params = ckpt.section("params")?.clone();
        params.check_spec(&model.param_spec).context("checkpoint/model mismatch")?;
        let m = ckpt.section("m")?.clone();
        let v = ckpt.section("v")?.clone();
        Self::with_state(runtime, model, params, m, v, ckpt.step)
    }

    fn with_state(
        runtime: &Runtime,
        model: ModelEntry,
        params: ParamStore,
        m: ParamStore,
        v: ParamStore,
        step: u64,
    ) -> Result<Self> {
        let train_name = model
            .artifacts
            .get("train")
            .ok_or_else(|| anyhow::anyhow!("model '{}' has no train artifact", model.name))?;
        let train_exe = runtime.load(train_name)?;
        let fwd_exe = match model.artifacts.get("fwd") {
            Some(n) => Some(runtime.load(n)?),
            None => None,
        };
        Ok(ArtifactTrainer { model, params, m, v, step, train_exe, fwd_exe })
    }
}

impl TrainBackend for ArtifactTrainer {
    fn model(&self) -> &ModelEntry {
        &self.model
    }

    fn backend_name(&self) -> &'static str {
        "artifact"
    }

    fn step(&self) -> u64 {
        self.step
    }

    /// Execute one fused train step on a batch; updates state in place.
    fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let timer = Timer::start();
        let np = self.params.len();
        let mut inputs: Vec<Tensor> = Vec::with_capacity(3 * np + 5);
        inputs.extend(self.params.leaves.iter().cloned());
        inputs.extend(self.m.leaves.iter().cloned());
        inputs.extend(self.v.leaves.iter().cloned());
        inputs.push(Tensor::scalar_i32(self.step as i32));
        inputs.push(batch.tokens.clone());
        inputs.push(batch.targets.clone());
        inputs.push(batch.weights.clone());
        inputs.push(Tensor::scalar_f32(lr));

        let mut out = self.train_exe.run(&inputs)?;
        // outputs: loss, params x np, m x np, v x np, step
        let loss = out[0].scalar()?;
        let new_step = out[out.len() - 1].scalar()? as u64;
        let rest: Vec<Tensor> = out.drain(1..1 + 3 * np).collect();
        let mut it = rest.into_iter();
        let p: Vec<Tensor> = it.by_ref().take(np).collect();
        let m: Vec<Tensor> = it.by_ref().take(np).collect();
        let v: Vec<Tensor> = it.by_ref().take(np).collect();
        self.params.replace_from(p)?;
        self.m.replace_from(m)?;
        self.v.replace_from(v)?;
        self.step = new_step;
        Ok(StepStats {
            step: self.step,
            loss,
            grad_norm: f64::NAN,
            step_time_s: timer.secs(),
        })
    }

    /// Forward pass on a batch (eval): returns logits (B, T, V).
    fn forward(&self, batch: &Batch) -> Result<Tensor> {
        let fwd = self
            .fwd_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("model has no fwd artifact"))?;
        let mut inputs: Vec<Tensor> = self.params.leaves.clone();
        inputs.push(batch.tokens.clone());
        Ok(fwd.run(&inputs)?.remove(0))
    }

    fn supports_eval(&self) -> bool {
        self.fwd_exe.is_some()
    }

    fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            step: self.step,
            sections: vec![
                ("params".into(), self.params.clone()),
                ("m".into(), self.m.clone()),
                ("v".into(), self.v.clone()),
            ],
        }
    }
}

/// Full training run per a [`TrainConfig`] over any [`TrainBackend`]:
/// the `holt train` command and the train_lm example both call this.
/// Returns the loss history (of this invocation's `cfg.steps` steps).
///
/// A trainer resumed from a checkpoint (`trainer.step() > 0`) continues
/// the run it left: the deterministic data stream is fast-forwarded past
/// the batches already consumed, the lr schedule picks up at the global
/// step, and the JSONL log is appended to instead of truncated — so
/// "train 200 then resume for 200" walks the same trajectory as one
/// 400-step run.
pub fn run_training(
    trainer: &mut dyn TrainBackend,
    cfg: &TrainConfig,
    quiet: bool,
) -> Result<Vec<StepStats>> {
    let (b, t) = trainer.train_shape();
    let start = trainer.step() as usize;
    let mut gen = data::make(&cfg.task, cfg.seed ^ 0x5eed)?;
    let mut eval_gen = data::make(&cfg.task, cfg.seed ^ 0xe7a1)?;
    for _ in 0..start {
        gen.batch(b, t);
    }
    if cfg.eval_every > 0 {
        for _ in 0..start / cfg.eval_every {
            eval_gen.batch(b, t);
        }
    }

    let out_dir = PathBuf::from(&cfg.out_dir);
    let log_path = out_dir.join(format!("train_{}_{}.jsonl", cfg.model, cfg.task));
    let mut log = if start > 0 {
        JsonlWriter::append(&log_path)?
    } else {
        JsonlWriter::create(&log_path)?
    };
    log.write(&obj(vec![
        ("event", "start".into()),
        ("backend", trainer.backend_name().into()),
        ("model", cfg.model.as_str().into()),
        ("task", cfg.task.as_str().into()),
        ("n_params", trainer.model().n_params.into()),
        ("start_step", (start as i64).into()),
        ("steps", cfg.steps.into()),
        ("lr", cfg.lr.into()),
        ("seed", (cfg.seed as i64).into()),
        ("batch", b.into()),
        ("seq_len", t.into()),
        ("accum", cfg.accum.into()),
        ("grad_workers", cfg.grad_workers.into()),
    ]))?;

    // training throughput + per-phase timing all come from the one
    // process-global registry: the counter below is what this loop adds
    // tokens to, and the phase histograms are recorded inside
    // `grad::loss_and_grad_*` itself.  The registry is cumulative for
    // the process (tests run several trainings), so the log reports
    // *deltas* against the values at run start / last log line.
    let reg = obs::global();
    let train_tokens = reg.counter("train_tokens");
    let train_steps = reg.counter("train_steps");
    let tokens0 = train_tokens.get();
    let run_timer = Timer::start();
    const PHASES: [&str; 3] = ["grad_capture_us", "reverse_sweep_us", "tree_reduce_us"];
    let phase_snap =
        |name: &str| reg.histo_snapshot(name).unwrap_or_default();
    let mut phase_last: Vec<obs::HistoSnapshot> = PHASES.iter().map(|n| phase_snap(n)).collect();

    let mut history = Vec::with_capacity(cfg.steps);
    for i in 0..cfg.steps {
        let batch = gen.batch(b, t);
        let lr = cfg.lr_at(start + i) as f32;
        let stats = trainer.train_step(&batch, lr)?;
        train_tokens.add((b * t) as u64);
        train_steps.inc();
        history.push(stats);

        if cfg.log_every > 0 && (start + i + 1) % cfg.log_every == 0 {
            let recent: f64 = history[history.len().saturating_sub(cfg.log_every)..]
                .iter()
                .map(|s| s.loss as f64)
                .sum::<f64>()
                / cfg.log_every.min(history.len()) as f64;
            let tok_per_s = {
                let dt = run_timer.secs();
                if dt <= 0.0 { 0.0 } else { (train_tokens.get() - tokens0) as f64 / dt }
            };
            if !quiet {
                println!(
                    "step {:>5}  loss {:.4}  |g| {:.3}  lr {:.2e}  {:.0} tok/s",
                    stats.step,
                    recent,
                    stats.grad_norm,
                    lr,
                    tok_per_s,
                )
            }
            // mean per-step phase cost over the window since the last
            // log line (histogram deltas — the registry is cumulative)
            let mut fields = vec![
                ("event".to_string(), "step".into()),
                ("step".to_string(), (stats.step as i64).into()),
                ("loss".to_string(), recent.into()),
                ("grad_norm".to_string(), grad_norm_json(stats.grad_norm)),
                ("lr".to_string(), (lr as f64).into()),
                ("tok_per_s".to_string(), tok_per_s.into()),
                ("step_time_s".to_string(), stats.step_time_s.into()),
            ];
            for (pi, name) in PHASES.iter().enumerate() {
                let now = phase_snap(name);
                let (dc, ds) = (now.count - phase_last[pi].count, now.sum - phase_last[pi].sum);
                let ms = if dc == 0 {
                    Json::Null
                } else {
                    (ds as f64 / dc as f64 / 1e3).into()
                };
                fields.push((format!("{}_ms", name.trim_end_matches("_us")), ms));
                phase_last[pi] = now;
            }
            log.write(&Json::Obj(fields))?;
        }

        if cfg.eval_every > 0 && (start + i + 1) % cfg.eval_every == 0 && trainer.supports_eval() {
            let eb = eval_gen.batch(b, t);
            let acc = trainer.eval_accuracy(&eb)?;
            if !quiet {
                println!("step {:>5}  eval accuracy {:.3}", stats.step, acc);
            }
            log.write(&obj(vec![
                ("event", "eval".into()),
                ("step", (stats.step as i64).into()),
                ("accuracy", acc.into()),
            ]))?;
        }

        if cfg.ckpt_every > 0 && (start + i + 1) % cfg.ckpt_every == 0 {
            let path = out_dir.join(format!("{}_{}.ckpt", cfg.model, cfg.task));
            trainer.checkpoint().save(&path)?;
            log.write(&obj(vec![
                ("event", "checkpoint".into()),
                ("step", (stats.step as i64).into()),
                ("path", path.to_string_lossy().to_string().into()),
            ]))?;
        }
    }

    // final checkpoint if any checkpointing was requested
    if cfg.ckpt_every > 0 {
        let path = out_dir.join(format!("{}_{}.ckpt", cfg.model, cfg.task));
        trainer.checkpoint().save(&path)?;
    }
    let tok_per_s = {
        let dt = run_timer.secs();
        if dt <= 0.0 { 0.0 } else { (train_tokens.get() - tokens0) as f64 / dt }
    };
    log.write(&obj(vec![
        ("event", "done".into()),
        ("final_loss", history.last().map(|s| s.loss as f64).unwrap_or(0.0).into()),
        ("tok_per_s", tok_per_s.into()),
    ]))?;
    log.flush()?;
    Ok(history)
}

/// `grad_norm` as JSON: `null` when the backend can't report one (the
/// artifact path returns NaN, which has no JSON representation).
fn grad_norm_json(g: f64) -> Json {
    if g.is_finite() {
        g.into()
    } else {
        Json::Null
    }
}
