//! Training orchestrator.
//!
//! Owns parameters + AdamW moments (as host tensors), feeds batches from a
//! [`DataGen`](crate::data::DataGen) into the fused `train_*` artifact, and
//! handles the run loop: lr schedule, periodic eval through the `fwd_*`
//! artifact, JSONL metrics, and checkpointing.  Python is never involved —
//! one artifact call per step.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::data::{self, Batch};
use crate::json::{obj, JsonlWriter};
use crate::metrics::{Throughput, Timer};
use crate::params::ParamStore;
use crate::rng::Rng;
use crate::runtime::{Executable, ModelEntry, Runtime, Tensor};

/// Everything a live training run needs.
pub struct Trainer<'rt> {
    pub runtime: &'rt Runtime,
    pub model: ModelEntry,
    pub params: ParamStore,
    pub m: ParamStore,
    pub v: ParamStore,
    pub step: u64,
    train_exe: Arc<Executable>,
    fwd_exe: Option<Arc<Executable>>,
}

/// One step's scalar outputs.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    pub step_time_s: f64,
}

impl<'rt> Trainer<'rt> {
    /// Initialize fresh parameters for `model_name` (manifest init spec).
    pub fn new(runtime: &'rt Runtime, model_name: &str, seed: u64) -> Result<Self> {
        let model = runtime.manifest.model(model_name)?.clone();
        let mut rng = Rng::new(seed);
        let params = ParamStore::init(&model.param_spec, &mut rng);
        let m = params.zeros_like();
        let v = params.zeros_like();
        Self::with_state(runtime, model, params, m, v, 0)
    }

    /// Resume from a checkpoint.
    pub fn from_checkpoint(
        runtime: &'rt Runtime,
        model_name: &str,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        let model = runtime.manifest.model(model_name)?.clone();
        let params = ckpt.section("params")?.clone();
        params.check_spec(&model.param_spec).context("checkpoint/model mismatch")?;
        let m = ckpt.section("m")?.clone();
        let v = ckpt.section("v")?.clone();
        Self::with_state(runtime, model, params, m, v, ckpt.step)
    }

    fn with_state(
        runtime: &'rt Runtime,
        model: ModelEntry,
        params: ParamStore,
        m: ParamStore,
        v: ParamStore,
        step: u64,
    ) -> Result<Self> {
        let train_name = model
            .artifacts
            .get("train")
            .ok_or_else(|| anyhow::anyhow!("model '{}' has no train artifact", model.name))?;
        let train_exe = runtime.load(train_name)?;
        let fwd_exe = match model.artifacts.get("fwd") {
            Some(n) => Some(runtime.load(n)?),
            None => None,
        };
        Ok(Trainer { runtime, model, params, m, v, step, train_exe, fwd_exe })
    }

    /// Execute one fused train step on a batch; updates state in place.
    pub fn train_step(&mut self, batch: &Batch, lr: f32) -> Result<StepStats> {
        let timer = Timer::start();
        let np = self.params.len();
        let mut inputs: Vec<Tensor> = Vec::with_capacity(3 * np + 5);
        inputs.extend(self.params.leaves.iter().cloned());
        inputs.extend(self.m.leaves.iter().cloned());
        inputs.extend(self.v.leaves.iter().cloned());
        inputs.push(Tensor::scalar_i32(self.step as i32));
        inputs.push(batch.tokens.clone());
        inputs.push(batch.targets.clone());
        inputs.push(batch.weights.clone());
        inputs.push(Tensor::scalar_f32(lr));

        let mut out = self.train_exe.run(&inputs)?;
        // outputs: loss, params x np, m x np, v x np, step
        let loss = out[0].scalar()?;
        let new_step = out[out.len() - 1].scalar()? as u64;
        let rest: Vec<Tensor> = out.drain(1..1 + 3 * np).collect();
        let mut it = rest.into_iter();
        let p: Vec<Tensor> = it.by_ref().take(np).collect();
        let m: Vec<Tensor> = it.by_ref().take(np).collect();
        let v: Vec<Tensor> = it.by_ref().take(np).collect();
        self.params.replace_from(p)?;
        self.m.replace_from(m)?;
        self.v.replace_from(v)?;
        self.step = new_step;
        Ok(StepStats { step: self.step, loss, step_time_s: timer.secs() })
    }

    /// Forward pass on a batch (eval): returns logits (B, T, V).
    pub fn forward(&self, batch: &Batch) -> Result<Tensor> {
        let fwd = self
            .fwd_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("model has no fwd artifact"))?;
        let mut inputs: Vec<Tensor> = self.params.leaves.clone();
        inputs.push(batch.tokens.clone());
        Ok(fwd.run(&inputs)?.remove(0))
    }

    /// Weighted accuracy on an eval batch.
    pub fn eval_accuracy(&self, batch: &Batch) -> Result<f64> {
        batch.accuracy(&self.forward(batch)?)
    }

    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            step: self.step,
            sections: vec![
                ("params".into(), self.params.clone()),
                ("m".into(), self.m.clone()),
                ("v".into(), self.v.clone()),
            ],
        }
    }

    /// Batch shape the train artifact was lowered with.
    pub fn train_shape(&self) -> (usize, usize) {
        (self.model.config.train_batch, self.model.config.train_len)
    }
}

/// Full training run per a [`TrainConfig`]: the `holt train` command and
/// the train_lm example both call this.  Returns the loss history.
pub fn run_training(
    runtime: &Runtime,
    cfg: &TrainConfig,
    quiet: bool,
) -> Result<Vec<StepStats>> {
    let mut trainer = Trainer::new(runtime, &cfg.model, cfg.seed)?;
    let (b, t) = trainer.train_shape();
    let mut gen = data::make(&cfg.task, cfg.seed ^ 0x5eed)?;
    let mut eval_gen = data::make(&cfg.task, cfg.seed ^ 0xe7a1)?;

    let out_dir = PathBuf::from(&cfg.out_dir);
    let log_path = out_dir.join(format!("train_{}_{}.jsonl", cfg.model, cfg.task));
    let mut log = JsonlWriter::create(&log_path)?;
    log.write(&obj(vec![
        ("event", "start".into()),
        ("model", cfg.model.as_str().into()),
        ("task", cfg.task.as_str().into()),
        ("n_params", trainer.model.n_params.into()),
        ("steps", cfg.steps.into()),
        ("lr", cfg.lr.into()),
        ("seed", (cfg.seed as i64).into()),
        ("batch", b.into()),
        ("seq_len", t.into()),
    ]))?;

    let mut history = Vec::with_capacity(cfg.steps);
    let mut tput = Throughput::new();
    for i in 0..cfg.steps {
        let batch = gen.batch(b, t);
        let lr = cfg.lr_at(i) as f32;
        let stats = trainer.train_step(&batch, lr)?;
        tput.add((b * t) as u64);
        history.push(stats);

        if cfg.log_every > 0 && (i + 1) % cfg.log_every == 0 {
            let recent: f64 = history[history.len().saturating_sub(cfg.log_every)..]
                .iter()
                .map(|s| s.loss as f64)
                .sum::<f64>()
                / cfg.log_every.min(history.len()) as f64;
            if !quiet {
                println!(
                    "step {:>5}  loss {:.4}  lr {:.2e}  {:.0} tok/s",
                    stats.step,
                    recent,
                    lr,
                    tput.per_sec()
                );
            }
            log.write(&obj(vec![
                ("event", "step".into()),
                ("step", (stats.step as i64).into()),
                ("loss", (recent).into()),
                ("lr", (lr as f64).into()),
                ("tok_per_s", tput.per_sec().into()),
                ("step_time_s", stats.step_time_s.into()),
            ]))?;
        }

        if cfg.eval_every > 0 && (i + 1) % cfg.eval_every == 0 {
            let eb = eval_gen.batch(b, t);
            let acc = trainer.eval_accuracy(&eb)?;
            if !quiet {
                println!("step {:>5}  eval accuracy {:.3}", stats.step, acc);
            }
            log.write(&obj(vec![
                ("event", "eval".into()),
                ("step", (stats.step as i64).into()),
                ("accuracy", acc.into()),
            ]))?;
        }

        if cfg.ckpt_every > 0 && (i + 1) % cfg.ckpt_every == 0 {
            let path = out_dir.join(format!("{}_{}.ckpt", cfg.model, cfg.task));
            trainer.checkpoint().save(&path)?;
            log.write(&obj(vec![
                ("event", "checkpoint".into()),
                ("step", (stats.step as i64).into()),
                ("path", path.to_string_lossy().to_string().into()),
            ]))?;
        }
    }

    // final checkpoint if any checkpointing was requested
    if cfg.ckpt_every > 0 {
        let path = out_dir.join(format!("{}_{}.ckpt", cfg.model, cfg.task));
        trainer.checkpoint().save(&path)?;
    }
    log.write(&obj(vec![
        ("event", "done".into()),
        ("final_loss", history.last().map(|s| s.loss as f64).unwrap_or(0.0).into()),
        ("tok_per_s", tput.per_sec().into()),
    ]))?;
    log.flush()?;
    Ok(history)
}
