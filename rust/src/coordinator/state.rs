//! Recurrent decode-state manager — the "KV cache" of a linear-attention
//! server.
//!
//! The decode artifact is lowered with a fixed slot count B
//! (`decode_batch`); each slot holds one sequence's recurrent state: for
//! ho2/linear that is, per layer, S (H, f, dh) and z (H, f) — **constant
//! in context length**, the paper's headline serving property — and for
//! the softmax baseline the (H, max_len, dh) KV cache, linear in context.
//!
//! The manager owns the batched state tensors (leading axis = slot),
//! allocates/frees slots as requests arrive/finish, zeroes a slot's slice
//! on reuse, and tracks per-slot positions (fed to the artifact as the
//! per-sequence `pos` vector — that is what makes continuous batching
//! possible).

use anyhow::{bail, Result};

use crate::runtime::{LeafSpec, Tensor};

/// Slot state manager over the decode artifact's state leaves.
pub struct StateManager {
    /// batched state tensors, in state_spec order (leading dim = slots)
    pub leaves: Vec<Tensor>,
    spec: Vec<LeafSpec>,
    /// per-slot next position (also = tokens consumed so far)
    pub pos: Vec<i32>,
    free: Vec<usize>,
    n_slots: usize,
}

impl StateManager {
    pub fn new(state_spec: &[LeafSpec]) -> Result<StateManager> {
        if state_spec.is_empty() {
            bail!("empty state spec");
        }
        let n_slots = state_spec[0].shape[0];
        for s in state_spec {
            if s.shape.first() != Some(&n_slots) {
                bail!("state leaf '{}' does not lead with slot dim", s.name);
            }
        }
        let leaves = state_spec
            .iter()
            .map(|s| Tensor::zeros(&s.shape, crate::runtime::DType::F32))
            .collect();
        Ok(StateManager {
            leaves,
            spec: state_spec.to_vec(),
            pos: vec![0; n_slots],
            free: (0..n_slots).rev().collect(),
            n_slots,
        })
    }

    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    /// Claim a slot: zero its state slice and reset its position.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        self.reset_slot(slot);
        Some(slot)
    }

    /// Release a slot back to the pool.
    pub fn release(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double free of slot {slot}");
        self.free.push(slot);
    }

    /// Zero one slot's slice in every state leaf and reset its position.
    fn reset_slot(&mut self, slot: usize) {
        for t in &mut self.leaves {
            let stride: usize = t.shape[1..].iter().product();
            let data = t.as_f32_mut().expect("state is f32");
            data[slot * stride..(slot + 1) * stride].fill(0.0);
        }
        self.pos[slot] = 0;
    }

    /// Swap in the artifact's updated state leaves.
    pub fn update_from(&mut self, new_leaves: Vec<Tensor>) -> Result<()> {
        if new_leaves.len() != self.leaves.len() {
            bail!(
                "state leaf count mismatch: {} vs {}",
                new_leaves.len(),
                self.leaves.len()
            );
        }
        for (old, new) in self.leaves.iter().zip(&new_leaves) {
            if old.shape != new.shape {
                bail!("state leaf shape changed: {:?} -> {:?}", old.shape, new.shape);
            }
        }
        self.leaves = new_leaves;
        Ok(())
    }

    /// Advance a slot's position after it consumed a token.
    pub fn advance(&mut self, slot: usize) {
        self.pos[slot] += 1;
    }

    /// The per-slot `pos` vector in artifact shape (B,) i32.
    pub fn pos_tensor(&self) -> Tensor {
        Tensor::i32(vec![self.n_slots], self.pos.clone())
    }

    /// Total f32 elements of state per slot (the paper's O(1) vs O(n)
    /// comparison reads this).
    pub fn state_elements_per_slot(&self) -> usize {
        self.spec
            .iter()
            .map(|s| s.shape[1..].iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Init;

    fn spec(slots: usize) -> Vec<LeafSpec> {
        vec![
            LeafSpec { name: "layer0.S".into(), shape: vec![slots, 2, 5, 3], init: Init::Zeros },
            LeafSpec { name: "layer0.z".into(), shape: vec![slots, 2, 5], init: Init::Zeros },
        ]
    }

    #[test]
    fn alloc_release_cycle() {
        let mut sm = StateManager::new(&spec(4)).unwrap();
        assert_eq!(sm.n_slots(), 4);
        let a = sm.alloc().unwrap();
        let b = sm.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(sm.free_slots(), 2);
        sm.release(a);
        assert_eq!(sm.free_slots(), 3);
        // exhaust
        let mut got = vec![b];
        while let Some(s) = sm.alloc() {
            got.push(s);
        }
        assert_eq!(got.len(), 4);
        assert_eq!(sm.free_slots(), 0);
    }

    #[test]
    fn reuse_zeroes_state_and_pos() {
        let mut sm = StateManager::new(&spec(2)).unwrap();
        let s = sm.alloc().unwrap();
        // dirty the slot
        let stride: usize = sm.leaves[0].shape[1..].iter().product();
        sm.leaves[0].as_f32_mut().unwrap()[s * stride] = 7.0;
        sm.pos[s] = 9;
        sm.release(s);
        let s2 = sm.alloc().unwrap();
        assert_eq!(s2, s);
        assert_eq!(sm.leaves[0].as_f32().unwrap()[s * stride], 0.0);
        assert_eq!(sm.pos[s], 0);
    }

    #[test]
    fn per_slot_isolation_on_reset() {
        let mut sm = StateManager::new(&spec(3)).unwrap();
        let a = sm.alloc().unwrap();
        let b = sm.alloc().unwrap();
        let stride: usize = sm.leaves[0].shape[1..].iter().product();
        sm.leaves[0].as_f32_mut().unwrap()[b * stride + 1] = 3.5;
        sm.release(a);
        sm.alloc().unwrap(); // re-zero a
        assert_eq!(sm.leaves[0].as_f32().unwrap()[b * stride + 1], 3.5);
    }

    #[test]
    fn state_size_accounting() {
        let sm = StateManager::new(&spec(2)).unwrap();
        assert_eq!(sm.state_elements_per_slot(), 2 * 5 * 3 + 2 * 5);
    }
}
