//! L3 runtime: PJRT client wrapper, artifact manifest, host tensors.
//!
//! `Runtime` loads HLO-text artifacts produced by `python/compile/aot.py`
//! (the only python in the system, build-time exclusively) and executes
//! them on the PJRT CPU client from the `xla` crate.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Executable, Runtime};
pub use manifest::{Artifact, Init, IoSpec, LeafSpec, Manifest, ModelEntry};
pub use tensor::{DType, Data, Tensor};
