//! Host-side tensors and conversion to/from PJRT literals.
//!
//! The coordinator keeps all model/optimizer/decode state as `Tensor`s
//! (dense row-major, f32 or i32) and converts at the executable boundary.

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unsupported dtype '{s}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }
}

/// Dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: Data::I32(data) }
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Self {
        let n: usize = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape.to_vec(), vec![0.0; n]),
            DType::I32 => Tensor::i32(shape.to_vec(), vec![0; n]),
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor::f32(shape.to_vec(), vec![1.0; n])
    }

    pub fn scalar_f32(x: f32) -> Self {
        Tensor::f32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        Tensor::i32(vec![], vec![x])
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        match (&self.data, self.len()) {
            (Data::F32(v), 1) => Ok(v[0]),
            (Data::I32(v), 1) => Ok(v[0] as f32),
            _ => bail!("not a scalar (shape {:?})", self.shape),
        }
    }

    /// Convert to a PJRT literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            Data::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Convert back from a PJRT literal (copies).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            t => Err(anyhow!("unsupported literal element type {t:?}")),
        }
    }

    /// Max |a - b| over two f32 tensors of identical shape.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max))
    }

    /// Mean squared error against another tensor.
    pub fn mse(&self, other: &Tensor) -> Result<f64> {
        let (a, b) = (self.as_f32()?, other.as_f32()?);
        if a.len() != b.len() {
            bail!("length mismatch");
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.len() as f64)
    }

    /// Relative L2 error ||a-b|| / ||b||.
    pub fn rel_l2(&self, reference: &Tensor) -> Result<f64> {
        let (a, b) = (self.as_f32()?, reference.as_f32()?);
        if a.len() != b.len() {
            bail!("length mismatch");
        }
        let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum();
        Ok((num / den.max(1e-30)).sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn metrics() {
        let a = Tensor::f32(vec![3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(vec![3], vec![1.0, 2.0, 4.0]);
        assert!((a.max_abs_diff(&b).unwrap() - 1.0).abs() < 1e-6);
        assert!((a.mse(&b).unwrap() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_i32(7);
        assert_eq!(t.scalar().unwrap(), 7.0);
    }
}
