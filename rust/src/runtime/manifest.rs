//! Typed view of `artifacts/manifest.json` — the contract with the python
//! compile path (python/compile/aot.py writes it; nothing else does).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;
use crate::runtime::tensor::DType;

/// One input or output port of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// One AOT-lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Json,
}

/// Parameter-leaf initialization spec (rust owns initialization).
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Init {
    Zeros,
    Ones,
    Normal { std: f32 },
}

/// Model hyper-parameters as lowered (mirror of python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub preset: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub attn: String,
    pub order: usize,
    pub alpha: f64,
    pub impl_: String,
    pub train_batch: usize,
    pub train_len: usize,
    pub decode_batch: usize,
    /// Default wire dtype for this model's cached session snapshots
    /// (`_s{dtype}` name suffix; `--state-dtype` overrides at serve
    /// time).  Never affects the live f64 compute state.
    pub state_dtype: crate::state::StateDtype,
}

/// One registered model: config + leaf specs + artifact names.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub config: ModelConfig,
    pub n_params: usize,
    pub param_spec: Vec<LeafSpec>,
    pub state_spec: Vec<LeafSpec>,
    /// kind ("fwd"/"train"/"decode") -> artifact name
    pub artifacts: HashMap<String, String>,
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, Artifact>,
    pub models: HashMap<String, ModelEntry>,
}

fn io_spec(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v.req("name")?.as_str().unwrap_or_default().to_string(),
        shape: v
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad shape"))?,
        dtype: DType::parse(v.req("dtype")?.as_str().unwrap_or("f32"))?,
    })
}

fn leaf_spec(v: &Json) -> Result<LeafSpec> {
    let init = match v.get("init").and_then(|j| j.as_str()) {
        Some("ones") => Init::Ones,
        Some("normal") => Init::Normal {
            std: v
                .get("std")
                .and_then(|j| j.as_f64())
                .unwrap_or(0.02) as f32,
        },
        // decode-state specs carry no init field: they start zeroed
        _ => Init::Zeros,
    };
    Ok(LeafSpec {
        name: v.req("name")?.as_str().unwrap_or_default().to_string(),
        shape: v
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("bad shape"))?,
        init,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = HashMap::new();
        for (name, a) in root
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow!("artifacts not an object"))?
        {
            let inputs: Result<Vec<_>> =
                a.req("inputs")?.as_arr().unwrap_or(&[]).iter().map(io_spec).collect();
            let outputs: Result<Vec<_>> =
                a.req("outputs")?.as_arr().unwrap_or(&[]).iter().map(io_spec).collect();
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    file: dir.join(a.req("file")?.as_str().unwrap_or_default()),
                    kind: a.req("kind")?.as_str().unwrap_or_default().to_string(),
                    inputs: inputs?,
                    outputs: outputs?,
                    meta: a.get("meta").cloned().unwrap_or(Json::Obj(vec![])),
                },
            );
        }

        let mut models = HashMap::new();
        for (name, m) in root
            .req("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models not an object"))?
        {
            let c = m.req("config")?;
            let config = ModelConfig {
                preset: c.req("preset")?.as_str().unwrap_or_default().to_string(),
                vocab_size: c.req("vocab_size")?.as_i64().unwrap_or(0) as usize,
                d_model: c.req("d_model")?.as_i64().unwrap_or(0) as usize,
                n_heads: c.req("n_heads")?.as_i64().unwrap_or(0) as usize,
                n_layers: c.req("n_layers")?.as_i64().unwrap_or(0) as usize,
                d_ff: c.req("d_ff")?.as_i64().unwrap_or(0) as usize,
                max_len: c.req("max_len")?.as_i64().unwrap_or(0) as usize,
                attn: c.req("attn")?.as_str().unwrap_or_default().to_string(),
                order: c.req("order")?.as_i64().unwrap_or(2) as usize,
                alpha: c.req("alpha")?.as_f64().unwrap_or(3.0),
                impl_: c.req("impl")?.as_str().unwrap_or("jnp").to_string(),
                train_batch: c.req("train_batch")?.as_i64().unwrap_or(0) as usize,
                train_len: c.req("train_len")?.as_i64().unwrap_or(0) as usize,
                decode_batch: c.req("decode_batch")?.as_i64().unwrap_or(0) as usize,
                // older manifests predate the compact-state subsystem:
                // absent means the lossless default
                state_dtype: match c.get("state_dtype").and_then(|j| j.as_str()) {
                    Some(s) => crate::state::StateDtype::parse(s)?,
                    None => crate::state::StateDtype::F64,
                },
            };
            let param_spec: Result<Vec<_>> = m
                .req("param_spec")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(leaf_spec)
                .collect();
            let state_spec: Result<Vec<_>> = m
                .req("state_spec")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(leaf_spec)
                .collect();
            let mut arts = HashMap::new();
            for (k, v) in m.req("artifacts")?.as_obj().unwrap_or(&[]) {
                arts.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    config,
                    n_params: m.req("n_params")?.as_i64().unwrap_or(0) as usize,
                    param_spec: param_spec?,
                    state_spec: state_spec?,
                    artifacts: arts,
                },
            );
        }

        Ok(Manifest { dir: dir.to_path_buf(), artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})",
                                   self.artifact_names()))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            let mut names: Vec<_> = self.models.keys().cloned().collect();
            names.sort();
            anyhow!("model '{name}' not in manifest (have: {names:?})")
        })
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }
}

impl ModelEntry {
    /// Total number of parameter elements (sanity-checked vs python count).
    pub fn param_elements(&self) -> usize {
        self.param_spec.iter().map(|l| l.shape.iter().product::<usize>()).sum()
    }
}
