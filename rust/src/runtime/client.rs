//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! The interchange format is HLO *text* (not serialized HloModuleProto):
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids (see DESIGN.md and
//! /opt/xla-example/README.md).  One `Runtime` owns the PJRT client and a
//! lazy compile cache keyed by artifact name — executables compile on first
//! use and are shared thereafter (`Arc`, thread-safe).

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{Artifact, Manifest};
use crate::runtime::tensor::Tensor;

/// A compiled artifact, ready to execute.
pub struct Executable {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; validates arity + shapes + dtypes against
    /// the manifest, returns one host tensor per declared output.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = &self.artifact.inputs;
        if inputs.len() != spec.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.artifact.name,
                spec.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(spec) {
            if t.shape != s.shape || t.dtype() != s.dtype {
                bail!(
                    "{}: input '{}' expects {:?}/{}, got {:?}/{}",
                    self.artifact.name,
                    s.name,
                    s.shape,
                    s.dtype.name(),
                    t.shape,
                    t.dtype().name()
                );
            }
        }
        let lits: Result<Vec<xla::Literal>> = inputs.iter().map(|t| t.to_literal()).collect();
        let lits = lits?;
        self.run_literals(&lits.iter().collect::<Vec<_>>())
    }

    /// Execute with pre-built literals.  The serving/training hot paths
    /// convert their *constant* inputs (parameters) to literals once and
    /// reuse them across calls — see §Perf in EXPERIMENTS.md; this skips a
    /// full host copy of every parameter per step.
    pub fn run_literals(&self, lits: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        if lits.len() != self.artifact.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {} literals",
                self.artifact.name,
                self.artifact.inputs.len(),
                lits.len()
            );
        }
        let result = self.exe.execute::<&xla::Literal>(lits)?;
        // jax lowering uses return_tuple=True: one tuple literal out.
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.artifact.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                self.artifact.name,
                self.artifact.outputs.len(),
                parts.len()
            );
        }
        parts.iter().map(Tensor::from_literal).collect()
    }
}

/// PJRT client + artifact registry + compile cache.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    verbose: bool,
}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
            verbose: std::env::var("HOLT_VERBOSE").is_ok(),
        })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let artifact = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            artifact.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", artifact.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        if self.verbose {
            eprintln!("[runtime] compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        }
        let e = Arc::new(Executable { artifact, exe });
        self.cache.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
