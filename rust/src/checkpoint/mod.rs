//! Binary checkpoint format for parameter/optimizer state.
//!
//! Self-describing little-endian container (magic "HOLTCKPT", version,
//! step, named f32 leaves).  Written atomically (tmp file + rename) so a
//! crash mid-save never corrupts the previous checkpoint.
//!
//! Two container versions, both readable:
//!
//! **v1** (legacy, streaming): metadata and payloads interleaved —
//! reading any leaf means parsing everything before it.
//! ```text
//! magic[8] version:u32 step:u64 n_sections:u32
//! per section: name_len:u32 name[..] n_leaves:u32
//!   per leaf: name_len:u32 name[..] rank:u32 dims[rank]:u64 data[f32...]
//! ```
//!
//! **v2** (current, mmap-indexable): all metadata up front as an offset
//! index, every leaf payload at a 64-byte-aligned absolute file offset.
//! A reader can `mmap` the file and hand out `&[f32]` views of the
//! payloads without copying or parsing past the header —
//! [`MmapCheckpoint`].  ([`Checkpoint::load`] materializes v2 through
//! that same mapping: one `memcpy` per leaf instead of buffered-read
//! syscall churn.)
//! ```text
//! magic[8] version:u32 step:u64 n_sections:u32
//! per section: name_len:u32 name[..] n_leaves:u32
//!   per leaf: name_len:u32 name[..] rank:u32 dims[rank]:u64
//!             offset:u64 nbytes:u64
//! zero padding to the first 64-byte boundary, then payloads
//! (each leaf's f32 data at its recorded offset, offsets ascending,
//!  64-byte-aligned)
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::params::ParamStore;
use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"HOLTCKPT";
/// Container version new checkpoints are written as.
pub const VERSION: u32 = 2;
/// Leaf-payload alignment in v2 files: a cache line, and a multiple of
/// every scalar size we store — an mmap'd payload is directly usable as
/// an aligned `&[f32]`.
pub const PAYLOAD_ALIGN: usize = 64;

/// A full training checkpoint: params + AdamW moments + step counter.
pub struct Checkpoint {
    pub step: u64,
    pub sections: Vec<(String, ParamStore)>,
}

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("unreasonable string length {n}");
    }
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

fn align_up(x: usize) -> usize {
    x.div_ceil(PAYLOAD_ALIGN) * PAYLOAD_ALIGN
}

fn leaf_bytes(t: &Tensor) -> Result<&[u8]> {
    let data = t.as_f32()?;
    // bulk I/O — leaves can be tens of MB
    Ok(unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) })
}

impl Checkpoint {
    /// Save as the current container version (v2).  Atomic: written to a
    /// tmp file and renamed over `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_as(path, VERSION)
    }

    /// Save as an explicit container version — v1 exists for
    /// compatibility coverage (old readers, and tests that pin the
    /// v1→v2 upgrade path).
    pub fn save_as(&self, path: &Path, version: u32) -> Result<()> {
        ensure!(
            version == 1 || version == 2,
            "cannot write checkpoint container version {version}"
        );
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
            if version == 1 {
                self.write_v1(&mut w)?;
            } else {
                self.write_v2(&mut w)?;
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    fn write_v1(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        write_u32(w, 1)?;
        write_u64(w, self.step)?;
        write_u32(w, self.sections.len() as u32)?;
        for (name, store) in &self.sections {
            write_str(w, name)?;
            write_u32(w, store.len() as u32)?;
            for (leaf_name, t) in store.names.iter().zip(&store.leaves) {
                write_str(w, leaf_name)?;
                write_u32(w, t.shape.len() as u32)?;
                for &d in &t.shape {
                    write_u64(w, d as u64)?;
                }
                w.write_all(leaf_bytes(t)?)?;
            }
        }
        Ok(())
    }

    fn write_v2(&self, w: &mut impl Write) -> Result<()> {
        // pass 1: the header size is deterministic (offset/nbytes are
        // fixed-width), so leaf offsets can be assigned before anything
        // is written
        let mut header = 8 + 4 + 8 + 4;
        for (name, store) in &self.sections {
            header += 4 + name.len() + 4;
            for (leaf_name, t) in store.names.iter().zip(&store.leaves) {
                header += 4 + leaf_name.len() + 4 + 8 * t.shape.len() + 8 + 8;
            }
        }
        let mut cursor = align_up(header);
        let mut offsets = Vec::new();
        for (_, store) in &self.sections {
            for t in &store.leaves {
                let nbytes = t.shape.iter().product::<usize>() * 4;
                offsets.push((cursor, nbytes));
                cursor = align_up(cursor + nbytes);
            }
        }
        // pass 2: header with the index, padding, then the payloads
        w.write_all(MAGIC)?;
        write_u32(w, 2)?;
        write_u64(w, self.step)?;
        write_u32(w, self.sections.len() as u32)?;
        let mut it = offsets.iter();
        for (name, store) in &self.sections {
            write_str(w, name)?;
            write_u32(w, store.len() as u32)?;
            for (leaf_name, t) in store.names.iter().zip(&store.leaves) {
                let &(offset, nbytes) = it.next().expect("one offset per leaf");
                write_str(w, leaf_name)?;
                write_u32(w, t.shape.len() as u32)?;
                for &d in &t.shape {
                    write_u64(w, d as u64)?;
                }
                write_u64(w, offset as u64)?;
                write_u64(w, nbytes as u64)?;
            }
        }
        let mut pos = header;
        let mut it = offsets.iter();
        for (_, store) in &self.sections {
            for t in &store.leaves {
                let &(offset, nbytes) = it.next().expect("one offset per leaf");
                w.write_all(&vec![0u8; offset - pos])?;
                w.write_all(leaf_bytes(t)?)?;
                pos = offset + nbytes;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        match container_version(path)? {
            1 => Self::load_v1(path),
            2 => Ok(MmapCheckpoint::open(path)?.to_checkpoint()),
            v => bail!("unsupported checkpoint version {v}"),
        }
    }

    fn load_v1(path: &Path) -> Result<Checkpoint> {
        let mut r = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a HOLT checkpoint");
        }
        let version = read_u32(&mut r)?;
        ensure!(version == 1, "load_v1 called on a v{version} file");
        let step = read_u64(&mut r)?;
        let n_sections = read_u32(&mut r)? as usize;
        let mut sections = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let name = read_str(&mut r)?;
            let n_leaves = read_u32(&mut r)? as usize;
            let mut names = Vec::with_capacity(n_leaves);
            let mut leaves = Vec::with_capacity(n_leaves);
            for _ in 0..n_leaves {
                let leaf_name = read_str(&mut r)?;
                let rank = read_u32(&mut r)? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(read_u64(&mut r)? as usize);
                }
                let n: usize = shape.iter().product();
                let mut data = vec![0f32; n];
                let bytes: &mut [u8] = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
                };
                r.read_exact(bytes)?;
                names.push(leaf_name);
                leaves.push(Tensor::f32(shape, data));
            }
            sections.push((name, ParamStore { names, leaves }));
        }
        Ok(Checkpoint { step, sections })
    }

    pub fn section(&self, name: &str) -> Result<&ParamStore> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s)
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no section '{name}'"))
    }
}

/// Container version of a checkpoint file (reads only the 12-byte
/// preamble) — `ckpt-info` reports it, [`Checkpoint::load`] dispatches
/// on it.
pub fn container_version(path: &Path) -> Result<u32> {
    let mut r = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut pre = [0u8; 12];
    r.read_exact(&mut pre)
        .with_context(|| format!("{path:?} is too short to be a checkpoint"))?;
    if &pre[..8] != MAGIC {
        bail!("{path:?} is not a HOLT checkpoint");
    }
    Ok(u32::from_le_bytes(pre[8..12].try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// zero-copy v2 reader
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// File bytes behind an [`MmapCheckpoint`]: a real mapping on unix, a
/// heap copy elsewhere (same API, one extra copy).  The heap fallback
/// allocates `u64`s so payload views keep ≥ 8-byte alignment.
enum Backing {
    #[cfg(unix)]
    Mapped {
        ptr: *const u8,
        len: usize,
    },
    // on unix builds only the mapped variant is ever constructed
    #[cfg_attr(unix, allow(dead_code))]
    Heap {
        buf: Vec<u64>,
        len: usize,
    },
}

impl Backing {
    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr() as *const u8, *len)
            },
        }
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self {
            unsafe {
                sys::munmap(*ptr as *mut std::os::raw::c_void, *len);
            }
        }
    }
}

struct LeafIndex {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    nbytes: usize,
}

/// Zero-copy reader for v2 checkpoints: the file is mapped read-only
/// and every leaf is an aligned `&[f32]` view straight into the mapping
/// — no payload is parsed, copied or even touched until asked for.
pub struct MmapCheckpoint {
    backing: Backing,
    step: u64,
    index: Vec<(String, Vec<LeafIndex>)>,
}

impl MmapCheckpoint {
    pub fn open(path: &Path) -> Result<MmapCheckpoint> {
        let backing = Self::map(path)?;
        let bytes = backing.bytes();
        let mut r: &[u8] = bytes;
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a HOLT checkpoint");
        }
        let version = read_u32(&mut r)?;
        ensure!(
            version == 2,
            "zero-copy loads need a v2 checkpoint, {path:?} is v{version} \
             (Checkpoint::load reads it; re-saving writes v2)"
        );
        let step = read_u64(&mut r)?;
        let n_sections = read_u32(&mut r)? as usize;
        let mut index = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let name = read_str(&mut r)?;
            let n_leaves = read_u32(&mut r)? as usize;
            let mut leaves = Vec::with_capacity(n_leaves);
            for _ in 0..n_leaves {
                let leaf_name = read_str(&mut r)?;
                let rank = read_u32(&mut r)? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(read_u64(&mut r)? as usize);
                }
                let offset = read_u64(&mut r)? as usize;
                let nbytes = read_u64(&mut r)? as usize;
                let n: usize = shape.iter().product();
                ensure!(
                    nbytes == n * 4,
                    "leaf '{leaf_name}': index says {nbytes} bytes, shape {shape:?} needs {}",
                    n * 4
                );
                ensure!(
                    offset % PAYLOAD_ALIGN == 0,
                    "leaf '{leaf_name}': payload offset {offset} is not {PAYLOAD_ALIGN}-byte aligned"
                );
                ensure!(
                    offset.checked_add(nbytes).is_some_and(|end| end <= bytes.len()),
                    "leaf '{leaf_name}': payload [{offset}, {offset}+{nbytes}) \
                     exceeds file size {} (truncated checkpoint?)",
                    bytes.len()
                );
                leaves.push(LeafIndex { name: leaf_name, shape, offset, nbytes });
            }
            index.push((name, leaves));
        }
        Ok(MmapCheckpoint { backing, step, index })
    }

    #[cfg(unix)]
    fn map(path: &Path) -> Result<Backing> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
        let len = f.metadata()?.len() as usize;
        ensure!(len >= 12, "{path:?} is too short to be a checkpoint");
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            bail!("mmap({path:?}, {len} bytes) failed: {}", std::io::Error::last_os_error());
        }
        Ok(Backing::Mapped { ptr: ptr as *const u8, len })
    }

    #[cfg(not(unix))]
    fn map(path: &Path) -> Result<Backing> {
        let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        ensure!(raw.len() >= 12, "{path:?} is too short to be a checkpoint");
        let mut buf = vec![0u64; raw.len().div_ceil(8)];
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), buf.as_mut_ptr() as *mut u8, raw.len());
        }
        Ok(Backing::Heap { buf, len: raw.len() })
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.index.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn leaf_names(&self, section: &str) -> Vec<&str> {
        self.index
            .iter()
            .find(|(n, _)| n == section)
            .map(|(_, ls)| ls.iter().map(|l| l.name.as_str()).collect())
            .unwrap_or_default()
    }

    /// Borrow one leaf without copying: `(shape, data)` where `data`
    /// points into the mapping (64-byte-aligned by the v2 layout).
    pub fn leaf(&self, section: &str, leaf: &str) -> Result<(&[usize], &[f32])> {
        let (_, leaves) = self
            .index
            .iter()
            .find(|(n, _)| n == section)
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no section '{section}'"))?;
        let l = leaves
            .iter()
            .find(|l| l.name == leaf)
            .ok_or_else(|| anyhow::anyhow!("section '{section}' has no leaf '{leaf}'"))?;
        let bytes = &self.backing.bytes()[l.offset..l.offset + l.nbytes];
        debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
        let data =
            unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, l.nbytes / 4) };
        Ok((&l.shape, data))
    }

    /// Materialize into an owned [`Checkpoint`] — one `memcpy` per leaf
    /// straight out of the mapping (the [`Checkpoint::load`] v2 path).
    pub fn to_checkpoint(&self) -> Checkpoint {
        let sections = self
            .index
            .iter()
            .map(|(name, leaves)| {
                let names = leaves.iter().map(|l| l.name.clone()).collect();
                let tensors = leaves
                    .iter()
                    .map(|l| {
                        let (shape, data) = self
                            .leaf(name, &l.name)
                            .expect("index entries resolve against their own index");
                        Tensor::f32(shape.to_vec(), data.to_vec())
                    })
                    .collect();
                (name.clone(), ParamStore { names, leaves: tensors })
            })
            .collect();
        Checkpoint { step: self.step, sections }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::{Init, LeafSpec};

    fn store(seed: u64) -> ParamStore {
        let spec = vec![
            LeafSpec { name: "a".into(), shape: vec![3, 5], init: Init::Normal { std: 1.0 } },
            LeafSpec { name: "b".into(), shape: vec![7], init: Init::Ones },
        ];
        ParamStore::init(&spec, &mut Rng::new(seed))
    }

    fn checkpoint() -> Checkpoint {
        Checkpoint {
            step: 123,
            sections: vec![
                ("params".into(), store(1)),
                ("m".into(), store(2)),
                ("v".into(), store(3)),
            ],
        }
    }

    fn assert_same(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.sections.len(), b.sections.len());
        for (orig, loaded) in a.sections.iter().zip(&b.sections) {
            assert_eq!(orig.0, loaded.0);
            assert_eq!(orig.1.names, loaded.1.names);
            assert_eq!(orig.1.leaves, loaded.1.leaves);
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("holt_ckpt_test");
        let path = dir.join("test.ckpt");
        let ck = checkpoint();
        ck.save(&path).unwrap();
        assert_eq!(container_version(&path).unwrap(), VERSION);
        let back = Checkpoint::load(&path).unwrap();
        assert_same(&ck, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_still_load() {
        // the backward-compat pin: a checkpoint saved by the pre-v2 code
        // (bit-identical writer, kept as save_as(.., 1)) must keep
        // loading to the same tensors forever
        let dir = std::env::temp_dir().join("holt_ckpt_test_v1");
        let path = dir.join("old.ckpt");
        let ck = checkpoint();
        ck.save_as(&path, 1).unwrap();
        assert_eq!(container_version(&path).unwrap(), 1);
        let back = Checkpoint::load(&path).unwrap();
        assert_same(&ck, &back);
        // and re-saving upgrades the container without touching the data
        let upgraded = dir.join("new.ckpt");
        back.save(&upgraded).unwrap();
        assert_eq!(container_version(&upgraded).unwrap(), 2);
        assert_same(&ck, &Checkpoint::load(&upgraded).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_views_match_without_copying() {
        let dir = std::env::temp_dir().join("holt_ckpt_test_mmap");
        let path = dir.join("test.ckpt");
        let ck = checkpoint();
        ck.save(&path).unwrap();
        let m = MmapCheckpoint::open(&path).unwrap();
        assert_eq!(m.step(), 123);
        assert_eq!(m.section_names(), vec!["params", "m", "v"]);
        assert_eq!(m.leaf_names("params"), vec!["a", "b"]);
        for (name, store) in &ck.sections {
            for (leaf, t) in store.names.iter().zip(&store.leaves) {
                let (shape, data) = m.leaf(name, leaf).unwrap();
                assert_eq!(shape, &t.shape[..]);
                assert_eq!(data, &t.as_f32().unwrap()[..]);
                // f32 views demand 4-byte alignment; the mapped path
                // additionally lands on the 64-byte file alignment
                assert_eq!(data.as_ptr() as usize % 4, 0);
            }
        }
        assert_same(&ck, &m.to_checkpoint());
        assert!(m.leaf("params", "nope").is_err());
        assert!(m.leaf("nope", "a").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_rejects_v1_and_truncation() {
        let dir = std::env::temp_dir().join("holt_ckpt_test_reject");
        let v1 = dir.join("v1.ckpt");
        checkpoint().save_as(&v1, 1).unwrap();
        let err = MmapCheckpoint::open(&v1).unwrap_err().to_string();
        assert!(err.contains("v2"), "{err}");

        // a truncated v2 file fails the index bounds check up front,
        // not with a fault on first payload touch
        let v2 = dir.join("v2.ckpt");
        checkpoint().save(&v2).unwrap();
        let full = std::fs::read(&v2).unwrap();
        let cut = dir.join("cut.ckpt");
        std::fs::write(&cut, &full[..full.len() - 16]).unwrap();
        let err = MmapCheckpoint::open(&cut).unwrap_err().to_string();
        assert!(err.contains("exceeds file size"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("holt_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        assert!(MmapCheckpoint::open(&path).is_err());
        let vpath = dir.join("future.ckpt");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&vpath, &bytes).unwrap();
        let err = Checkpoint::load(&vpath).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 99"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
