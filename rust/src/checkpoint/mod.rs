//! Binary checkpoint format for parameter/optimizer state.
//!
//! Self-describing little-endian container (magic "HOLTCKPT", version,
//! step, named f32 leaves).  Written atomically (tmp file + rename) so a
//! crash mid-save never corrupts the previous checkpoint.
//!
//! Layout:
//! ```text
//! magic[8] version:u32 step:u64 n_sections:u32
//! per section: name_len:u32 name[..] n_leaves:u32
//!   per leaf: name_len:u32 name[..] rank:u32 dims[rank]:u64 data[f32...]
//! ```

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::params::ParamStore;
use crate::runtime::Tensor;

const MAGIC: &[u8; 8] = b"HOLTCKPT";
const VERSION: u32 = 1;

/// A full training checkpoint: params + AdamW moments + step counter.
pub struct Checkpoint {
    pub step: u64,
    pub sections: Vec<(String, ParamStore)>,
}

fn write_u32(w: &mut impl Write, x: u32) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, x: u64) -> Result<()> {
    w.write_all(&x.to_le_bytes())?;
    Ok(())
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    if n > 1 << 20 {
        bail!("unreasonable string length {n}");
    }
    let mut b = vec![0u8; n];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        {
            let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
            w.write_all(MAGIC)?;
            write_u32(&mut w, VERSION)?;
            write_u64(&mut w, self.step)?;
            write_u32(&mut w, self.sections.len() as u32)?;
            for (name, store) in &self.sections {
                write_str(&mut w, name)?;
                write_u32(&mut w, store.len() as u32)?;
                for (leaf_name, t) in store.names.iter().zip(&store.leaves) {
                    write_str(&mut w, leaf_name)?;
                    write_u32(&mut w, t.shape.len() as u32)?;
                    for &d in &t.shape {
                        write_u64(&mut w, d as u64)?;
                    }
                    let data = t.as_f32()?;
                    // bulk write — leaves can be tens of MB
                    let bytes: &[u8] = unsafe {
                        std::slice::from_raw_parts(
                            data.as_ptr() as *const u8,
                            data.len() * 4,
                        )
                    };
                    w.write_all(bytes)?;
                }
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut r = BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a HOLT checkpoint");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut r)?;
        let n_sections = read_u32(&mut r)? as usize;
        let mut sections = Vec::with_capacity(n_sections);
        for _ in 0..n_sections {
            let name = read_str(&mut r)?;
            let n_leaves = read_u32(&mut r)? as usize;
            let mut names = Vec::with_capacity(n_leaves);
            let mut leaves = Vec::with_capacity(n_leaves);
            for _ in 0..n_leaves {
                let leaf_name = read_str(&mut r)?;
                let rank = read_u32(&mut r)? as usize;
                let mut shape = Vec::with_capacity(rank);
                for _ in 0..rank {
                    shape.push(read_u64(&mut r)? as usize);
                }
                let n: usize = shape.iter().product();
                let mut data = vec![0f32; n];
                let bytes: &mut [u8] = unsafe {
                    std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
                };
                r.read_exact(bytes)?;
                names.push(leaf_name);
                leaves.push(Tensor::f32(shape, data));
            }
            sections.push((name, ParamStore { names, leaves }));
        }
        Ok(Checkpoint { step, sections })
    }

    pub fn section(&self, name: &str) -> Result<&ParamStore> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s)
            .ok_or_else(|| anyhow::anyhow!("checkpoint has no section '{name}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::runtime::{Init, LeafSpec};

    fn store(seed: u64) -> ParamStore {
        let spec = vec![
            LeafSpec { name: "a".into(), shape: vec![3, 5], init: Init::Normal { std: 1.0 } },
            LeafSpec { name: "b".into(), shape: vec![7], init: Init::Ones },
        ];
        ParamStore::init(&spec, &mut Rng::new(seed))
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("holt_ckpt_test");
        let path = dir.join("test.ckpt");
        let ck = Checkpoint {
            step: 123,
            sections: vec![
                ("params".into(), store(1)),
                ("m".into(), store(2)),
                ("v".into(), store(3)),
            ],
        };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 123);
        assert_eq!(back.sections.len(), 3);
        for (orig, loaded) in ck.sections.iter().zip(&back.sections) {
            assert_eq!(orig.0, loaded.0);
            assert_eq!(orig.1.names, loaded.1.names);
            assert_eq!(orig.1.leaves, loaded.1.leaves);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("holt_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
