//! Pure-rust reference math: Taylor expansion (Figure 1), exact softmax
//! attention, the paper's higher-order linear attention, and the elu+1
//! baseline — all direct, readable O(n²) implementations. These are the
//! *oracles*: the native O(n) kernels in `crate::kernels` and the AOT
//! artifacts are both cross-checked against this independently-written
//! code (see `rust/tests/proptests.rs`), and Figure 1 regenerates from
//! here without touching python.
//!
//! Shapes: attention functions take flat row-major buffers with explicit
//! (n, d) sizes for a single head; callers loop heads/batches.

/// sum_{i<=order} x^i / i! — the paper's exp approximation (Figure 1).
pub fn taylor_exp(x: f64, order: usize) -> f64 {
    let mut acc = 1.0;
    let mut term = 1.0;
    for i in 1..=order {
        term *= x / i as f64;
        acc += term;
    }
    acc
}

/// Row-wise LayerNorm without affine, in place. x is (n, d) row-major.
pub fn layernorm_noaffine(x: &mut [f32], n: usize, d: usize, eps: f32) {
    assert_eq!(x.len(), n * d);
    for r in 0..n {
        let row = &mut x[r * d..(r + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// VJP of [`layernorm_noaffine`]: given the raw rows `x` (n, d) and the
/// upstream gradient `g` w.r.t. the normalized rows, return the gradient
/// w.r.t. `x`.  With y = (x − μ)/σ, σ = √(var + ε):
///
/// ```text
/// dx = (g − mean(g) − y · mean(g ⊙ y)) / σ
/// ```
///
/// Math in f64 (the backward pass accumulates over whole sequences).
pub fn layernorm_noaffine_vjp(x: &[f32], n: usize, d: usize, eps: f32, g: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), n * d, "ln vjp x shape");
    assert_eq!(g.len(), n * d, "ln vjp g shape");
    let mut out = vec![0.0f64; n * d];
    for r in 0..n {
        let row = &x[r * d..(r + 1) * d];
        let grow = &g[r * d..(r + 1) * d];
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row
            .iter()
            .map(|&v| (v as f64 - mean) * (v as f64 - mean))
            .sum::<f64>()
            / d as f64;
        let sigma = (var + eps as f64).sqrt();
        let mut gm = 0.0f64;
        let mut gym = 0.0f64;
        for (&xv, &gv) in row.iter().zip(grow) {
            let y = (xv as f64 - mean) / sigma;
            gm += gv;
            gym += gv * y;
        }
        gm /= d as f64;
        gym /= d as f64;
        for ((o, &xv), &gv) in out[r * d..(r + 1) * d].iter_mut().zip(row).zip(grow) {
            let y = (xv as f64 - mean) / sigma;
            *o = (gv - gm - y * gym) / sigma;
        }
    }
    out
}

/// Exact softmax attention for one head: q (n,d), k (m,d), v (m,dv).
pub fn softmax_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
) -> Vec<f32> {
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; n * dv];
    let mut logits = vec![0.0f32; m];
    for i in 0..n {
        let limit = if causal { i + 1 } else { m };
        let mut maxv = f32::NEG_INFINITY;
        for j in 0..limit {
            let mut dot = 0.0f32;
            for c in 0..d {
                dot += q[i * d + c] * k[j * d + c];
            }
            logits[j] = dot * scale;
            maxv = maxv.max(logits[j]);
        }
        let mut den = 0.0f32;
        for j in 0..limit {
            logits[j] = (logits[j] - maxv).exp();
            den += logits[j];
        }
        for j in 0..limit {
            let w = logits[j] / den;
            for c in 0..dv {
                out[i * dv + c] += w * v[j * dv + c];
            }
        }
    }
    out
}

/// The paper's higher-order linear attention (direct O(n^2) evaluation,
/// used as an oracle): LN(q), LN(k), A = taylor(q.k/(a sqrt d)), row-norm.
#[allow(clippy::too_many_arguments)]
pub fn ho_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    order: usize,
    alpha: f64,
    causal: bool,
    normalize_qk: bool,
) -> Vec<f32> {
    let mut qn = q.to_vec();
    let mut kn = k.to_vec();
    if normalize_qk {
        layernorm_noaffine(&mut qn, n, d, 1e-5);
        layernorm_noaffine(&mut kn, m, d, 1e-5);
    }
    let scale = 1.0 / (alpha * (d as f64).sqrt());
    let mut out = vec![0.0f32; n * dv];
    for i in 0..n {
        let limit = if causal { i + 1 } else { m };
        let mut den = 0.0f64;
        let mut acc = vec![0.0f64; dv];
        for j in 0..limit {
            let mut dot = 0.0f64;
            for c in 0..d {
                dot += qn[i * d + c] as f64 * kn[j * d + c] as f64;
            }
            let w = taylor_exp(dot * scale, order);
            den += w;
            for c in 0..dv {
                acc[c] += w * v[j * dv + c] as f64;
            }
        }
        let den = den.max(1e-6);
        for c in 0..dv {
            out[i * dv + c] = (acc[c] / den) as f32;
        }
    }
    out
}

/// elu(x)+1 feature map (Katharopoulos et al. 2020 baseline).
pub fn elu1(x: f32) -> f32 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

/// First-order linear attention baseline (direct evaluation oracle).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * dv];
    for i in 0..n {
        let limit = if causal { i + 1 } else { m };
        let mut den = 0.0f64;
        let mut acc = vec![0.0f64; dv];
        for j in 0..limit {
            let mut w = 0.0f64;
            for c in 0..d {
                w += elu1(q[i * d + c]) as f64 * elu1(k[j * d + c]) as f64;
            }
            den += w;
            for c in 0..dv {
                acc[c] += w * v[j * dv + c] as f64;
            }
        }
        let den = den.max(1e-6);
        for c in 0..dv {
            out[i * dv + c] = (acc[c] / den) as f32;
        }
    }
    out
}

/// Run a single-head attention reference over a (b, h, n, d) tensor the
/// way the AOT attention artifacts are shaped. kind: "softmax" | "linear"
/// | "ho"/"ho2" (the Taylor kernel, any order/alpha — "ho2" is the
/// historic spelling kept as an alias).
#[allow(clippy::too_many_arguments)]
pub fn attention_bhnd(
    kind: &str,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bh: usize,
    n: usize,
    d: usize,
    order: usize,
    alpha: f64,
    causal: bool,
) -> Vec<f32> {
    let stride = n * d;
    let mut out = vec![0.0f32; bh * stride];
    for s in 0..bh {
        let (qs, ks, vs) = (
            &q[s * stride..(s + 1) * stride],
            &k[s * stride..(s + 1) * stride],
            &v[s * stride..(s + 1) * stride],
        );
        let o = match kind {
            "softmax" => softmax_attention(qs, ks, vs, n, n, d, d, causal),
            "linear" => linear_attention(qs, ks, vs, n, n, d, d, causal),
            "ho" | "ho2" => ho_attention(qs, ks, vs, n, n, d, d, order, alpha, causal, true),
            _ => panic!("unknown attention kind {kind}"),
        };
        out[s * stride..(s + 1) * stride].copy_from_slice(&o);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn taylor_matches_exp_near_zero() {
        for &x in &[-0.1, 0.0, 0.05, 0.2] {
            assert!((taylor_exp(x, 2) - x.exp()).abs() < 2e-3, "x={x}");
            assert!((taylor_exp(x, 3) - x.exp()).abs() < 1e-4, "x={x}");
        }
        // paper's Figure 1 point: far from zero the approximation is bad
        assert!((taylor_exp(3.0, 2) - 3f64.exp()).abs() > 10.0);
    }

    #[test]
    fn taylor_order2_is_exactly_the_quadratic() {
        // order 2 must be literally 1 + x + x²/2, not merely close
        for i in -60..=60 {
            let x = i as f64 * 0.1;
            let want = 1.0 + x + x * x / 2.0;
            assert!((taylor_exp(x, 2) - want).abs() < 1e-12, "x={x}");
        }
        // and the low orders degenerate as they should
        assert_eq!(taylor_exp(7.5, 0), 1.0);
        assert!((taylor_exp(7.5, 1) - 8.5).abs() < 1e-12);
    }

    #[test]
    fn taylor_converges_to_exp_as_order_grows() {
        for &x in &[-2.0, -0.5, 0.3, 1.0, 2.0] {
            let mut prev = f64::INFINITY;
            for order in [2, 4, 6, 8, 12] {
                let err = (taylor_exp(x, order) - x.exp()).abs();
                assert!(err <= prev + 1e-15, "x={x} order={order}: {err} > {prev}");
                prev = err;
            }
            // order 12 on |x| <= 2 is accurate to ~1e-6 (worst case x = ±2)
            assert!(prev < 1e-5, "x={x}: residual {prev}");
        }
    }

    #[test]
    fn taylor_order2_is_positive() {
        // 1 + x + x^2/2 >= 1/2 — the denominator-safety property
        for i in -100..=100 {
            let x = i as f64 * 0.3;
            assert!(taylor_exp(x, 2) >= 0.5 - 1e-12, "x={x}");
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut r = Rng::new(0);
        let (n, d) = (4, 64);
        let mut x = r.normal_vec_f32(n * d, 2.0);
        layernorm_noaffine(&mut x, n, d, 1e-5);
        for row in x.chunks(d) {
            let mean = row.iter().sum::<f32>() / d as f32;
            let var = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_constant_rows_stay_finite() {
        // zero variance: eps must keep the result finite (and exactly 0,
        // since every deviation from the mean is 0)
        let (n, d) = (3, 16);
        for c in [0.0f32, 1.0, -4.5, 1e6] {
            let mut x = vec![c; n * d];
            layernorm_noaffine(&mut x, n, d, 1e-5);
            for &v in &x {
                assert!(v.is_finite(), "c={c}");
                assert_eq!(v, 0.0, "c={c}");
            }
        }
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        let mut r = Rng::new(1);
        let (n, d) = (8, 16);
        let q = r.normal_vec_f32(n * d, 1.0);
        let k = r.normal_vec_f32(n * d, 1.0);
        let v = vec![1.0f32; n * d]; // constant v -> output must be exactly 1
        let out = softmax_attention(&q, &k, &v, n, n, d, d, false);
        for x in out {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn ho_attention_constant_v_invariant() {
        // row-normalized weights: constant v must be reproduced exactly
        let mut r = Rng::new(2);
        let (n, d) = (8, 16);
        let q = r.normal_vec_f32(n * d, 1.0);
        let k = r.normal_vec_f32(n * d, 1.0);
        let v = vec![2.5f32; n * d];
        for order in [0, 1, 2] {
            let out = ho_attention(&q, &k, &v, n, n, d, d, order, 3.0, true, true);
            for x in out {
                assert!((x - 2.5).abs() < 1e-4, "order {order}");
            }
        }
    }

    #[test]
    fn causal_prefix_property() {
        // causal attention output at position i must not change when the
        // suffix after i changes
        let mut r = Rng::new(3);
        let (n, d) = (12, 8);
        let q = r.normal_vec_f32(n * d, 1.0);
        let k = r.normal_vec_f32(n * d, 1.0);
        let v = r.normal_vec_f32(n * d, 1.0);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for x in &mut k2[6 * d..] {
            *x += 5.0;
        }
        for x in &mut v2[6 * d..] {
            *x -= 3.0;
        }
        for kind in ["softmax", "linear", "ho2"] {
            let a = attention_bhnd(kind, &q, &k, &v, 1, n, d, 2, 3.0, true);
            let b = attention_bhnd(kind, &q, &k2, &v2, 1, n, d, 2, 3.0, true);
            for i in 0..6 * d {
                assert!((a[i] - b[i]).abs() < 1e-5, "{kind} leaked future");
            }
        }
    }

    #[test]
    fn ho2_approximates_softmax_on_small_logits() {
        // with LN + alpha=3 the logits are small, so order-2 should be a
        // decent softmax approximation — and order 2 beats order 1
        let mut r = Rng::new(4);
        let (n, d) = (32, 32);
        let q = r.normal_vec_f32(n * d, 1.0);
        let k = r.normal_vec_f32(n * d, 1.0);
        let v = r.normal_vec_f32(n * d, 1.0);
        // the softmax target with the same LN + alpha rescaling
        let mut qn = q.clone();
        let mut kn = k.clone();
        layernorm_noaffine(&mut qn, n, d, 1e-5);
        layernorm_noaffine(&mut kn, n, d, 1e-5);
        let alpha = 3.0f32;
        let qs: Vec<f32> = qn.iter().map(|x| x / alpha.sqrt()).collect();
        let ks: Vec<f32> = kn.iter().map(|x| x / alpha.sqrt()).collect();
        let target = softmax_attention(&qs, &ks, &v, n, n, d, d, false);
        let err = |o: &[f32]| -> f64 {
            o.iter()
                .zip(&target)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e1 = err(&ho_attention(&q, &k, &v, n, n, d, d, 1, 3.0, false, true));
        let e2 = err(&ho_attention(&q, &k, &v, n, n, d, d, 2, 3.0, false, true));
        assert!(e2 < e1, "order 2 ({e2}) should beat order 1 ({e1})");
    }
}
