//! # HOLT — Higher Order Linear Transformer
//!
//! Reproduction of Mercat 2020, *Higher Order Linear Transformer*:
//! softmax attention replaced by its 2nd-order Taylor expansion, which
//! factorizes into running-sum state — O(n) time over a sequence and O(1)
//! state per token while decoding.
//!
//! The crate has **two execution paths**:
//!
//! * **Native (default, zero setup)** — [`kernels`] implements the
//!   factorized recurrence directly in Rust, organized around one
//!   [`kernels::FeatureMap`] abstraction: a single generic
//!   [`kernels::PhiState`] recurrence (absorb / O(1)-decode `step` /
//!   backward) instantiated by [`kernels::TaylorMap`] (the paper's
//!   kernel at *any* Taylor order — `ho_tiny_o3` runs order 3, beyond
//!   the paper) and [`kernels::EluMap`] (the elu+1 first-order
//!   baseline), a cache-blocked [`kernels::chunked_forward`] for full
//!   sequences, and [`kernels::NativeBackend`] tying them into the
//!   batched `(b·h, n, d)` layout. [`mathref`] keeps the direct O(n²)
//!   evaluations as independent oracles; the property tests pin
//!   recurrent ≡ chunked ≡ oracle across orders 0–3.
//!   On top of the kernels, [`model`] is a full pure-Rust transformer —
//!   chunked prefill, O(1)-state [`model::DecodeSession`] decoding, the
//!   [`model::Executor`] trait the coordinator serves through, and
//!   [`model::grad`]: a hand-derived backward through the same chunked
//!   recurrence plus native AdamW, behind the
//!   [`coordinator::trainer::TrainBackend`] trait — so `holt train`,
//!   `holt ablation`, `holt generate` and `holt serve` (all `--backend
//!   native`) work end to end with no artifacts, no PJRT and no Python,
//!   as do `cargo test`, `cargo run --example quickstart` and
//!   `cargo bench --bench native_scaling`.
//!
//! * **PJRT artifacts (optional)** — the original three-layer stack:
//!   Pallas kernels (`python/compile/kernels/`), a jax transformer LM
//!   AOT-lowered to HLO text (`python/compile/aot.py`), and [`runtime`]
//!   executing those artifacts through a PJRT client, driven by the
//!   [`coordinator`] (training, O(1)-state serving, every paper
//!   experiment). Offline builds link a vendored stub `xla` crate that
//!   reports itself unavailable at `Runtime::new`; swap in a real PJRT
//!   `xla` crate and build with `--features artifacts` to enable the
//!   integration tests (see README.md).
//!
//! Entry points: the `holt` binary (`main.rs` CLI), `examples/`, and
//! `benches/` (one per paper table/figure).

pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod json;
pub mod kernels;
pub mod mathref;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod params;
pub mod plot;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod state;
pub mod tokenizer;

/// Locate the artifacts directory: `$HOLT_ARTIFACTS` if set (validated),
/// else the first `artifacts/manifest.json` found walking up from the
/// current directory.
///
/// Errors instead of guessing: callers used to receive a relative
/// `"artifacts"` path that might not exist and fail later with a confusing
/// manifest error. Artifact-path entry points want the actionable message
/// up front — and the native kernels ([`kernels`]) never need this at all.
pub fn default_artifacts_dir() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("HOLT_ARTIFACTS") {
        let path = std::path::PathBuf::from(&dir);
        if !path.join("manifest.json").exists() {
            anyhow::bail!(
                "$HOLT_ARTIFACTS points at '{dir}' but there is no manifest.json there \
                 (run `make artifacts` to build them)"
            );
        }
        return Ok(path);
    }
    let start = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut cur = start.clone();
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !cur.pop() {
            anyhow::bail!(
                "no artifacts directory found walking up from {start:?}: set $HOLT_ARTIFACTS \
                 or run `make artifacts`. (The native kernel path — holt::kernels, the \
                 quickstart example, `holt crosscheck --native` — needs no artifacts.)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn missing_artifacts_dir_is_an_error_not_a_guess() {
        // run from a temp cwd with no artifacts anywhere up the tree is not
        // something a unit test can guarantee, but the env-var path is:
        // point HOLT_ARTIFACTS at a bogus dir and expect a clear error.
        // (env vars are process-global; keep this the only test touching it)
        std::env::set_var("HOLT_ARTIFACTS", "/definitely/not/a/real/artifacts/dir");
        let err = super::default_artifacts_dir().unwrap_err().to_string();
        std::env::remove_var("HOLT_ARTIFACTS");
        assert!(err.contains("manifest.json"), "{err}");
    }
}
