//! # HOLT — Higher Order Linear Transformer
//!
//! Reproduction of Mercat 2020, *Higher Order Linear Transformer*: linear-
//! complexity attention through a 2nd-order Taylor expansion of the softmax,
//! built as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels for the factorized
//!   higher-order attention + baselines, with pure-jnp oracles.
//! * **L2** (`python/compile/model.py`): jax transformer LM (fwd / fused
//!   AdamW train step / O(1)-state recurrent decode), AOT-lowered to HLO
//!   text once by `python/compile/aot.py`.
//! * **L3** (this crate): the runtime coordinator — loads the artifacts via
//!   PJRT and runs training, serving and every paper experiment with no
//!   python on any hot path.
//!
//! Entry points: the `holt` binary (see `main.rs` for the CLI), the
//! examples (`examples/`), and the benches (`benches/`, one per paper
//! table/figure — see DESIGN.md §4 for the experiment index).

pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod json;
pub mod mathref;
pub mod metrics;
pub mod params;
pub mod plot;
pub mod rng;
pub mod runtime;
pub mod tokenizer;

/// Locate the artifacts directory: `$HOLT_ARTIFACTS`, else the first
/// `artifacts/manifest.json` found walking up from the current directory.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("HOLT_ARTIFACTS") {
        return dir.into();
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
