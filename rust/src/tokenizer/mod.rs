//! Tokenization substrate: byte-level tokenizer (the default for all
//! experiments; vocab = 256 bytes + specials, matching python/compile/
//! configs.py) plus a small trainable BPE for the char-LM workloads.

pub mod bpe;

/// Special token ids — must match python/compile/configs.py.
pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
/// Total vocab size the models are lowered with (padded to multiple of 16).
pub const VOCAB_SIZE: usize = 272;

/// Byte-level tokenizer: one token per byte, specials above 255.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    /// Encode with BOS prepended and optionally EOS appended.
    pub fn encode_with_specials(&self, text: &str, eos: bool) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 2);
        v.push(BOS);
        v.extend(text.as_bytes().iter().map(|&b| b as i32));
        if eos {
            v.push(EOS);
        }
        v
    }

    /// Decode, dropping specials and replacing invalid utf-8 lossily.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello, world");
        assert_eq!(t.decode(&ids), "hello, world");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "héllo 😀";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = ByteTokenizer::new();
        let ids = t.encode_with_specials("ab", true);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = ByteTokenizer::new();
        for id in t.encode_with_specials("\u{0}\u{7f}é", true) {
            assert!((id as usize) < VOCAB_SIZE);
        }
    }
}
