//! Minimal byte-pair encoding: trainable merge table over bytes.
//!
//! Used by the char-LM workload when `--bpe-merges N` is set; the byte
//! tokenizer is the default.  Merged tokens are assigned ids from 259
//! upward (after the specials), capped at VOCAB_SIZE, so BPE-encoded
//! streams remain valid inputs for the lowered models.

use std::collections::HashMap;

use super::VOCAB_SIZE;

/// A trained BPE model: ordered merges + decode table.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// (left, right) -> merged id, in training order (rank = priority).
    merges: Vec<((i32, i32), i32)>,
    /// merged id -> byte expansion
    expansions: HashMap<i32, Vec<u8>>,
}

impl Bpe {
    /// Train `n_merges` merges on a corpus (greedy most-frequent-pair).
    pub fn train(corpus: &[u8], n_merges: usize) -> Bpe {
        let mut seq: Vec<i32> = corpus.iter().map(|&b| b as i32).collect();
        let mut merges = Vec::new();
        let mut expansions: HashMap<i32, Vec<u8>> = HashMap::new();
        let mut next_id = 259; // after PAD/BOS/EOS

        for _ in 0..n_merges {
            if next_id as usize >= VOCAB_SIZE || seq.len() < 2 {
                break;
            }
            // count adjacent pairs
            let mut counts: HashMap<(i32, i32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &count)) = counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if count < 2 {
                break;
            }
            let id = next_id;
            next_id += 1;
            merges.push((pair, id));
            let expand = |tok: i32, exp: &HashMap<i32, Vec<u8>>| -> Vec<u8> {
                if tok < 256 {
                    vec![tok as u8]
                } else {
                    exp.get(&tok).cloned().unwrap_or_default()
                }
            };
            let mut e = expand(pair.0, &expansions);
            e.extend(expand(pair.1, &expansions));
            expansions.insert(id, e);
            // apply the merge to the working sequence
            seq = apply_merge(&seq, pair, id);
        }
        Bpe { merges, expansions }
    }

    /// Encode bytes by replaying merges in training order.
    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        let mut seq: Vec<i32> = text.iter().map(|&b| b as i32).collect();
        for &(pair, id) in &self.merges {
            seq = apply_merge(&seq, pair, id);
        }
        seq
    }

    /// Decode ids back to bytes.
    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        for &t in tokens {
            if (0..256).contains(&t) {
                out.push(t as u8);
            } else if let Some(e) = self.expansions.get(&t) {
                out.extend_from_slice(e);
            }
            // specials are dropped
        }
        out
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }
}

fn apply_merge(seq: &[i32], pair: (i32, i32), id: i32) -> Vec<i32> {
    let mut out = Vec::with_capacity(seq.len());
    let mut i = 0;
    while i < seq.len() {
        if i + 1 < seq.len() && seq[i] == pair.0 && seq[i + 1] == pair.1 {
            out.push(id);
            i += 2;
        } else {
            out.push(seq[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn train_and_roundtrip() {
        let corpus = b"the theme of the thesis is the theory";
        let bpe = Bpe::train(corpus, 8);
        assert!(bpe.n_merges() > 0);
        let ids = bpe.encode(corpus);
        assert!(ids.len() < corpus.len(), "BPE should compress");
        assert_eq!(bpe.decode(&ids), corpus.to_vec());
    }

    #[test]
    fn roundtrip_unseen_text() {
        let bpe = Bpe::train(b"aaabbbaaabbb", 4);
        let text = b"xyz aaa bbb unseen";
        assert_eq!(bpe.decode(&bpe.encode(text)), text.to_vec());
    }

    #[test]
    fn ids_stay_in_vocab() {
        let bpe = Bpe::train(b"abababababababab", 100);
        for &id in &bpe.encode(b"abab") {
            assert!((id as usize) < VOCAB_SIZE);
        }
    }

    #[test]
    fn zero_merges_is_bytes() {
        let bpe = Bpe::train(b"abcabc", 0);
        let ids = bpe.encode(b"abc");
        assert_eq!(ids, vec![97, 98, 99]);
    }
}
