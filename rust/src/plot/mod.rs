//! Terminal plotting: render metric series (e.g. the E3 loss curves from
//! the trainer's JSONL logs) as a braille/ASCII chart — no plotting
//! dependency exists offline, and eyeballing loss curves matters.

use anyhow::{bail, Context, Result};

use crate::json::Json;

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Extract a series from a trainer JSONL log: events with
    /// `event == filter` contribute (`x_key`, `y_key`).
    pub fn from_jsonl(
        path: &std::path::Path,
        filter: &str,
        x_key: &str,
        y_key: &str,
    ) -> Result<Series> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        let mut points = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line)?;
            if v.get("event").and_then(|j| j.as_str()) == Some(filter) {
                if let (Some(x), Some(y)) = (
                    v.get(x_key).and_then(|j| j.as_f64()),
                    v.get(y_key).and_then(|j| j.as_f64()),
                ) {
                    points.push((x, y));
                }
            }
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok(Series { name, points })
    }
}

const MARKS: [char; 6] = ['o', '+', 'x', '*', '#', '@'];

/// Render series into a `width` x `height` character chart with axes and a
/// legend.  Points are mapped nearest-cell; later series draw over earlier
/// ones (legend shows each series' mark).
pub fn render(series: &[Series], width: usize, height: usize) -> Result<String> {
    if series.iter().all(|s| s.points.is_empty()) {
        bail!("nothing to plot");
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for s in series {
        for &(x, y) in &s.points {
            xmin = xmin.min(x);
            xmax = xmax.max(x);
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx] = mark;
        }
    }

    let mut out = String::new();
    for (r, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yv:>9.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}{:<w2$}{:>w3$.0}\n",
        "",
        format!("{xmin:.0}"),
        xmax,
        w2 = width / 2,
        w3 = width / 2
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "    {} {}  (n={})\n",
            MARKS[si % MARKS.len()],
            s.name,
            s.points.len()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_monotone_series() {
        let s = Series {
            name: "loss".into(),
            points: (0..50).map(|i| (i as f64, 5.0 - 0.08 * i as f64)).collect(),
        };
        let chart = render(&[s], 60, 12).unwrap();
        assert!(chart.contains('o'));
        assert!(chart.contains("loss"));
        // descending series: first data row (max y) has a mark near the left
        let first_row = chart.lines().next().unwrap();
        let last_data_row = chart.lines().nth(11).unwrap();
        assert!(first_row.find('o').unwrap() < last_data_row.find('o').unwrap());
    }

    #[test]
    fn multiple_series_get_distinct_marks() {
        let a = Series { name: "a".into(), points: vec![(0.0, 0.0), (1.0, 1.0)] };
        let b = Series { name: "b".into(), points: vec![(0.0, 1.0), (1.0, 0.0)] };
        let chart = render(&[a, b], 20, 8).unwrap();
        assert!(chart.contains('o') && chart.contains('+'));
    }

    #[test]
    fn rejects_empty() {
        assert!(render(&[], 10, 5).is_err());
        let s = Series { name: "e".into(), points: vec![] };
        assert!(render(&[s], 10, 5).is_err());
    }

    #[test]
    fn from_jsonl_extracts_events() {
        let dir = std::env::temp_dir().join("holt_plot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("log.jsonl");
        std::fs::write(
            &p,
            concat!(
                r#"{"event":"start","steps":2}"#,
                "\n",
                r#"{"event":"step","step":1,"loss":5.0}"#,
                "\n",
                r#"{"event":"step","step":2,"loss":4.0}"#,
                "\n",
                r#"{"event":"eval","step":2,"accuracy":0.5}"#,
                "\n"
            ),
        )
        .unwrap();
        let s = Series::from_jsonl(&p, "step", "step", "loss").unwrap();
        assert_eq!(s.points, vec![(1.0, 5.0), (2.0, 4.0)]);
        let e = Series::from_jsonl(&p, "eval", "step", "accuracy").unwrap();
        assert_eq!(e.points, vec![(2.0, 0.5)]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
