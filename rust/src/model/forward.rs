//! `NativeModel` — the pure-Rust transformer forward over a
//! [`ParamStore`], running attention through the native O(n) kernels.
//!
//! Architecture (exact mirror of `python/compile/model.py::forward`):
//! token embedding + learned absolute positions, pre-LN blocks
//! (LN → multi-head attention → residual, LN → GELU FFN → residual),
//! final LN, logits tied to the embedding.  Attention is dispatched per
//! (sequence, head) through [`NativeBackend`] — chunked evaluation for
//! the full-sequence form here, streaming `step` in
//! [`DecodeSession`](crate::model::DecodeSession).
//!
//! The per-(sequence, head) attention calls are independent, so the
//! forward fans them out over the persistent
//! [`WorkerPool`](crate::model::WorkerPool) — the same parallelism shape
//! (and the same pool) as the decode batch loop in `NativeExecutor` and
//! the train-step vjp loop.

use anyhow::{ensure, Result};

use crate::kernels::{Evaluation, NativeBackend, RecurrentAttention};
use crate::model::nn;
use crate::params::ParamStore;
use crate::runtime::{ModelConfig, ModelEntry};

/// Leaf offsets inside one block, in `param_spec` order (shared with the
/// training backward in `model::grad`).
pub(crate) const L_LN1_G: usize = 0;
pub(crate) const L_LN1_B: usize = 1;
pub(crate) const L_WQ: usize = 2;
pub(crate) const L_WK: usize = 3;
pub(crate) const L_WV: usize = 4;
pub(crate) const L_WO: usize = 5;
pub(crate) const L_LN2_G: usize = 6;
pub(crate) const L_LN2_B: usize = 7;
pub(crate) const L_W1: usize = 8;
pub(crate) const L_B1: usize = 9;
pub(crate) const L_W2: usize = 10;
pub(crate) const L_B2: usize = 11;
/// Leaves per block.
pub(crate) const L_PER_BLOCK: usize = 12;

/// Leaf index of `lnf_g` (with `lnf_b` right after it).
pub(crate) fn lnf_index(n_layers: usize) -> usize {
    2 + L_PER_BLOCK * n_layers
}

/// Borrowed weight view of one transformer block.
pub struct LayerView<'a> {
    pub ln1_g: &'a [f32],
    pub ln1_b: &'a [f32],
    pub wq: &'a [f32],
    pub wk: &'a [f32],
    pub wv: &'a [f32],
    pub wo: &'a [f32],
    pub ln2_g: &'a [f32],
    pub ln2_b: &'a [f32],
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
}

/// A model: config + parameters + the native attention backend.
/// Immutable and `Sync` — one instance serves every decode slot and every
/// prefill thread concurrently.
pub struct NativeModel {
    entry: ModelEntry,
    params: ParamStore,
    backend: NativeBackend,
}

impl NativeModel {
    /// Wrap a parameter store for `entry`, validating names/shapes/dtypes
    /// against `entry.param_spec` up front so weight accessors are
    /// infallible afterwards.
    pub fn new(entry: ModelEntry, params: ParamStore) -> Result<NativeModel> {
        params.check_spec(&entry.param_spec)?;
        for (name, t) in params.names.iter().zip(&params.leaves) {
            ensure!(t.as_f32().is_ok(), "parameter leaf '{name}' is not f32");
        }
        let cfg = &entry.config;
        ensure!(cfg.n_heads > 0 && cfg.d_model % cfg.n_heads == 0, "bad head split");
        ensure!(
            entry.param_spec.len() == 2 + L_PER_BLOCK * cfg.n_layers + 2,
            "param spec does not look like the transformer layout"
        );
        let backend = NativeBackend {
            order: cfg.order,
            alpha: cfg.alpha,
            normalize_qk: true,
            chunk: 64,
            evaluation: Evaluation::Chunked,
            isa: None,
        };
        Ok(NativeModel { entry, params, backend })
    }

    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    pub fn config(&self) -> &ModelConfig {
        &self.entry.config
    }

    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    fn leaf(&self, i: usize) -> &[f32] {
        self.params.leaves[i].as_f32().expect("validated f32 in new()")
    }

    /// (vocab, d_model) token embedding — also the tied LM head.
    pub fn embed(&self) -> &[f32] {
        self.leaf(0)
    }

    /// (max_len, d_model) learned positions.
    pub fn pos_embed(&self) -> &[f32] {
        self.leaf(1)
    }

    pub fn lnf_g(&self) -> &[f32] {
        self.leaf(lnf_index(self.entry.config.n_layers))
    }

    pub fn lnf_b(&self) -> &[f32] {
        self.leaf(lnf_index(self.entry.config.n_layers) + 1)
    }

    /// Weight view of block `li`.
    pub fn layer(&self, li: usize) -> LayerView<'_> {
        layer_view(&self.params, li)
    }

    /// Fresh recurrent attention state for one head — errors for
    /// `"softmax"` (no O(1) recurrent form).
    pub fn kernel_state(&self) -> Result<Box<dyn RecurrentAttention + Send>> {
        let dh = self.entry.config.d_model / self.entry.config.n_heads;
        self.backend.state(&self.entry.config.attn, dh, dh)
    }

    /// Full-sequence forward: `tokens` (b·t, row-major (b, t)) → logits
    /// (b, t, vocab) flat.  Causal; attention runs in the chunked O(n)
    /// evaluation (exact softmax for the `"softmax"` baseline).
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        let cfg = &self.entry.config;
        let (d, v, nh, ff) = (cfg.d_model, cfg.vocab_size, cfg.n_heads, cfg.d_ff);
        let dh = d / nh;
        ensure!(tokens.len() == b * t && b > 0 && t > 0, "tokens shape ({b}, {t})");
        ensure!(
            t <= cfg.max_len,
            "sequence length {t} exceeds model max_len {}",
            cfg.max_len
        );

        // embedding + positions
        let embed = self.embed();
        let pose = self.pos_embed();
        let mut x = vec![0.0f32; b * t * d];
        for (row, &tok) in tokens.iter().enumerate() {
            ensure!(
                (0..v as i32).contains(&tok),
                "token {tok} out of vocab {v}"
            );
            let ti = row % t;
            let e = &embed[tok as usize * d..(tok as usize + 1) * d];
            let p = &pose[ti * d..(ti + 1) * d];
            for (o, (&ev, &pv)) in x[row * d..(row + 1) * d].iter_mut().zip(e.iter().zip(p)) {
                *o = ev + pv;
            }
        }

        for li in 0..cfg.n_layers {
            let lw = self.layer(li);
            // attention sublayer
            let (q, k, vv) = block_qkv(&lw, &x, b * t, d);
            let mut units = Vec::with_capacity(b * nh);
            for bi in 0..b {
                for hd in 0..nh {
                    units.push((
                        gather_head(&q, bi, t, d, hd, dh),
                        gather_head(&k, bi, t, d, hd, dh),
                        gather_head(&vv, bi, t, d, hd, dh),
                    ));
                }
            }
            let outs = self.attend_units(&units, t, dh)?;
            let mut a = vec![0.0f32; b * t * d];
            for (u, o) in outs.iter().enumerate() {
                scatter_head(&mut a, o, u / nh, t, d, u % nh, dh);
            }
            block_finish(&lw, &mut x, &a, b * t, d, ff);
        }

        let xf = nn::layernorm_affine(&x, b * t, d, self.lnf_g(), self.lnf_b());
        Ok(nn::tied_logits(&xf, b * t, d, embed, v))
    }

    /// Run one attention call per (sequence, head) unit, fanned out over
    /// the persistent worker pool (each unit is independent).
    fn attend_units(
        &self,
        units: &[(Vec<f32>, Vec<f32>, Vec<f32>)],
        t: usize,
        dh: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let kind = self.entry.config.attn.as_str();
        let backend = &self.backend;
        let mut work: Vec<(&(Vec<f32>, Vec<f32>, Vec<f32>), Option<Result<Vec<f32>>>)> =
            units.iter().map(|u| (u, None)).collect();
        fan_out(&mut work, |item| {
            let (q, k, v) = item.0;
            item.1 = Some(backend.forward(kind, q, k, v, t, dh, dh, true));
        });
        work.into_iter()
            .map(|(_, o)| o.expect("every attention unit is computed"))
            .collect()
    }
}

/// Run `f` over every item on the persistent process-wide
/// [`WorkerPool`] (the caller's thread participates; serial when the
/// batch is trivial).  The one fan-out used by the prefill head loop,
/// the executor's decode batch loop, and the train-step vjp loop —
/// previously each call spawned and joined a fresh `std::thread::scope`.
pub(crate) fn fan_out<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], f: F) {
    crate::model::pool::WorkerPool::global().fan_out(items, f)
}

/// [`fan_out`] bounded to at most `cap` threads (0 = uncapped, 1 =
/// fully serial on the caller) — the data-parallel gradient loop uses
/// this to honor `--grad-workers` without resizing the shared pool.
pub(crate) fn fan_out_capped<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], cap: usize, f: F) {
    crate::model::pool::WorkerPool::global().fan_out_capped(items, cap, f)
}

/// Weight view of block `li` over a [`ParamStore`] whose leaves were
/// validated f32 (see [`NativeModel::new`] / `NativeTrainer`) — shared
/// by the serving forward and the training backward.
pub(crate) fn layer_view(params: &ParamStore, li: usize) -> LayerView<'_> {
    let leaf = |i: usize| params.leaves[i].as_f32().expect("validated f32 leaves");
    let base = 2 + li * L_PER_BLOCK;
    LayerView {
        ln1_g: leaf(base + L_LN1_G),
        ln1_b: leaf(base + L_LN1_B),
        wq: leaf(base + L_WQ),
        wk: leaf(base + L_WK),
        wv: leaf(base + L_WV),
        wo: leaf(base + L_WO),
        ln2_g: leaf(base + L_LN2_G),
        ln2_b: leaf(base + L_LN2_B),
        w1: leaf(base + L_W1),
        b1: leaf(base + L_B1),
        w2: leaf(base + L_W2),
        b2: leaf(base + L_B2),
    }
}

/// ln1 → q/k/v projections for `rows` rows of `x` — the pre-attention
/// half of a block, shared verbatim by the full-sequence forward, the
/// serve engine's chunked prompt absorption
/// ([`crate::model::DecodeSession::absorb_chunk`]) and the per-token
/// decode, so the paths cannot drift apart.
pub(crate) fn block_qkv(
    lw: &LayerView<'_>,
    x: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut h = vec![0.0f32; rows * d];
    let mut q = vec![0.0f32; rows * d];
    let mut k = vec![0.0f32; rows * d];
    let mut v = vec![0.0f32; rows * d];
    block_qkv_into(lw, x, rows, d, &mut h, &mut q, &mut k, &mut v);
    (q, k, v)
}

/// [`block_qkv`] into caller-owned buffers (`h` is the LN scratch, also
/// overwritten). The allocating form delegates here — same ops, same
/// order, bit-identical — so the decode scratch path cannot drift.
#[allow(clippy::too_many_arguments)]
pub(crate) fn block_qkv_into(
    lw: &LayerView<'_>,
    x: &[f32],
    rows: usize,
    d: usize,
    h: &mut [f32],
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
) {
    nn::layernorm_affine_into(x, rows, d, lw.ln1_g, lw.ln1_b, h);
    nn::matmul_into(h, lw.wq, rows, d, d, q);
    nn::matmul_into(h, lw.wk, rows, d, d, k);
    nn::matmul_into(h, lw.wv, rows, d, d, v);
}

/// Attention output projection + residual, then the FFN sublayer (`b2`
/// lands outside the matmul, as in the jax model) — the post-attention
/// half of a block, shared by prefill and decode.
pub(crate) fn block_finish(
    lw: &LayerView<'_>,
    x: &mut [f32],
    a: &[f32],
    rows: usize,
    d: usize,
    ff: usize,
) {
    let mut ao = vec![0.0f32; rows * d];
    let mut h = vec![0.0f32; rows * d];
    let mut f = vec![0.0f32; rows * ff];
    let mut g = vec![0.0f32; rows * d];
    block_finish_into(lw, x, a, rows, d, ff, &mut ao, &mut h, &mut f, &mut g);
}

/// [`block_finish`] with caller-owned scratch (`ao`, `h`, `f`, `g` are
/// all overwritten). The allocating form delegates here.
#[allow(clippy::too_many_arguments)]
pub(crate) fn block_finish_into(
    lw: &LayerView<'_>,
    x: &mut [f32],
    a: &[f32],
    rows: usize,
    d: usize,
    ff: usize,
    ao: &mut [f32],
    h: &mut [f32],
    f: &mut [f32],
    g: &mut [f32],
) {
    nn::matmul_into(a, lw.wo, rows, d, d, ao);
    nn::add_inplace(x, ao);
    nn::layernorm_affine_into(x, rows, d, lw.ln2_g, lw.ln2_b, h);
    nn::matmul_into(h, lw.w1, rows, d, ff, f);
    nn::add_bias(f, rows, ff, lw.b1);
    nn::gelu_inplace(f);
    nn::matmul_into(f, lw.w2, rows, ff, d, g);
    nn::add_inplace(x, g);
    nn::add_bias(x, rows, d, lw.b2);
}

/// Copy head `hd`'s (t, dh) slice out of a (t, d) row-major buffer for
/// sequence `bi` of a (b, t, d) stack.
pub(crate) fn gather_head(
    src: &[f32],
    bi: usize,
    t: usize,
    d: usize,
    hd: usize,
    dh: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * dh];
    for (ti, orow) in out.chunks_mut(dh).enumerate() {
        let base = (bi * t + ti) * d + hd * dh;
        orow.copy_from_slice(&src[base..base + dh]);
    }
    out
}

/// Inverse of [`gather_head`].
pub(crate) fn scatter_head(
    dst: &mut [f32],
    src: &[f32],
    bi: usize,
    t: usize,
    d: usize,
    hd: usize,
    dh: usize,
) {
    for (ti, srow) in src.chunks(dh).enumerate() {
        let base = (bi * t + ti) * d + hd * dh;
        dst[base..base + dh].copy_from_slice(srow);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::native_model_entry;
    use crate::rng::Rng;

    fn tiny_model(name: &str, seed: u64) -> NativeModel {
        let entry = native_model_entry(name).unwrap();
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(seed));
        NativeModel::new(entry, params).unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_model("ho2_tiny", 0);
        let (b, t) = (2, 12);
        let toks: Vec<i32> = (0..(b * t) as i32).map(|i| i % 256).collect();
        let logits = m.forward(&toks, b, t).unwrap();
        assert_eq!(logits.len(), b * t * m.config().vocab_size);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_is_deterministic_across_thread_schedules() {
        // the parallel fan-out must not change results run to run
        let m = tiny_model("ho2_tiny", 1);
        let toks: Vec<i32> = (0..24).map(|i| (i * 7) % 256).collect();
        let a = m.forward(&toks, 2, 12).unwrap();
        let b = m.forward(&toks, 2, 12).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn causality_suffix_changes_do_not_leak_backward() {
        let m = tiny_model("ho2_tiny", 2);
        let t = 16;
        let mut toks: Vec<i32> = (0..t as i32).map(|i| (i * 11) % 256).collect();
        let base = m.forward(&toks, 1, t).unwrap();
        let v = m.config().vocab_size;
        toks[t - 1] = (toks[t - 1] + 1) % 256; // perturb only the last token
        let got = m.forward(&toks, 1, t).unwrap();
        for i in 0..(t - 1) * v {
            assert_eq!(base[i], got[i], "position {} leaked the future", i / v);
        }
    }

    #[test]
    fn rejects_bad_tokens_and_lengths() {
        let m = tiny_model("ho2_tiny", 3);
        assert!(m.forward(&[99999], 1, 1).is_err());
        assert!(m.forward(&[-1], 1, 1).is_err());
        let long = vec![0i32; 129];
        assert!(m.forward(&long, 1, 129).is_err(), "tiny max_len is 128");
    }

    #[test]
    fn softmax_baseline_forward_works_natively() {
        let m = tiny_model("softmax_tiny", 4);
        let toks: Vec<i32> = (0..10).collect();
        let logits = m.forward(&toks, 1, 10).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
