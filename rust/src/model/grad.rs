//! Native training: the hand-derived backward pass through the full
//! transformer, gradient-checked against finite differences.
//!
//! # One forward, capture and reverse
//!
//! A train step pays for attention exactly **once**.  The cached
//! training forward runs [`chunked_forward_captured`] per (sequence,
//! head) unit: the serving forward's exact arithmetic, which *also*
//! records the backward's tape — raw denominators, f64 numerators,
//! chunk-boundary state snapshots, and the prepped q/k rows — into a
//! [`CapturedChunks`] held in the unit's [`VjpPlan`].  The backward
//! then calls [`chunked_attention_vjp_reverse`] on that tape: no
//! forward replay, zero `prep_rows` calls on the way back.  (The
//! historic replaying path survives as [`loss_and_grad_replay`], the
//! bench/test baseline; its gradients are bit-identical because the
//! capture *is* the replay's first phase.)
//!
//! # The backward recurrence
//!
//! * **loss** — weighted softmax cross-entropy: `dlogits = (p − 1ₜ)·w/W`
//!   per scored position, `W = max(Σw, 1)` (mirror of
//!   `python/compile/model.py::loss_fn`).
//! * **dense ops** (`matmul`, LayerNorm, GELU, tied logits, embedding
//!   gather) — standard VJPs, written with the same fixed accumulation
//!   order discipline as the forward in [`crate::model::nn`].
//! * **attention** — the causal O(n) recurrence is differentiated *as
//!   the recurrence*, not as an unrolled n² graph: pairwise weights
//!   inside a chunk are differentiated directly (`Tᵣ'(s) = Tᵣ₋₁(s)` for
//!   Taylor order r), while a single *state-gradient* vector — the loss
//!   gradient w.r.t. each prefix-sum moment (Σ1, Σk, Σk⊗v, Σk⊗k,
//!   Σ(k⊗k)⊗v) — flows backward across chunks, exactly as Katharopoulos
//!   et al. 2020 describe for first-order linear attention.  Cost stays
//!   O(n), and decode-time state and train-time gradient share one
//!   layout.  The softmax baseline has no linear-time form in either
//!   direction and uses the direct [`softmax_attention_vjp`].
//!
//! # What a FeatureMap owes the vjp
//!
//! A new φ gets all of this for free by implementing
//! `FeatureMap::prep_rows_vjp` + `map_q_vjp`/`map_k_vjp` +
//! `pair_weight_dot_grad` (see `kernels/featuremap.rs`): the generic
//! `PhiState` derives the [`crate::kernels::AttentionGrad`] surface —
//! `query_vjp` (state read), `absorb_vjp` (additive update), and the
//! row-prep backward — and both the capture and reverse phases are
//! kernel-agnostic on top of that.  Nothing in this module is
//! per-kernel.
//!
//! # Data-parallel accumulation
//!
//! [`loss_and_grad_accum`] is the trainer's entry point: the unit of
//! computation is always **one sequence** (so splitting a batch across
//! micro-batches or worker threads cannot reassociate any f32 sum), the
//! global weight normalizer `W` is computed once over the whole batch
//! and baked into every per-sequence backward, and the per-sequence
//! gradients merge through a **fixed-shape binary-counter tree**
//! ([`TreeReducer`]) keyed only on the sequence index — so loss curves
//! are bit-reproducible across `--grad-workers` and `--accum` settings
//! (pinned in `rust/tests/train_native.rs`).
//!
//! `rust/tests/grad_check.rs` pins every kernel kind × order against
//! finite differences of f64 oracles (rel. err ≤ 1e-3) and the full
//! model against numeric directional derivatives;
//! `rust/tests/fused_train.rs` pins the one-forward-per-step claim with
//! the process-global [`crate::kernels::counters`] instrument.

use anyhow::{ensure, Result};

use crate::data::Batch;
use crate::kernels::{
    chunked_attention_vjp, chunked_attention_vjp_reverse, chunked_forward_captured,
    softmax_attention_vjp, AttentionGrad, CapturedChunks, Evaluation, NativeBackend,
};
use crate::model::forward::{
    block_finish, block_qkv, fan_out, fan_out_capped, gather_head, layer_view, lnf_index,
    scatter_head, L_B1, L_B2, L_LN1_B, L_LN1_G, L_LN2_B, L_LN2_G, L_PER_BLOCK, L_W1, L_W2, L_WK,
    L_WO, L_WQ, L_WV,
};
use crate::model::nn::{self, LN_EPS};
use crate::params::ParamStore;
use crate::runtime::ModelConfig;

/// Chunk length of the training-time attention evaluation — the same
/// value `NativeModel` serves with, so train/eval/serve forwards agree
/// bit for bit outside the f64 state reassociation.
const TRAIN_CHUNK: usize = 64;

/// Training-phase span histograms on the global [`crate::obs`] registry.
/// Timers only: recording wraps the phases without touching any of their
/// arithmetic, so loss curves stay bit-reproducible (test- and
/// CI-pinned) whether or not anyone reads the histograms.
mod spans {
    use std::sync::OnceLock;

    use crate::obs;

    /// Forward pass with VJP-tape capture (`forward_cached`).
    pub fn grad_capture_us() -> &'static obs::Histo {
        static H: OnceLock<obs::Histo> = OnceLock::new();
        H.get_or_init(|| obs::global().histo("grad_capture_us"))
    }

    /// The backward sweep: loss head back to embeddings.
    pub fn reverse_sweep_us() -> &'static obs::Histo {
        static H: OnceLock<obs::Histo> = OnceLock::new();
        H.get_or_init(|| obs::global().histo("reverse_sweep_us"))
    }

    /// Deterministic pairwise gradient reduction (`TreeReducer`).
    pub fn tree_reduce_us() -> &'static obs::Histo {
        static H: OnceLock<obs::Histo> = OnceLock::new();
        H.get_or_init(|| obs::global().histo("tree_reduce_us"))
    }
}

fn backend_for(cfg: &ModelConfig) -> NativeBackend {
    NativeBackend {
        order: cfg.order,
        alpha: cfg.alpha,
        normalize_qk: true,
        chunk: TRAIN_CHUNK,
        evaluation: Evaluation::Chunked,
        isa: None,
    }
}

/// Cached activations of one block, in forward order.
struct LayerCache {
    /// residual stream entering the block (rows, d)
    x_in: Vec<f32>,
    /// ln1 output (rows, d)
    h1: Vec<f32>,
    /// per-(sequence, head) attention units — the gathered q/k/v rows
    /// the backward re-uses, and (on the fused path) each unit's
    /// recorded [`VjpPlan`]
    units: Vec<AttnUnit>,
    /// concatenated attention output (rows, d)
    a: Vec<f32>,
    /// residual stream after the attention sublayer (rows, d)
    x_mid: Vec<f32>,
    /// ln2 output (rows, d)
    h2: Vec<f32>,
    /// pre-GELU FFN activation (rows, ff)
    f_pre: Vec<f32>,
    /// post-GELU FFN activation (rows, ff)
    f_post: Vec<f32>,
}

/// Everything the backward needs from one forward pass.
struct Cache {
    layers: Vec<LayerCache>,
    /// residual stream entering the final LayerNorm (rows, d)
    x_out: Vec<f32>,
    /// final LayerNorm output — the tied-head input (rows, d)
    xf: Vec<f32>,
}

/// What one attention unit's fused forward leaves behind for its
/// backward: the kernel instance that ran the capture (the reverse
/// sweep reuses its scratch arena and pinned ISA) and the tape itself.
pub(crate) struct VjpPlan {
    st: Box<dyn AttentionGrad + Send>,
    cap: CapturedChunks,
}

/// One attention unit (sequence × head) of the parallel fan-out.
struct AttnUnit {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    out: Vec<f32>,
    /// `Some` iff the forward captured (fused path, non-softmax kinds)
    plan: Option<VjpPlan>,
}

/// (gq, gk, gv) of one attention unit.
type UnitGrads = (Vec<f32>, Vec<f32>, Vec<f32>);

/// Run the attention forward for every (sequence, head) unit — the same
/// dispatch `NativeModel::forward` uses, so logits agree exactly.  With
/// `capture` set (and a non-softmax kind), each unit runs
/// [`chunked_forward_captured`] instead: identical output bits, plus
/// the recorded [`VjpPlan`] that makes the backward replay-free.
fn attend_forward(
    cfg: &ModelConfig,
    units: &mut [AttnUnit],
    t: usize,
    dh: usize,
    capture: bool,
) -> Result<()> {
    let backend = backend_for(cfg);
    let kind = cfg.attn.as_str();
    if capture && kind != "softmax" {
        let mut work: Vec<(&mut AttnUnit, Option<Result<()>>)> =
            units.iter_mut().map(|u| (u, None)).collect();
        fan_out(&mut work, |(u, done)| {
            *done = Some(backend.grad_state(kind, dh, dh).map(|mut st| {
                let (out, cap) =
                    chunked_forward_captured(st.as_mut(), &u.q, &u.k, &u.v, t, TRAIN_CHUNK);
                u.out = out;
                u.plan = Some(VjpPlan { st, cap });
            }));
        });
        for (_, done) in work {
            done.expect("every unit computed")?;
        }
        return Ok(());
    }
    let mut work: Vec<(&mut AttnUnit, Option<Result<Vec<f32>>>)> =
        units.iter_mut().map(|u| (u, None)).collect();
    fan_out(&mut work, |(u, out)| {
        *out = Some(backend.forward(kind, &u.q, &u.k, &u.v, t, dh, dh, true));
    });
    for (u, out) in work {
        u.out = out.expect("every unit computed")?;
    }
    Ok(())
}

/// Token embedding + learned positions into a fresh residual stream —
/// shared by the cached (train) and lean (eval) forwards.
fn embed_tokens(
    cfg: &ModelConfig,
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    t: usize,
) -> Result<Vec<f32>> {
    let (d, v) = (cfg.d_model, cfg.vocab_size);
    let rows = b * t;
    ensure!(tokens.len() == rows && b > 0 && t > 0, "tokens shape ({b}, {t})");
    ensure!(t <= cfg.max_len, "sequence length {t} exceeds max_len {}", cfg.max_len);
    let embed = params.leaves[0].as_f32()?;
    let pose = params.leaves[1].as_f32()?;
    let mut x = vec![0.0f32; rows * d];
    for (row, &tok) in tokens.iter().enumerate() {
        ensure!((0..v as i32).contains(&tok), "token {tok} out of vocab {v}");
        let ti = row % t;
        let e = &embed[tok as usize * d..(tok as usize + 1) * d];
        let p = &pose[ti * d..(ti + 1) * d];
        for (o, (&ev, &pv)) in x[row * d..(row + 1) * d].iter_mut().zip(e.iter().zip(p)) {
            *o = ev + pv;
        }
    }
    Ok(x)
}

/// Attention sublayer over the whole batch: gather heads, fan out,
/// scatter back into a (rows, d) buffer — and hand the units back so
/// the cached forward can keep them (q/k/v rows + any recorded
/// [`VjpPlan`]) for the backward.
fn attend_batched(
    cfg: &ModelConfig,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    t: usize,
    capture: bool,
) -> Result<(Vec<f32>, Vec<AttnUnit>)> {
    let d = cfg.d_model;
    let nh = cfg.n_heads;
    let dh = d / nh;
    let mut units = Vec::with_capacity(b * nh);
    for bi in 0..b {
        for hd in 0..nh {
            units.push(AttnUnit {
                q: gather_head(q, bi, t, d, hd, dh),
                k: gather_head(k, bi, t, d, hd, dh),
                v: gather_head(v, bi, t, d, hd, dh),
                out: Vec::new(),
                plan: None,
            });
        }
    }
    attend_forward(cfg, &mut units, t, dh, capture)?;
    let mut a = vec![0.0f32; b * t * d];
    for (u, unit) in units.iter_mut().enumerate() {
        scatter_head(&mut a, &unit.out, u / nh, t, d, u % nh, dh);
        // scattered: the backward never reads the per-unit output
        unit.out = Vec::new();
    }
    Ok((a, units))
}

/// Full-sequence forward with activation caching.  Identical arithmetic
/// to [`crate::model::NativeModel::forward`] (same `nn` ops in the same
/// order, same chunked attention — the capture changes nothing about
/// the output bits) — pinned by a test in `rust/tests/grad_check.rs`.
/// With `capture` set, each attention unit records its [`VjpPlan`] so
/// the backward is replay-free.
fn forward_cached(
    cfg: &ModelConfig,
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    t: usize,
    capture: bool,
) -> Result<(Vec<f32>, Cache)> {
    let (d, v, ff) = (cfg.d_model, cfg.vocab_size, cfg.d_ff);
    let rows = b * t;
    let mut x = embed_tokens(cfg, params, tokens, b, t)?;
    let embed = params.leaves[0].as_f32()?;

    let mut layers = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let lw = layer_view(params, li);
        let x_in = x.clone();
        let h1 = nn::layernorm_affine(&x, rows, d, lw.ln1_g, lw.ln1_b);
        let q = nn::matmul(&h1, lw.wq, rows, d, d);
        let k = nn::matmul(&h1, lw.wk, rows, d, d);
        let vv = nn::matmul(&h1, lw.wv, rows, d, d);
        let (a, units) = attend_batched(cfg, &q, &k, &vv, b, t, capture)?;

        let ao = nn::matmul(&a, lw.wo, rows, d, d);
        nn::add_inplace(&mut x, &ao);
        let x_mid = x.clone();
        let h2 = nn::layernorm_affine(&x, rows, d, lw.ln2_g, lw.ln2_b);
        let mut f_pre = nn::matmul(&h2, lw.w1, rows, d, ff);
        nn::add_bias(&mut f_pre, rows, ff, lw.b1);
        let mut f_post = f_pre.clone();
        nn::gelu_inplace(&mut f_post);
        let g = nn::matmul(&f_post, lw.w2, rows, ff, d);
        nn::add_inplace(&mut x, &g);
        nn::add_bias(&mut x, rows, d, lw.b2);

        layers.push(LayerCache { x_in, h1, units, a, x_mid, h2, f_pre, f_post });
    }

    let x_out = x;
    let lnf = lnf_index(cfg.n_layers);
    let xf = nn::layernorm_affine(
        &x_out,
        rows,
        d,
        params.leaves[lnf].as_f32()?,
        params.leaves[lnf + 1].as_f32()?,
    );
    let logits = nn::tied_logits(&xf, rows, d, embed, v);
    Ok((logits, Cache { layers, x_out, xf }))
}

/// Teacher-forced logits only — the eval path of `NativeTrainer`.
/// Cache-free: runs the same shared block helpers
/// ([`block_qkv`]/[`block_finish`]) as `NativeModel::forward`, so eval
/// pays no activation-cache allocations and stays bit-identical to both
/// the serving forward and the cached training forward.
pub fn forward_logits(
    cfg: &ModelConfig,
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    t: usize,
) -> Result<Vec<f32>> {
    let (d, v, ff) = (cfg.d_model, cfg.vocab_size, cfg.d_ff);
    let rows = b * t;
    let mut x = embed_tokens(cfg, params, tokens, b, t)?;
    for li in 0..cfg.n_layers {
        let lw = layer_view(params, li);
        let (q, k, vv) = block_qkv(&lw, &x, rows, d);
        let (a, _units) = attend_batched(cfg, &q, &k, &vv, b, t, false)?;
        block_finish(&lw, &mut x, &a, rows, d, ff);
    }
    let lnf = lnf_index(cfg.n_layers);
    let xf = nn::layernorm_affine(
        &x,
        rows,
        d,
        params.leaves[lnf].as_f32()?,
        params.leaves[lnf + 1].as_f32()?,
    );
    Ok(nn::tied_logits(&xf, rows, d, params.leaves[0].as_f32()?, v))
}

/// The whole batch's weight normalizer `W = max(Σw, 1)` — computed once
/// over the *full* batch so per-sequence backward calls of
/// [`loss_and_grad_accum`] bake in the identical scale.
fn batch_wnorm(batch: &Batch) -> Result<f64> {
    let weights = batch.weights.as_f32()?;
    Ok(weights.iter().map(|&w| w as f64).sum::<f64>().max(1.0))
}

/// Weighted-CE loss and its gradient w.r.t. every parameter leaf, as a
/// [`ParamStore`] with the same names/shapes as `params`.  Fused path:
/// one attention forward per (sequence, head), backward from the
/// recorded capture.
pub fn loss_and_grad(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
) -> Result<(f64, ParamStore)> {
    let wnorm = batch_wnorm(batch)?;
    let (raw, grads) = loss_and_grad_inner(cfg, params, batch, wnorm, true)?;
    Ok((raw / wnorm, grads))
}

/// The historic two-forward path: plain forward (no capture), backward
/// rebuilds each unit's tape inside [`chunked_attention_vjp`].
/// Gradients and loss are **bit-identical** to [`loss_and_grad`] — the
/// capture *is* the replay's first phase, arithmetic unchanged — which
/// is exactly what lets `rust/tests/fused_train.rs` pin the fusion as a
/// pure cost optimization, and what `benches/train_throughput.rs`
/// measures `fused_speedup_vs_replay` against.
pub fn loss_and_grad_replay(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
) -> Result<(f64, ParamStore)> {
    let wnorm = batch_wnorm(batch)?;
    let (raw, grads) = loss_and_grad_inner(cfg, params, batch, wnorm, false)?;
    Ok((raw / wnorm, grads))
}

/// One forward + backward over `batch` with an externally fixed weight
/// normalizer; returns the **raw** (un-normalized) weighted CE sum so
/// callers can sum losses across micro-batches in a fixed order.
fn loss_and_grad_inner(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
    wnorm: f64,
    fused: bool,
) -> Result<(f64, ParamStore)> {
    let (b, t) = (batch.batch_size(), batch.seq_len());
    let tokens = batch.tokens.as_i32()?;
    let targets = batch.targets.as_i32()?;
    let weights = batch.weights.as_f32()?;
    let (d, v, nh, ff) = (cfg.d_model, cfg.vocab_size, cfg.n_heads, cfg.d_ff);
    let dh = d / nh;
    let rows = b * t;
    ensure!(targets.len() == rows && weights.len() == rows, "batch shapes");

    let (logits, mut cache) = {
        let _span = spans::grad_capture_us().span();
        forward_cached(cfg, params, tokens, b, t, fused)?
    };

    // ---- loss + dlogits (softmax CE, weighted, /max(Σw, 1)) ----
    let mut loss = 0.0f64;
    let mut dlogits = vec![0.0f32; rows * v];
    for i in 0..rows {
        let w = weights[i] as f64;
        if w == 0.0 {
            continue;
        }
        ensure!((0..v as i32).contains(&targets[i]), "target out of vocab");
        let row = &logits[i * v..(i + 1) * v];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x)) as f64;
        let z: f64 = row.iter().map(|&x| (x as f64 - maxv).exp()).sum();
        let logz = maxv + z.ln();
        loss += w * (logz - row[targets[i] as usize] as f64);
        let drow = &mut dlogits[i * v..(i + 1) * v];
        let scale = w / wnorm;
        for (dc, &x) in drow.iter_mut().zip(row) {
            *dc = (((x as f64 - maxv).exp() / z) * scale) as f32;
        }
        drow[targets[i] as usize] -= scale as f32;
    }

    // ---- backward ----
    let _sweep = spans::reverse_sweep_us().span(); // drops at return
    let mut grads = params.zeros_like();
    let embed = params.leaves[0].as_f32()?;
    let lnf = lnf_index(cfg.n_layers);

    // tied head: logits = xf · embedᵀ
    // dembed += dlogitsᵀ · xf ; dxf = dlogits · embed
    matmul_gw(&dlogits, &cache.xf, rows, v, d, grads.leaves[0].as_f32_mut()?);
    let dxf = nn::matmul(&dlogits, embed, rows, v, d);

    // final LayerNorm
    let lnf_g = params.leaves[lnf].as_f32()?;
    let mut dx = {
        let (dx, dg, db) = layernorm_affine_vjp(&cache.x_out, rows, d, lnf_g, &dxf);
        nn::add_inplace(grads.leaves[lnf].as_f32_mut()?, &dg);
        nn::add_inplace(grads.leaves[lnf + 1].as_f32_mut()?, &db);
        dx
    };

    for li in (0..cfg.n_layers).rev() {
        let lw = layer_view(params, li);
        let units = std::mem::take(&mut cache.layers[li].units);
        let lc = &cache.layers[li];
        let base = 2 + li * L_PER_BLOCK;

        // x_out = x_mid + f_post·w2 + b2
        add_rows_into(grads.leaves[base + L_B2].as_f32_mut()?, &dx, rows, d);
        matmul_gw(&lc.f_post, &dx, rows, ff, d, grads.leaves[base + L_W2].as_f32_mut()?);
        let df_post = matmul_gx(&dx, lw.w2, rows, ff, d);
        let df_pre = gelu_vjp(&lc.f_pre, &df_post);
        add_rows_into(grads.leaves[base + L_B1].as_f32_mut()?, &df_pre, rows, ff);
        matmul_gw(&lc.h2, &df_pre, rows, d, ff, grads.leaves[base + L_W1].as_f32_mut()?);
        let dh2 = matmul_gx(&df_pre, lw.w1, rows, d, ff);
        let (dx_ln2, dg2, db2) = layernorm_affine_vjp(&lc.x_mid, rows, d, lw.ln2_g, &dh2);
        nn::add_inplace(grads.leaves[base + L_LN2_G].as_f32_mut()?, &dg2);
        nn::add_inplace(grads.leaves[base + L_LN2_B].as_f32_mut()?, &db2);
        // residual join: x_mid feeds both the FFN sublayer and x_out
        let mut dx_mid = dx;
        nn::add_inplace(&mut dx_mid, &dx_ln2);

        // attention output projection
        matmul_gw(&lc.a, &dx_mid, rows, d, d, grads.leaves[base + L_WO].as_f32_mut()?);
        let da = matmul_gx(&dx_mid, lw.wo, rows, d, d);

        // per-(sequence, head) attention backward, fanned out like the
        // forward — fused units run the reverse sweep straight off their
        // recorded capture; planless units (the replay baseline, and any
        // future path without a capture) rebuild the tape first
        let mut work: Vec<(AttnUnit, Vec<f32>, Option<UnitGrads>)> = units
            .into_iter()
            .enumerate()
            .map(|(u, unit)| {
                let go = gather_head(&da, u / nh, t, d, u % nh, dh);
                (unit, go, None)
            })
            .collect();
        let backend = backend_for(cfg);
        let kind = cfg.attn.as_str();
        fan_out(&mut work, |(u, go, out)| {
            *out = Some(if kind == "softmax" {
                softmax_attention_vjp(&u.q, &u.k, &u.v, t, dh, dh, true, go)
            } else if let Some(VjpPlan { st, cap }) = u.plan.as_mut() {
                chunked_attention_vjp_reverse(st.as_mut(), cap, &u.q, &u.k, &u.v, go)
            } else {
                let mut st = backend
                    .grad_state(kind, dh, dh)
                    .expect("attention kind validated at model construction");
                chunked_attention_vjp(st.as_mut(), &u.q, &u.k, &u.v, t, TRAIN_CHUNK, go)
            });
        });
        let mut dq = vec![0.0f32; rows * d];
        let mut dk = vec![0.0f32; rows * d];
        let mut dv = vec![0.0f32; rows * d];
        for (u, (_, _, out)) in work.iter().enumerate() {
            let (gq, gk, gv) = out.as_ref().expect("every unit computed");
            scatter_head(&mut dq, gq, u / nh, t, d, u % nh, dh);
            scatter_head(&mut dk, gk, u / nh, t, d, u % nh, dh);
            scatter_head(&mut dv, gv, u / nh, t, d, u % nh, dh);
        }

        // q/k/v projections share the ln1 output
        matmul_gw(&lc.h1, &dq, rows, d, d, grads.leaves[base + L_WQ].as_f32_mut()?);
        matmul_gw(&lc.h1, &dk, rows, d, d, grads.leaves[base + L_WK].as_f32_mut()?);
        matmul_gw(&lc.h1, &dv, rows, d, d, grads.leaves[base + L_WV].as_f32_mut()?);
        let mut dh1 = matmul_gx(&dq, lw.wq, rows, d, d);
        nn::add_inplace(&mut dh1, &matmul_gx(&dk, lw.wk, rows, d, d));
        nn::add_inplace(&mut dh1, &matmul_gx(&dv, lw.wv, rows, d, d));
        let (dx_ln1, dg1, db1) = layernorm_affine_vjp(&lc.x_in, rows, d, lw.ln1_g, &dh1);
        nn::add_inplace(grads.leaves[base + L_LN1_G].as_f32_mut()?, &dg1);
        nn::add_inplace(grads.leaves[base + L_LN1_B].as_f32_mut()?, &db1);
        // residual join: x_in feeds both ln1 and x_mid
        dx = dx_mid;
        nn::add_inplace(&mut dx, &dx_ln1);
    }

    // embedding gather + learned positions
    {
        let gembed = grads.leaves[0].as_f32_mut()?;
        for (row, &tok) in tokens.iter().enumerate() {
            let dst = &mut gembed[tok as usize * d..(tok as usize + 1) * d];
            for (g, &x) in dst.iter_mut().zip(&dx[row * d..(row + 1) * d]) {
                *g += x;
            }
        }
    }
    {
        let gpos = grads.leaves[1].as_f32_mut()?;
        for row in 0..rows {
            let ti = row % t;
            let dst = &mut gpos[ti * d..(ti + 1) * d];
            for (g, &x) in dst.iter_mut().zip(&dx[row * d..(row + 1) * d]) {
                *g += x;
            }
        }
    }

    Ok((loss, grads))
}

/// [`loss_and_grad`] as explicit micro-batch gradient accumulation plus
/// data-parallel per-sequence gradient workers — the trainer's entry
/// point.
///
/// Determinism contract (pinned in `rust/tests/train_native.rs`): the
/// result is **bit-identical** for every `(accum, grad_workers)`
/// setting, because
/// * the unit of computation is always one sequence (f32 accumulation
///   inside a sequence's backward never crosses a split boundary),
/// * the weight normalizer is computed once over the full batch,
/// * losses sum in sequence order in f64, and
/// * per-sequence gradients merge through the fixed-shape
///   [`TreeReducer`], whose schedule depends only on the batch size.
///
/// `accum` splits the batch into that many contiguous micro-batches
/// (clamped to `[1, B]`); `grad_workers` caps the threads of the
/// per-sequence fan-out (0 = the whole worker pool, 1 = serial).
pub fn loss_and_grad_accum(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
    accum: usize,
    grad_workers: usize,
) -> Result<(f64, ParamStore)> {
    let b = batch.batch_size();
    ensure!(b > 0, "empty batch");
    let wnorm = batch_wnorm(batch)?;
    let accum = accum.clamp(1, b);
    let mut reducer = TreeReducer::new();
    let mut raw = 0.0f64;
    let mut s0 = 0;
    for ai in 0..accum {
        // balanced contiguous micro-batches, fixed by (B, accum) alone
        let s1 = s0 + (b - s0).div_ceil(accum - ai);
        let mut items: Vec<(Batch, Option<Result<(f64, ParamStore)>>)> =
            Vec::with_capacity(s1 - s0);
        for s in s0..s1 {
            items.push((batch.slice_rows(s, s + 1)?, None));
        }
        fan_out_capped(&mut items, grad_workers, |(sb, out)| {
            *out = Some(loss_and_grad_inner(cfg, params, sb, wnorm, true));
        });
        // fold in sequence order regardless of which thread computed what
        {
            let _span = spans::tree_reduce_us().span();
            for (_, out) in items {
                let (l, g) = out.expect("every sequence computed")?;
                raw += l;
                reducer.push(g)?;
            }
        }
        s0 = s1;
    }
    let grads = {
        let _span = spans::tree_reduce_us().span();
        reducer.finish()?
    };
    Ok((raw / wnorm, grads))
}

/// Deterministic fixed-shape pairwise reduction of per-sequence
/// gradients: a binary counter of partial sums (the classic pairwise-
/// summation tree).  Leaves are pushed in sequence order; equal-sized
/// partials merge like binary-addition carries (1+1→2, 2+2→4, …), so
/// the full merge schedule is a function of the leaf count alone —
/// never of worker count, micro-batch split, or thread timing.  f32
/// addition is not associative; a timing-dependent order here would
/// make loss curves irreproducible across `--grad-workers` settings.
struct TreeReducer {
    /// (leaf count, partial sum), counts strictly decreasing powers of
    /// two from the bottom up — exactly the set bits of the number of
    /// leaves pushed so far
    stack: Vec<(usize, ParamStore)>,
}

impl TreeReducer {
    fn new() -> TreeReducer {
        TreeReducer { stack: Vec::new() }
    }

    /// Fold in the next leaf (earlier partial += later, preserving
    /// sequence order inside every merge).
    fn push(&mut self, g: ParamStore) -> Result<()> {
        let mut count = 1usize;
        let mut g = g;
        while let Some((c, _)) = self.stack.last() {
            if *c != count {
                break;
            }
            let (_, mut left) = self.stack.pop().expect("checked non-empty");
            left.add_assign(&g)?;
            g = left;
            count *= 2;
        }
        self.stack.push((count, g));
        Ok(())
    }

    /// Collapse the remaining partials, most recent (smallest) first —
    /// a fixed order given the leaf count.
    fn finish(mut self) -> Result<ParamStore> {
        ensure!(!self.stack.is_empty(), "no gradients reduced");
        let (_, mut acc) = self.stack.pop().expect("checked non-empty");
        while let Some((_, mut next)) = self.stack.pop() {
            next.add_assign(&acc)?;
            acc = next;
        }
        Ok(acc)
    }
}

// ---------------------------------------------------------------------------
// dense VJPs
// ---------------------------------------------------------------------------

/// dX of `Y = X·W`: `dX = dY·Wᵀ`.  `dy` is (n, m), `w` is (d, m).
fn matmul_gx(dy: &[f32], w: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    assert_eq!(dy.len(), n * m, "matmul_gx dy shape");
    assert_eq!(w.len(), d * m, "matmul_gx w shape");
    let mut dx = vec![0.0f32; n * d];
    for (dyr, dxr) in dy.chunks(m).zip(dx.chunks_mut(d)) {
        for (o, wr) in dxr.iter_mut().zip(w.chunks(m)) {
            let mut acc = 0.0f32;
            for (&wv, &dv) in wr.iter().zip(dyr) {
                acc += wv * dv;
            }
            *o = acc;
        }
    }
    dx
}

/// dW of `Y = X·W`, accumulated: `dW += Xᵀ·dY`.  `x` is (n, d), `dy`
/// (n, m), `dw` (d, m).
fn matmul_gw(x: &[f32], dy: &[f32], n: usize, d: usize, m: usize, dw: &mut [f32]) {
    assert_eq!(x.len(), n * d, "matmul_gw x shape");
    assert_eq!(dy.len(), n * m, "matmul_gw dy shape");
    assert_eq!(dw.len(), d * m, "matmul_gw dw shape");
    for (xr, dyr) in x.chunks(d).zip(dy.chunks(m)) {
        for (&xi, dwr) in xr.iter().zip(dw.chunks_mut(m)) {
            for (o, &dv) in dwr.iter_mut().zip(dyr) {
                *o += xi * dv;
            }
        }
    }
}

/// VJP of [`nn::layernorm_affine`]: returns (dx, dgain, dbias).  One
/// statistics pass per row — with ŷ = (x − μ)/σ and g = dy ⊙ gain:
///
/// ```text
/// dgain += Σᵣ dy ⊙ ŷ     dbias += Σᵣ dy
/// dx = (g − mean(g) − ŷ · mean(g ⊙ ŷ)) / σ
/// ```
fn layernorm_affine_vjp(
    x: &[f32],
    n: usize,
    d: usize,
    gain: &[f32],
    dy: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), n * d, "ln vjp x shape");
    assert_eq!(dy.len(), n * d, "ln vjp dy shape");
    assert_eq!(gain.len(), d, "ln vjp gain shape");
    let mut dgain = vec![0.0f64; d];
    let mut dbias = vec![0.0f64; d];
    let mut dx = vec![0.0f32; n * d];
    let mut y = vec![0.0f64; d];
    let mut g = vec![0.0f64; d];
    for r in 0..n {
        let row = &x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / d as f64;
        let var = row
            .iter()
            .map(|&v| (v as f64 - mean) * (v as f64 - mean))
            .sum::<f64>()
            / d as f64;
        let sigma = (var + LN_EPS as f64).sqrt();
        let mut gm = 0.0f64;
        let mut gym = 0.0f64;
        for c in 0..d {
            y[c] = (row[c] as f64 - mean) / sigma;
            let dyv = dyr[c] as f64;
            dgain[c] += dyv * y[c];
            dbias[c] += dyv;
            g[c] = dyv * gain[c] as f64;
            gm += g[c];
            gym += g[c] * y[c];
        }
        gm /= d as f64;
        gym /= d as f64;
        for c in 0..d {
            dx[r * d + c] = ((g[c] - gm - y[c] * gym) / sigma) as f32;
        }
    }
    (
        dx,
        dgain.iter().map(|&v| v as f32).collect(),
        dbias.iter().map(|&v| v as f32).collect(),
    )
}

/// VJP of the tanh-approximated GELU in [`nn::gelu_inplace`], from the
/// *pre*-activation values.
fn gelu_vjp(x_pre: &[f32], dy: &[f32]) -> Vec<f32> {
    const C: f64 = 0.797_884_56;
    assert_eq!(x_pre.len(), dy.len(), "gelu vjp shape");
    x_pre
        .iter()
        .zip(dy)
        .map(|(&x, &g)| {
            let x = x as f64;
            let t = (C * (x + 0.044715 * x * x * x)).tanh();
            let d = 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * 0.044715 * x * x);
            (g as f64 * d) as f32
        })
        .collect()
}

/// Column-sum a (n, m) gradient into a (m,) bias gradient: `acc += Σ rows`.
fn add_rows_into(acc: &mut [f32], dy: &[f32], n: usize, m: usize) {
    assert_eq!(acc.len(), m, "bias grad shape");
    assert_eq!(dy.len(), n * m, "bias grad rows shape");
    for row in dy.chunks(m) {
        for (a, &b) in acc.iter_mut().zip(row) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::native_model_entry;
    use crate::rng::Rng;

    #[test]
    fn matmul_vjps_match_explicit_sums() {
        let mut rng = Rng::new(41);
        let (n, d, m) = (3, 4, 5);
        let x = rng.normal_vec_f32(n * d, 1.0);
        let w = rng.normal_vec_f32(d * m, 1.0);
        let dy = rng.normal_vec_f32(n * m, 1.0);
        let dx = matmul_gx(&dy, &w, n, d, m);
        for r in 0..n {
            for i in 0..d {
                let want: f32 = (0..m).map(|j| dy[r * m + j] * w[i * m + j]).sum();
                assert!((dx[r * d + i] - want).abs() < 1e-5);
            }
        }
        let mut dw = vec![0.0f32; d * m];
        matmul_gw(&x, &dy, n, d, m, &mut dw);
        for i in 0..d {
            for j in 0..m {
                let want: f32 = (0..n).map(|r| x[r * d + i] * dy[r * m + j]).sum();
                assert!((dw[i * m + j] - want).abs() < 1e-5);
            }
        }
    }

    /// The accumulation determinism contract in miniature: every
    /// (accum, grad_workers) setting produces the same loss bits and the
    /// same gradient bits (the full trainer-level curve pin lives in
    /// rust/tests/train_native.rs).
    #[test]
    fn accum_and_workers_do_not_change_the_gradient() {
        let entry = native_model_entry("ho2_tiny").unwrap();
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(5));
        let mut gen = crate::data::make("copy", 7).unwrap();
        let batch = gen.batch(4, 12);
        let (l0, g0) = loss_and_grad_accum(&entry.config, &params, &batch, 1, 1).unwrap();
        for (accum, workers) in [(1, 2), (4, 1), (4, 0), (2, 8), (3, 3), (9, 0)] {
            let (l, g) =
                loss_and_grad_accum(&entry.config, &params, &batch, accum, workers).unwrap();
            assert_eq!(l.to_bits(), l0.to_bits(), "loss accum={accum} workers={workers}");
            for ((n_, a), b_) in g.names.iter().zip(&g.leaves).zip(&g0.leaves) {
                assert_eq!(
                    a.as_f32().unwrap(),
                    b_.as_f32().unwrap(),
                    "leaf {n_} accum={accum} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn loss_and_grad_shapes_and_finiteness() {
        let entry = native_model_entry("ho2_tiny").unwrap();
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(5));
        let mut gen = crate::data::make("copy", 7).unwrap();
        let batch = gen.batch(2, 16);
        let (loss, grads) = loss_and_grad(&entry.config, &params, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        assert_eq!(grads.len(), params.len());
        for (n_, (gt, pt)) in grads
            .names
            .iter()
            .zip(grads.leaves.iter().zip(&params.leaves))
        {
            assert_eq!(gt.shape, pt.shape, "{n_}");
            assert!(gt.as_f32().unwrap().iter().all(|x| x.is_finite()), "{n_}");
        }
        // something flowed everywhere: at least the embedding and every
        // matrix leaf have nonzero gradient
        for (n_, gt) in grads.names.iter().zip(&grads.leaves) {
            if gt.shape.len() == 2 {
                assert!(
                    gt.as_f32().unwrap().iter().any(|&x| x != 0.0),
                    "no gradient reached '{n_}'"
                );
            }
        }
    }
}
