//! Native model executor — the full transformer, served from pure Rust.
//!
//! PR 1 proved the O(n) attention kernels against the O(n²) oracles; this
//! subsystem turns them into **a model that serves**: an artifact-free
//! multi-layer transformer forward plus an O(1)-per-token decode object,
//! behind one execution trait the whole coordinator is written against.
//!
//! # The `Executor` trait
//!
//! [`Executor`] is the contract between models and the coordinator
//! (generation, the continuous-batching server, eval).  Its surface is
//! three execution calls plus slot management:
//!
//! * [`Executor::forward_logits`] — full-sequence `(B, T) → (B, T, V)`
//!   teacher-forced forward (prefill / eval).  On the native path this is
//!   [`NativeModel::forward`]: cache-blocked chunked attention, heads
//!   fanned out over the persistent [`WorkerPool`].
//! * [`Executor::decode_step`] — one token for every allocated slot,
//!   `(B,) → (B, V)`, advancing each slot's recurrent state in place.
//!   O(1) work and O(1) state per token per slot — the paper's serving
//!   claim.  The native impl runs active slots on the shared pool.
//! * [`Executor::state_bytes_per_slot`] — the size of one slot's decode
//!   state in bytes, constant in context length for ho2/linear (vs a
//!   KV cache that grows with `max_len` for the softmax baseline).
//! * slots — [`Executor::alloc_slot`] / [`Executor::release_slot`] /
//!   [`Executor::pos`]: continuous batching admits a request the moment a
//!   slot frees up, mid-flight of everyone else.
//! * preemption — [`Executor::snapshot_slot`] /
//!   [`Executor::restore_slot`] serialize one slot's state
//!   ([`SessionSnapshot`]) so a scheduler can evict and resume sequences
//!   (native backend only; probe with [`Executor::supports_snapshot`]).
//!   The [`crate::serve`] scheduler builds preemptive fair scheduling
//!   and the multi-turn session cache on exactly this surface.
//! * chunked prefill — [`Executor::absorb_slot`] folds a whole block of
//!   prompt tokens into one slot's state per call (bit-identical to the
//!   token loop), so a P-token prompt costs ⌈P/chunk⌉ engine steps
//!   instead of P (native backend only).
//!
//! Two implementations ship today: [`NativeExecutor`] (no artifacts, no
//! PJRT, no Python — `holt serve --backend native` runs anywhere the
//! crate compiles) and [`ArtifactExecutor`] (the original PJRT path,
//! behavior unchanged).  Future scaling PRs — batching policy, sharding,
//! quantized state — land as new impls or wrappers of this trait, not as
//! coordinator rewrites.
//!
//! # Model registry
//!
//! [`native_model_entry`] builds a [`crate::runtime::ModelEntry`] from a
//! manifest-style name (`ho2_small`, `linear_tiny`, `ho2_tiny_a1_o2`, …)
//! with the *same* parameter leaf order, shapes and init spec as the
//! python lowering — checkpoints are interchangeable between backends.
//!
//! # Consistency
//!
//! The non-attention ops ([`nn`]) use a fixed accumulation order so
//! prefill and decode differ only by the attention evaluation strategy
//! (chunked vs streaming — the same recurrence, reassociated);
//! `rust/tests/model_native.rs` pins full-model prefill ≡ decode logits
//! to ≤ 1e-4 across attention kinds, Taylor orders and shapes, and
//! snapshot → decode → restore → decode to bit-equality.

//! # Training
//!
//! [`grad`] closes the loop natively: a hand-derived backward through
//! the same chunked O(n) recurrence the forward runs (state gradients
//! across chunks, direct pairwise gradients inside — see the module
//! docs), gradient-checked against finite differences.  The
//! [`crate::coordinator::trainer::TrainBackend`] trait puts it behind
//! the same two-engine split as serving: `NativeTrainer` (this path)
//! and `ArtifactTrainer` (fused PJRT train step, unchanged).

pub mod decode;
pub mod executor;
pub mod forward;
pub mod grad;
pub mod nn;
pub mod pool;
pub mod presets;

pub use self::decode::{DecodeSession, SessionSnapshot};
pub use self::executor::{ArtifactExecutor, Executor, NativeExecutor, SKIP};
pub use self::forward::{LayerView, NativeModel};
pub use self::pool::WorkerPool;
pub use self::presets::{native_model_entry, ho_feature_dim, is_ho, ATTN_KINDS, PRESET_NAMES};
