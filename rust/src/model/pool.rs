//! Persistent worker pool behind every model-layer fan-out.
//!
//! The historic `fan_out` spawned a fresh `std::thread::scope` per call —
//! one thread spawn/join per prefill head batch, per decode batch, per
//! train-step vjp batch.  This module keeps one set of workers alive for
//! the process ([`WorkerPool::global`]) and hands them *batches*: a slice
//! of items and a `Fn(&mut T)` to run over each.
//!
//! # Design
//!
//! * **Caller participates.**  `fan_out` enqueues up to `workers` tickets
//!   for a batch and then drains the batch itself.  Item claiming is a
//!   single `fetch_add` on a shared cursor, so progress never depends on
//!   any worker picking the batch up — if the pool is busy (or has zero
//!   workers) the caller simply computes everything, which also makes
//!   nested `fan_out` calls from inside a worker deadlock-free by
//!   construction.
//! * **Determinism.**  Item `i` is processed by exactly one thread and
//!   each item's computation is independent of which thread claimed it,
//!   so outputs are identical for any worker count (pinned by tests
//!   here and in `rust/tests/simd_hotpath.rs`).
//! * **Lifetime safety without scopes.**  A batch shares borrowed data
//!   (`items`, `f`) with 'static worker threads via type-erased pointers
//!   in an `Arc<BatchCore>`.  The caller cannot return before every
//!   worker that *entered* the batch has left (`wait_idle`), and tickets
//!   that fire late find the cursor exhausted and touch nothing — they
//!   never dereference the borrowed pointers.  A drop guard runs the
//!   same wait on unwind, so a panicking `f` on the caller's thread
//!   still cannot free borrowed data out from under a worker.
//! * **Panics propagate.**  Worker-side panics are caught, flagged, and
//!   re-raised on the caller's thread after the batch quiesces —
//!   matching the scoped-thread behavior this replaces.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
}

/// A fixed set of persistent worker threads executing [`WorkerPool::fan_out`]
/// batches.  Construct test instances with [`WorkerPool::new`]; production
/// code uses the process-wide [`WorkerPool::global`].
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: usize,
}

/// Type-erased shared state of one fan-out batch.  `items`/`f` are
/// borrowed pointers smuggled as `usize`; validity is guaranteed by the
/// caller of `fan_out` not returning (or unwinding) past `wait_idle`,
/// and by `drain` never dereferencing them once the cursor is exhausted.
struct BatchCore {
    items: usize,
    f: usize,
    len: usize,
    /// Next unclaimed item — `fetch_add` claiming, so each item runs on
    /// exactly one thread.
    next: AtomicUsize,
    panicked: AtomicBool,
    /// Set once the batch is complete; late tickets exit immediately.
    expired: AtomicBool,
    /// Workers currently inside the batch; guarded by a mutex (not an
    /// atomic) so `wait_idle` cannot miss the last exit's notify.
    inside: Mutex<usize>,
    idle: Condvar,
    drain: unsafe fn(&BatchCore),
}

impl BatchCore {
    fn enter(&self) -> bool {
        if self.expired.load(Ordering::Acquire) {
            return false;
        }
        let mut g = self.inside.lock().unwrap();
        if self.expired.load(Ordering::Acquire) {
            return false;
        }
        *g += 1;
        true
    }

    fn exit(&self) {
        let mut g = self.inside.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.idle.notify_all();
        }
    }

    fn wait_idle(&self) {
        let mut g = self.inside.lock().unwrap();
        while *g > 0 {
            g = self.idle.wait(g).unwrap();
        }
    }
}

/// Claim-and-run loop over the batch, monomorphized per (T, F) and
/// reached through the `drain` fn pointer.
///
/// Safety: caller of `fan_out` guarantees `items`/`f` outlive the batch
/// (it blocks in `wait_idle` until every entered worker exits); the
/// pointers are only dereferenced for indices the cursor hands out,
/// which stop before `len`.
unsafe fn drain_batch<T: Send, F: Fn(&mut T) + Sync>(core: &BatchCore) {
    loop {
        let i = core.next.fetch_add(1, Ordering::Relaxed);
        if i >= core.len {
            return;
        }
        let f = &*(core.f as *const F);
        f(&mut *(core.items as *mut T).add(i));
    }
}

fn run_ticket(core: &BatchCore) {
    if !core.enter() {
        return;
    }
    let r = catch_unwind(AssertUnwindSafe(|| unsafe { (core.drain)(core) }));
    if r.is_err() {
        core.panicked.store(true, Ordering::Release);
    }
    core.exit();
}

/// Blocks the caller until the batch quiesces even if `f` panics on the
/// caller's own thread mid-drain.
struct CallerGuard<'a> {
    core: &'a BatchCore,
}

impl Drop for CallerGuard<'_> {
    fn drop(&mut self) {
        // make any unclaimed work invisible (workers that already
        // entered finish their claimed items), then wait them out
        self.core.next.fetch_add(self.core.len, Ordering::Relaxed);
        self.core.wait_idle();
        self.core.expired.store(true, Ordering::Release);
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        job();
    }
}

impl WorkerPool {
    /// Pool with exactly `workers` persistent threads (0 = every batch
    /// runs entirely on the calling thread).  Tests use this to compare
    /// outputs across thread counts; production code wants
    /// [`WorkerPool::global`].
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        });
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("holt-pool-{w}"))
                .spawn(move || worker_loop(sh))
                .expect("spawn pool worker");
        }
        WorkerPool { shared, workers }
    }

    /// The process-wide pool every model-layer fan-out shares:
    /// `available_parallelism − 1` workers (the calling thread is the
    /// +1), `HOLT_POOL_THREADS` overrides the total.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let total = std::env::var("HOLT_POOL_THREADS")
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
                });
            WorkerPool::new(total.saturating_sub(1))
        })
    }

    /// Worker threads in this pool (the caller adds one more at drain
    /// time).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every item, the caller's thread included.  Returns
    /// once every item has been processed and no worker still touches
    /// the borrowed data.  Panics (from any thread) propagate to the
    /// caller after the batch quiesces.
    pub fn fan_out<T: Send, F: Fn(&mut T) + Sync>(&self, items: &mut [T], f: F) {
        self.fan_out_capped(items, 0, f)
    }

    /// [`Self::fan_out`] with an upper bound on the threads that may
    /// touch this batch: at most `cap` total, the caller counted as one
    /// (`cap == 0` means uncapped, `cap == 1` runs entirely on the
    /// calling thread).  Item claiming and per-item work are unchanged,
    /// so outputs are bit-identical at every cap — the knob only bounds
    /// concurrency, which is what lets `--grad-workers N` mean "N
    /// gradient threads" without resizing the shared pool.
    pub fn fan_out_capped<T: Send, F: Fn(&mut T) + Sync>(&self, items: &mut [T], cap: usize, f: F) {
        let len = items.len();
        if len == 0 {
            return;
        }
        // the caller drains too, so more tickets than len−1 (or cap−1)
        // can never find work
        let tickets = match cap {
            0 => self.workers,
            c => self.workers.min(c - 1),
        }
        .min(len.saturating_sub(1));
        if tickets == 0 {
            for item in items.iter_mut() {
                f(item);
            }
            return;
        }
        let core = Arc::new(BatchCore {
            items: items.as_mut_ptr() as usize,
            f: &f as *const F as usize,
            len,
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            expired: AtomicBool::new(false),
            inside: Mutex::new(0),
            idle: Condvar::new(),
            drain: drain_batch::<T, F>,
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..tickets {
                let c = Arc::clone(&core);
                q.push_back(Box::new(move || run_ticket(&c)));
            }
        }
        self.shared.available.notify_all();
        {
            let guard = CallerGuard { core: &core };
            // caller-side drain: uncaught — but the guard's Drop still
            // quiesces the batch before the unwind can free items/f
            unsafe { (core.drain)(&core) };
            drop(guard);
        }
        if core.panicked.load(Ordering::Acquire) {
            panic!("worker panicked during fan_out batch");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools() -> Vec<WorkerPool> {
        vec![WorkerPool::new(0), WorkerPool::new(1), WorkerPool::new(2), WorkerPool::new(8)]
    }

    #[test]
    fn every_item_processed_exactly_once() {
        for pool in pools() {
            for len in [0usize, 1, 2, 7, 64, 501] {
                let mut items: Vec<usize> = vec![0; len];
                pool.fan_out(&mut items, |x| *x += 1);
                assert!(items.iter().all(|&x| x == 1), "workers={} len={len}", pool.workers());
            }
        }
    }

    #[test]
    fn outputs_are_independent_of_worker_count() {
        // per-item work is deterministic, so any thread schedule and any
        // worker count must produce bit-identical results
        let compute = |x: &mut f64| {
            let seed = *x;
            let mut acc = 0.0f64;
            for i in 0..2000 {
                acc += (seed + i as f64).sin() * 1e-3;
            }
            *x = acc;
        };
        let mut want: Vec<f64> = (0..257).map(|i| i as f64).collect();
        WorkerPool::new(0).fan_out(&mut want, compute);
        for pool in pools() {
            let mut got: Vec<f64> = (0..257).map(|i| i as f64).collect();
            pool.fan_out(&mut got, compute);
            assert_eq!(got, want, "workers={}", pool.workers());
        }
    }

    #[test]
    fn capped_fan_out_matches_uncapped_at_every_cap() {
        let pool = WorkerPool::new(4);
        let compute = |x: &mut f64| {
            let seed = *x;
            let mut acc = 0.0f64;
            for i in 0..500 {
                acc += (seed + i as f64).sin() * 1e-3;
            }
            *x = acc;
        };
        let mut want: Vec<f64> = (0..97).map(|i| i as f64).collect();
        pool.fan_out(&mut want, compute);
        for cap in [0usize, 1, 2, 3, 8, 100] {
            let mut got: Vec<f64> = (0..97).map(|i| i as f64).collect();
            pool.fan_out_capped(&mut got, cap, compute);
            assert_eq!(got, want, "cap={cap}");
        }
    }

    #[test]
    fn nested_fan_out_completes() {
        let pool = Arc::new(WorkerPool::new(3));
        let inner_pool = Arc::clone(&pool);
        let mut outer: Vec<Vec<u32>> = (0..6).map(|_| vec![0; 40]).collect();
        pool.fan_out(&mut outer, move |row| {
            inner_pool.fan_out(row, |x| *x += 1);
        });
        assert!(outer.iter().flatten().all(|&x| x == 1));
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let mut items: Vec<usize> = (0..64).collect();
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.fan_out(&mut items, |x| {
                if *x == 13 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the caller");
        // the pool keeps working after a poisoned batch
        let mut again: Vec<usize> = vec![0; 32];
        pool.fan_out(&mut again, |x| *x += 1);
        assert!(again.iter().all(|&x| x == 1));
    }

    #[test]
    fn global_pool_is_usable() {
        let mut items: Vec<usize> = vec![0; 100];
        WorkerPool::global().fan_out(&mut items, |x| *x += 7);
        assert!(items.iter().all(|&x| x == 7));
    }
}
