//! Dense f32 building blocks for the native transformer forward.
//!
//! Everything here is deliberately written with a **fixed accumulation
//! order** (ascending inner index, f32 accumulator): the chunked prefill
//! and the token-by-token decode run the *same* functions over the same
//! rows, so outside the attention kernels the two execution forms are
//! bit-identical — the prefill/decode cross-check in
//! `rust/tests/model_native.rs` only has to absorb the (tiny, f64)
//! reassociation inside the attention state itself.

use crate::mathref::layernorm_noaffine;

/// LayerNorm epsilon — matches `python/compile/kernels/ref.py` (shared
/// with the backward in `model::grad`).
pub(crate) const LN_EPS: f32 = 1e-5;

/// Row-major matmul: `x` (n, d) @ `w` (d, m) -> (n, m).
///
/// Loop order (row, inner, col) keeps `w` rows contiguous in cache and —
/// more importantly — gives every output element the same summation order
/// whether `n` is a full sequence (prefill) or 1 (decode).
pub fn matmul(x: &[f32], w: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    matmul_into(x, w, n, d, m, &mut out);
    out
}

/// [`matmul`] into a caller-owned buffer (overwritten, not accumulated).
/// The allocating form delegates here, so the two are bit-identical.
pub fn matmul_into(x: &[f32], w: &[f32], n: usize, d: usize, m: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * d, "matmul lhs shape");
    assert_eq!(w.len(), d * m, "matmul rhs shape");
    assert_eq!(out.len(), n * m, "matmul out shape");
    out.fill(0.0);
    for (xr, or) in x.chunks(d).zip(out.chunks_mut(m)) {
        for (&xi, wr) in xr.iter().zip(w.chunks(m)) {
            for (o, &wv) in or.iter_mut().zip(wr) {
                *o += xi * wv;
            }
        }
    }
}

/// Elementwise `x += y`.
pub fn add_inplace(x: &mut [f32], y: &[f32]) {
    assert_eq!(x.len(), y.len(), "add shape");
    for (a, &b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

/// Broadcast-add a (m,) bias onto every row of `x` (n, m).
pub fn add_bias(x: &mut [f32], n: usize, m: usize, bias: &[f32]) {
    assert_eq!(x.len(), n * m, "bias target shape");
    assert_eq!(bias.len(), m, "bias shape");
    for row in x.chunks_mut(m) {
        for (a, &b) in row.iter_mut().zip(bias) {
            *a += b;
        }
    }
}

/// tanh-approximated GELU, in place — the `jax.nn.gelu` default the
/// artifact models are lowered with.
pub fn gelu_inplace(x: &mut [f32]) {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    for v in x.iter_mut() {
        let t = *v;
        *v = 0.5 * t * (1.0 + (C * (t + 0.044715 * t * t * t)).tanh());
    }
}

/// Affine LayerNorm over rows of `x` (n, d): `LN(x) * g + b`, returned as
/// a new buffer (the residual stream stays untouched).
pub fn layernorm_affine(x: &[f32], n: usize, d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d];
    layernorm_affine_into(x, n, d, g, b, &mut out);
    out
}

/// [`layernorm_affine`] into a caller-owned buffer. The allocating form
/// delegates here, so the two are bit-identical.
pub fn layernorm_affine_into(x: &[f32], n: usize, d: usize, g: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(x.len(), n * d, "layernorm shape");
    assert_eq!(g.len(), d, "layernorm gain shape");
    assert_eq!(b.len(), d, "layernorm bias shape");
    assert_eq!(out.len(), n * d, "layernorm out shape");
    out.copy_from_slice(x);
    layernorm_noaffine(out, n, d, LN_EPS);
    for row in out.chunks_mut(d) {
        for ((v, &gc), &bc) in row.iter_mut().zip(g).zip(b) {
            *v = *v * gc + bc;
        }
    }
}

/// Tied LM head: `x` (n, d) @ `embed`ᵀ (d, v) -> logits (n, v), with
/// `embed` stored row-major (v, d) as in the parameter store.
pub fn tied_logits(x: &[f32], n: usize, d: usize, embed: &[f32], v: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * v];
    tied_logits_into(x, n, d, embed, v, &mut out);
    out
}

/// [`tied_logits`] into a caller-owned buffer. The allocating form
/// delegates here, so the two are bit-identical.
pub fn tied_logits_into(x: &[f32], n: usize, d: usize, embed: &[f32], v: usize, out: &mut [f32]) {
    assert_eq!(x.len(), n * d, "logits input shape");
    assert_eq!(embed.len(), v * d, "embedding shape");
    assert_eq!(out.len(), n * v, "logits out shape");
    for (xr, or) in x.chunks(d).zip(out.chunks_mut(v)) {
        for (o, er) in or.iter_mut().zip(embed.chunks(d)) {
            let mut acc = 0.0f32;
            for (xi, ei) in xr.iter().zip(er) {
                acc += xi * ei;
            }
            *o = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known_case() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let out = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_row_batching_is_bit_identical() {
        // computing rows one at a time (the decode path) must give bitwise
        // the same result as the batched call (the prefill path)
        let mut rng = crate::rng::Rng::new(9);
        let (n, d, m) = (5, 7, 6);
        let x = rng.normal_vec_f32(n * d, 1.0);
        let w = rng.normal_vec_f32(d * m, 1.0);
        let full = matmul(&x, &w, n, d, m);
        for r in 0..n {
            let row = matmul(&x[r * d..(r + 1) * d], &w, 1, d, m);
            assert_eq!(row, full[r * m..(r + 1) * m].to_vec(), "row {r}");
        }
    }

    #[test]
    fn gelu_anchor_values() {
        let mut x = vec![0.0f32, 1.0, -1.0, 3.0];
        gelu_inplace(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 0.841192).abs() < 1e-4, "{}", x[1]);
        assert!((x[2] + 0.158808).abs() < 1e-4, "{}", x[2]);
        assert!((x[3] - 2.9964).abs() < 1e-3, "{}", x[3]);
    }

    #[test]
    fn layernorm_affine_identity_gain() {
        let mut rng = crate::rng::Rng::new(1);
        let (n, d) = (3, 16);
        let x = rng.normal_vec_f32(n * d, 2.0);
        let g = vec![1.0f32; d];
        let b = vec![0.0f32; d];
        let out = layernorm_affine(&x, n, d, &g, &b);
        for row in out.chunks(d) {
            let mean: f32 = row.iter().sum::<f32>() / d as f32;
            let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
        // and the residual input is untouched (fresh buffer returned)
        assert_eq!(x.len(), n * d);
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_identically() {
        // the _into forms must fully overwrite whatever garbage the scratch
        // buffer held — this is what makes the decode scratch path safe
        let mut rng = crate::rng::Rng::new(11);
        let (n, d, m) = (3, 8, 5);
        let x = rng.normal_vec_f32(n * d, 1.0);
        let w = rng.normal_vec_f32(d * m, 1.0);
        let g = rng.normal_vec_f32(d, 1.0);
        let b = rng.normal_vec_f32(d, 1.0);
        let e = rng.normal_vec_f32(m * d, 1.0);

        let mut dirty = vec![f32::NAN; n * m];
        matmul_into(&x, &w, n, d, m, &mut dirty);
        assert_eq!(dirty, matmul(&x, &w, n, d, m));

        let mut dirty = vec![f32::NAN; n * d];
        layernorm_affine_into(&x, n, d, &g, &b, &mut dirty);
        assert_eq!(dirty, layernorm_affine(&x, n, d, &g, &b));

        let mut dirty = vec![f32::NAN; n * m];
        tied_logits_into(&x, n, d, &e, m, &mut dirty);
        assert_eq!(dirty, tied_logits(&x, n, d, &e, m));
    }

    #[test]
    fn tied_logits_matches_explicit_dot() {
        let mut rng = crate::rng::Rng::new(2);
        let (n, d, v) = (2, 4, 3);
        let x = rng.normal_vec_f32(n * d, 1.0);
        let e = rng.normal_vec_f32(v * d, 1.0);
        let out = tied_logits(&x, n, d, &e, v);
        for r in 0..n {
            for w in 0..v {
                let want: f32 = (0..d).map(|i| x[r * d + i] * e[w * d + i]).sum();
                assert!((out[r * v + w] - want).abs() < 1e-6);
            }
        }
    }
}
