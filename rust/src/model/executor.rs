//! The [`Executor`] trait — one execution surface for the coordinator,
//! two engines behind it.
//!
//! The coordinator (generation, continuous-batching server, eval) is
//! written against this trait only; *how* logits get computed is an
//! implementation detail:
//!
//! * [`NativeExecutor`] — pure-Rust [`NativeModel`] forward + per-slot
//!   [`DecodeSession`]s on the O(n) kernels.  Zero setup: no artifacts,
//!   no PJRT, no Python.  The decode batch loop fans active slots out
//!   over the persistent worker pool (each session is independent).
//! * [`ArtifactExecutor`] — the original PJRT path: AOT-lowered decode /
//!   fwd artifacts driven through [`Runtime`], state slots managed by
//!   [`StateManager`].  Behavior is unchanged from the pre-trait
//!   coordinator.
//!
//! Both executors are `Send`: the sharded serving tier moves each one
//! onto a dedicated engine thread (`serve/shard.rs`), and session
//! snapshots ([`SessionSnapshot`] — the live f64 kernel state encoded
//! into one of the [`StateDtype`](crate::state::StateDtype) wire
//! formats, f64 passthrough by default) ship between those threads when
//! the router migrates a session.  The compile-time assertions in this
//! file's tests keep that property from regressing.
//!
//! Future scaling work (batching policy, quantized state) lands as new
//! trait impls or wrappers, not coordinator rewrites.

use std::sync::Arc;

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::generation::{decode_step, CachedParams};
use crate::coordinator::state::StateManager;
use crate::kernels::RecurrentAttention;
use crate::model::decode::{DecodeSession, SessionSnapshot};
use crate::model::forward::{fan_out, NativeModel};
use crate::params::ParamStore;
use crate::runtime::{Executable, ModelEntry, Runtime, Tensor};

/// Feed sentinel for [`Executor::decode_step`]: an *allocated* slot whose
/// feed entry is negative sits the step out (state and position
/// untouched).  The serve engine uses this for slots that absorbed their
/// prompt through [`Executor::absorb_slot`] this step and are waiting to
/// sample.  Native-backend only — the lowered decode artifact always
/// steps every slot, so [`ArtifactExecutor`] rejects it.
pub const SKIP: i32 = -1;

/// A model execution engine with slot-based O(1)-state decoding.
///
/// Slots are the unit of continuous batching: every [`Executor::decode_step`]
/// consumes one token for *every allocated* slot (callers pad the feed
/// vector with `PAD` for free slots, or [`SKIP`] to leave an allocated
/// slot untouched on backends that support it) and advances their
/// positions.
pub trait Executor {
    /// The model being executed (config, specs, parameter counts).
    fn model(&self) -> &ModelEntry;

    /// `"native"` or `"artifact"` — for logs and bench records.
    fn backend_name(&self) -> &'static str;

    /// Whether this executor can decode (native softmax models and
    /// models lowered without a decode artifact cannot).
    fn supports_decode(&self) -> bool;

    /// Full-sequence forward: `tokens` (B, T) i32 → logits (B, T, V) f32.
    /// The prefill / eval form — no slot state involved.
    fn forward_logits(&self, tokens: &Tensor) -> Result<Tensor>;

    /// Fixed slot count of the decode batch.
    fn n_slots(&self) -> usize;

    fn free_slots(&self) -> usize;

    /// Claim a fresh slot (state zeroed, position 0), if any is free.
    fn alloc_slot(&mut self) -> Option<usize>;

    /// Return a slot to the pool.
    fn release_slot(&mut self, slot: usize);

    /// Tokens consumed so far by `slot` (0 for free slots).
    fn pos(&self, slot: usize) -> usize;

    /// One decode step over all slots: `feed[slot]` is the token for each
    /// allocated slot (free slots' entries are ignored).  Returns logits
    /// (B, V); rows of free slots are zero.  Advances every allocated
    /// slot's position.
    fn decode_step(&mut self, feed: &[i32]) -> Result<Tensor>;

    /// Decode-state footprint per slot in bytes — the paper's O(1) vs
    /// O(n) serving comparison in one number.
    fn state_bytes_per_slot(&self) -> usize;

    /// Whether [`Executor::absorb_slot`] works (chunked prefill).
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Whether [`Executor::snapshot_slot`] / [`Executor::restore_slot`]
    /// work — the gate for preemptive scheduling and the session cache.
    fn supports_snapshot(&self) -> bool {
        false
    }

    /// Absorb `tokens` (in order) into one slot's state in a single call
    /// and return the next-token logits after the last one — the chunked
    /// prefill hook.  Equivalent to feeding the tokens through
    /// [`Executor::decode_step`] one at a time (bit-identical on the
    /// native backend), minus the per-token logits of interior positions.
    fn absorb_slot(&mut self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let _ = (slot, tokens);
        bail!("multi-token absorb is only supported on the native backend")
    }

    /// Serialize a slot's decode state for preemption.  Only the native
    /// backend supports this today.
    fn snapshot_slot(&self, slot: usize) -> Result<SessionSnapshot> {
        let _ = slot;
        bail!("state snapshot is only supported on the native backend")
    }

    /// Restore a slot from a [`SessionSnapshot`].
    fn restore_slot(&mut self, slot: usize, snap: &SessionSnapshot) -> Result<()> {
        let _ = (slot, snap);
        bail!("state restore is only supported on the native backend")
    }

    /// Tag `slot` with the observability trace id of the request now
    /// occupying it, so backend-level state can be correlated with the
    /// serve layer's flight recorder.  Metadata only — must not affect
    /// any computation.  Backends without per-slot state ignore it.
    fn tag_slot(&mut self, slot: usize, trace: u64) {
        let _ = (slot, trace);
    }
}

// ---------------------------------------------------------------------------
// native
// ---------------------------------------------------------------------------

/// Pure-Rust executor: [`NativeModel`] + per-slot [`DecodeSession`]s.
pub struct NativeExecutor {
    model: NativeModel,
    sessions: Vec<Option<DecodeSession>>,
    /// per-slot state elements, probed once (0 ⇒ decode unsupported)
    state_elems: usize,
}

impl NativeExecutor {
    /// Build from a native [`ModelEntry`] (see
    /// [`crate::model::native_model_entry`]) and its parameters.
    pub fn new(entry: ModelEntry, params: ParamStore) -> Result<NativeExecutor> {
        let n_slots = entry.config.decode_batch.max(1);
        let model = NativeModel::new(entry, params)?;
        let state_elems = if model.config().attn == "softmax" {
            0 // exact attention has no recurrent state; forward-only
        } else {
            // all (layer, head) kernel states are identical — probe one
            let cfg = model.config();
            model.kernel_state()?.state_elements() * cfg.n_layers * cfg.n_heads
        };
        Ok(NativeExecutor {
            model,
            sessions: (0..n_slots).map(|_| None).collect(),
            state_elems,
        })
    }

    /// The underlying model (weights + forward).
    pub fn native_model(&self) -> &NativeModel {
        &self.model
    }
}

impl Executor for NativeExecutor {
    fn model(&self) -> &ModelEntry {
        self.model.entry()
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn supports_decode(&self) -> bool {
        self.state_elems > 0
    }

    fn forward_logits(&self, tokens: &Tensor) -> Result<Tensor> {
        ensure!(tokens.shape.len() == 2, "tokens must be (B, T), got {:?}", tokens.shape);
        let (b, t) = (tokens.shape[0], tokens.shape[1]);
        let logits = self.model.forward(tokens.as_i32()?, b, t)?;
        Ok(Tensor::f32(vec![b, t, self.model.config().vocab_size], logits))
    }

    fn n_slots(&self) -> usize {
        self.sessions.len()
    }

    fn free_slots(&self) -> usize {
        if !self.supports_decode() {
            return 0;
        }
        self.sessions.iter().filter(|s| s.is_none()).count()
    }

    fn alloc_slot(&mut self) -> Option<usize> {
        if !self.supports_decode() {
            return None;
        }
        let slot = self.sessions.iter().position(|s| s.is_none())?;
        // state shape was validated at construction; new() cannot fail here
        self.sessions[slot] = Some(DecodeSession::new(&self.model).ok()?);
        Some(slot)
    }

    fn release_slot(&mut self, slot: usize) {
        self.sessions[slot] = None;
    }

    fn tag_slot(&mut self, slot: usize, trace: u64) {
        if let Some(s) = self.sessions[slot].as_mut() {
            s.set_trace(trace);
        }
    }

    fn pos(&self, slot: usize) -> usize {
        self.sessions[slot].as_ref().map(|s| s.pos()).unwrap_or(0)
    }

    fn decode_step(&mut self, feed: &[i32]) -> Result<Tensor> {
        let b = self.sessions.len();
        ensure!(feed.len() == b, "feed length {} != slots {b}", feed.len());
        ensure!(self.supports_decode(), "model '{}' has no native decode", self.model().name);
        let v = self.model.config().vocab_size;
        let model = &self.model;
        let mut rows: Vec<Option<Result<Vec<f32>>>> = feed.iter().map(|_| None).collect();
        // the parallel batch loop: active (token, session, result) triples
        // (negative feed = SKIP: leave that slot's state untouched),
        // fanned out over the persistent worker pool — sessions are
        // disjoint &mut, the model is a shared &.
        let mut work: Vec<(i32, &mut DecodeSession, &mut Option<Result<Vec<f32>>>)> = self
            .sessions
            .iter_mut()
            .zip(rows.iter_mut())
            .enumerate()
            .filter(|(slot, _)| feed[*slot] >= 0)
            .filter_map(|(slot, (sess, row))| sess.as_mut().map(|s| (feed[slot], s, row)))
            .collect();
        // sub-128-dim models do so little per token that a thread spawn
        // per slot costs as much as the step itself — keep those serial
        if work.len() < 2 || self.model.config().d_model < 128 {
            for (tok, sess, row) in work.iter_mut() {
                **row = Some(sess.decode_step(model, *tok));
            }
        } else {
            fan_out(&mut work, |(tok, sess, row)| {
                **row = Some(sess.decode_step(model, *tok));
            });
        }
        let mut out = vec![0.0f32; b * v];
        for (slot, row) in rows.into_iter().enumerate() {
            if let Some(r) = row {
                out[slot * v..(slot + 1) * v].copy_from_slice(&r?);
            }
        }
        Ok(Tensor::f32(vec![b, v], out))
    }

    fn state_bytes_per_slot(&self) -> usize {
        self.state_elems * std::mem::size_of::<f64>()
    }

    fn supports_chunked_prefill(&self) -> bool {
        self.supports_decode()
    }

    fn supports_snapshot(&self) -> bool {
        self.supports_decode()
    }

    fn absorb_slot(&mut self, slot: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let model = &self.model;
        match self.sessions.get_mut(slot).and_then(|s| s.as_mut()) {
            Some(s) => s.absorb_chunk(model, tokens),
            None => bail!("slot {slot} is not active"),
        }
    }

    fn snapshot_slot(&self, slot: usize) -> Result<SessionSnapshot> {
        self.sessions
            .get(slot)
            .and_then(|s| s.as_ref())
            .map(|s| s.snapshot())
            .ok_or_else(|| anyhow!("slot {slot} is not active"))
    }

    fn restore_slot(&mut self, slot: usize, snap: &SessionSnapshot) -> Result<()> {
        match self.sessions.get_mut(slot).and_then(|s| s.as_mut()) {
            Some(s) => s.restore(snap),
            None => bail!("slot {slot} is not active"),
        }
    }
}

// ---------------------------------------------------------------------------
// artifact (PJRT)
// ---------------------------------------------------------------------------

/// PJRT executor over AOT-lowered artifacts — the pre-trait coordinator
/// behavior, unchanged: decode runs the `decode_*` artifact over all B
/// slots per step, state lives in a [`StateManager`].  Compiled
/// executables are `Arc`-shared with the [`Runtime`]'s cache, so the
/// executor does not borrow the runtime.
pub struct ArtifactExecutor {
    entry: ModelEntry,
    params: ParamStore,
    /// parameter literals for the decode hot path — built only when a
    /// decode artifact exists (forward-only eval skips the copy)
    cached: Option<CachedParams>,
    decode_exe: Option<Arc<Executable>>,
    fwd_exe: Option<Arc<Executable>>,
    sm: Option<StateManager>,
    active: Vec<bool>,
}

impl ArtifactExecutor {
    /// Loads whichever of the decode/fwd artifacts the model declares up
    /// front (the executor does not keep the runtime, so it cannot load
    /// lazily).  A declared artifact that fails to load only disables its
    /// path — decoding still works with a broken fwd artifact and vice
    /// versa, exactly as when the coordinator loaded per-path; the error
    /// surfaces (with the load failure already logged) when the disabled
    /// path is actually used.
    pub fn new(runtime: &Runtime, model_name: &str, params: ParamStore) -> Result<Self> {
        let entry = runtime.manifest.model(model_name)?.clone();
        params.check_spec(&entry.param_spec)?;
        let try_load = |kind: &str| match entry.artifacts.get(kind) {
            Some(name) => match runtime.load(name) {
                Ok(exe) => Some(exe),
                Err(err) => {
                    eprintln!("[executor] {kind} artifact '{name}' unavailable: {err:#}");
                    None
                }
            },
            None => None,
        };
        let decode_exe = try_load("decode");
        let fwd_exe = try_load("fwd");
        let cached = if decode_exe.is_some() {
            Some(CachedParams::new(&params)?)
        } else {
            None
        };
        let sm = if decode_exe.is_some() && !entry.state_spec.is_empty() {
            Some(StateManager::new(&entry.state_spec)?)
        } else {
            None
        };
        let n = sm.as_ref().map(|s| s.n_slots()).unwrap_or(0);
        let active = vec![false; n];
        Ok(ArtifactExecutor { entry, params, cached, decode_exe, fwd_exe, sm, active })
    }
}

impl Executor for ArtifactExecutor {
    fn model(&self) -> &ModelEntry {
        &self.entry
    }

    fn backend_name(&self) -> &'static str {
        "artifact"
    }

    fn supports_decode(&self) -> bool {
        self.decode_exe.is_some() && self.sm.is_some()
    }

    fn forward_logits(&self, tokens: &Tensor) -> Result<Tensor> {
        let fwd = self
            .fwd_exe
            .as_ref()
            .ok_or_else(|| anyhow!("model '{}' has no fwd artifact", self.entry.name))?;
        let mut inputs = self.params.leaves.clone();
        inputs.push(tokens.clone());
        Ok(fwd.run(&inputs)?.remove(0))
    }

    fn n_slots(&self) -> usize {
        self.active.len()
    }

    fn free_slots(&self) -> usize {
        self.sm.as_ref().map(|s| s.free_slots()).unwrap_or(0)
    }

    fn alloc_slot(&mut self) -> Option<usize> {
        let slot = self.sm.as_mut()?.alloc()?;
        self.active[slot] = true;
        Some(slot)
    }

    fn release_slot(&mut self, slot: usize) {
        if self.active[slot] {
            self.active[slot] = false;
            if let Some(sm) = self.sm.as_mut() {
                sm.release(slot);
            }
        }
    }

    fn pos(&self, slot: usize) -> usize {
        self.sm.as_ref().map(|s| s.pos[slot] as usize).unwrap_or(0)
    }

    fn decode_step(&mut self, feed: &[i32]) -> Result<Tensor> {
        // the lowered artifact steps every slot unconditionally — it has
        // no way to honor the SKIP sentinel the native engine uses
        for (slot, (is_active, tok)) in self.active.iter().zip(feed).enumerate() {
            ensure!(
                !*is_active || *tok >= 0,
                "artifact decode cannot skip active slot {slot} \
                 (chunked prefill / preemption are native-only)"
            );
        }
        let exe = self
            .decode_exe
            .as_ref()
            .ok_or_else(|| anyhow!("model '{}' has no decode artifact", self.entry.name))?;
        let cached = self
            .cached
            .as_ref()
            .ok_or_else(|| anyhow!("model '{}' has no cached decode params", self.entry.name))?;
        let sm = self
            .sm
            .as_mut()
            .ok_or_else(|| anyhow!("model '{}' has no decode state spec", self.entry.name))?;
        let logits = decode_step(exe, cached, sm, feed)?;
        for (slot, is_active) in self.active.iter().enumerate() {
            if *is_active {
                sm.advance(slot);
            }
        }
        Ok(logits)
    }

    fn state_bytes_per_slot(&self) -> usize {
        self.sm
            .as_ref()
            .map(|s| s.state_elements_per_slot() * std::mem::size_of::<f32>())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_send<T: Send>() {}

    /// The sharded serving tier pins one executor per engine thread and
    /// ships snapshots between threads during migration — all of which
    /// type-checks only while these stay `Send`.  (Compile-time test:
    /// it passes by building.)
    #[test]
    fn executors_and_snapshots_are_send() {
        is_send::<NativeExecutor>();
        is_send::<ArtifactExecutor>();
        is_send::<SessionSnapshot>();
        is_send::<Box<dyn Executor + Send>>();
    }
}
