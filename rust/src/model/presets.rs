//! Native model registry — [`ModelEntry`] construction **without a
//! manifest**, so the whole serving stack runs with no artifacts, no PJRT
//! and no Python.
//!
//! The presets, leaf order and shapes mirror `python/compile/configs.py`
//! and `python/compile/model.py::param_spec`/`state_spec` exactly: a
//! checkpoint trained through the artifact path loads into the native
//! executor (same names, same shapes, same order) and vice versa.
//!
//! Model names follow the manifest convention:
//!
//! ```text
//! {attn}_{preset}[_a{alpha}][_o{order}][_s{dtype}]
//! ```
//!
//! e.g. `ho2_small`, `linear_tiny`, `softmax_base`, `ho2_tiny_a1_o2`
//! (the E6 ablation grid), `ho_tiny_o3` (the order-3 run the paper never
//! did), `ho2_tiny_sf16` (f16 session snapshots by default).  `attn` ∈
//! {ho, ho2, linear, softmax} — `ho` is the Taylor kernel at any order
//! R ≥ 0 via the `_oR` suffix (default 2), `ho2` the historic spelling
//! kept as an alias (also `_oR`-overridable); `preset` ∈ {tiny, small,
//! base, large}; `_s{dtype}` with dtype ∈ {f64, f32, f16, bf16, int8}
//! sets the model's default [`StateDtype`] for *cached* session
//! snapshots (serve-time `--state-dtype` wins; the live compute state
//! stays f64 regardless).  For `ho` kinds the packed per-head feature
//! dim `Σ_{j≤R} C(d_head+j−1, j)` is validated here so an absurd order
//! fails with a number, not an allocation.

use anyhow::{bail, Result};

use crate::kernels::{taylor_feature_dim, MAX_TAYLOR_FEATURES};
use crate::runtime::{Init, LeafSpec, ModelConfig, ModelEntry};
use crate::state::StateDtype;
use crate::tokenizer::VOCAB_SIZE;

/// Preset names, in size order (mirror of python PRESETS).
pub const PRESET_NAMES: [&str; 4] = ["tiny", "small", "base", "large"];

/// Canonical attention kinds (what `holt info` lists); [`parse_name`]
/// additionally accepts the generalized `ho` spelling — see [`is_ho`].
pub const ATTN_KINDS: [&str; 3] = ["ho2", "linear", "softmax"];

/// Whether an attention-kind string is the Taylor (higher-order) family.
pub fn is_ho(attn: &str) -> bool {
    matches!(attn, "ho" | "ho2")
}

/// Base [`ModelConfig`] for a preset (attention defaults: ho2, order 2,
/// α = 3 — overridden by the name's suffixes) — mirror of configs.py:
/// (d_model, n_heads, n_layers, d_ff, max_len, train_batch, train_len,
/// decode_batch, vocab).
fn base_config(preset: &str) -> Option<ModelConfig> {
    let cfg = |d, h, l, ff, ctx, tb, tl, db, v| ModelConfig {
        preset: preset.to_string(),
        vocab_size: v,
        d_model: d,
        n_heads: h,
        n_layers: l,
        d_ff: ff,
        max_len: ctx,
        attn: "ho2".to_string(),
        order: 2,
        alpha: 3.0,
        impl_: "native".to_string(),
        train_batch: tb,
        train_len: tl,
        decode_batch: db,
        state_dtype: StateDtype::F64,
    };
    match preset {
        "tiny" => Some(cfg(64, 2, 2, 256, 128, 8, 64, 4, VOCAB_SIZE)),
        "small" => Some(cfg(256, 8, 4, 1024, 256, 16, 128, 8, VOCAB_SIZE)),
        "base" => Some(cfg(512, 16, 8, 2048, 512, 8, 256, 8, VOCAB_SIZE)),
        "large" => Some(cfg(768, 12, 12, 3072, 1024, 4, 512, 4, 32768)),
        _ => None,
    }
}

/// Feature dimension of the (unpacked) HO feature map for head dim `d`:
/// `Σ_{j≤order} dʲ` — mirror of python `ref.ho_feature_dim`, saturating
/// on overflow; used only for the informational `state_spec` (the native
/// kernels store the packed `Σ_{j≤order} C(d+j−1, j)` form — see
/// [`crate::kernels::taylor_feature_dim`]).
pub fn ho_feature_dim(d: usize, order: usize) -> usize {
    let mut total = 0usize;
    let mut block = 1usize;
    for j in 0..=order {
        if j > 0 {
            block = block.saturating_mul(d);
        }
        total = total.saturating_add(block);
    }
    total
}

/// Parse a manifest-style model name into a [`ModelConfig`].
fn parse_name(name: &str) -> Result<ModelConfig> {
    let mut parts = name.split('_');
    let attn = parts.next().unwrap_or_default();
    if !(ATTN_KINDS.contains(&attn) || attn == "ho") {
        bail!(
            "unknown model '{name}': want {{attn}}_{{preset}}[_a{{alpha}}][_o{{order}}] \
             with attn in {ATTN_KINDS:?} (or `ho` — any Taylor order via _oR) \
             and preset in {PRESET_NAMES:?}"
        );
    }
    let preset = parts.next().unwrap_or_default();
    let Some(mut cfg) = base_config(preset) else {
        bail!("unknown preset '{preset}' in model '{name}' (want one of {PRESET_NAMES:?})");
    };
    cfg.attn = attn.to_string();
    for part in parts {
        if let Some(a) = part.strip_prefix('a') {
            cfg.alpha = match a.parse() {
                Ok(x) if x > 0.0 => x,
                _ => bail!("bad alpha suffix '{part}' in model '{name}'"),
            };
        } else if let Some(o) = part.strip_prefix('o') {
            cfg.order = match o.parse() {
                Ok(x) => x,
                _ => bail!("bad order suffix '{part}' in model '{name}'"),
            };
        } else if let Some(s) = part.strip_prefix('s') {
            cfg.state_dtype = StateDtype::parse(s)
                .map_err(|e| e.context(format!("bad state-dtype suffix '{part}' in model '{name}'")))?;
        } else {
            bail!("unrecognized suffix '{part}' in model '{name}'");
        }
    }
    Ok(cfg)
}

/// Ordered parameter leaf spec — the exact mirror of python
/// `model.param_spec` (names, shapes, init kinds and order).  This order
/// is the checkpoint / train-artifact calling convention.
pub fn param_spec(cfg: &ModelConfig) -> Vec<LeafSpec> {
    let (d, v, ff) = (cfg.d_model, cfg.vocab_size, cfg.d_ff);
    let std = 0.02f32;
    // residual-branch output projections: GPT-2 depth-scaled init
    let std_res = std / (2.0 * cfg.n_layers as f32).sqrt();
    let mut spec = vec![
        LeafSpec { name: "embed".into(), shape: vec![v, d], init: Init::Normal { std } },
        LeafSpec { name: "pos".into(), shape: vec![cfg.max_len, d], init: Init::Normal { std } },
    ];
    let normal = |name: String, shape: Vec<usize>, std: f32| LeafSpec {
        name,
        shape,
        init: Init::Normal { std },
    };
    for i in 0..cfg.n_layers {
        let p = format!("blocks.{i}.");
        spec.push(LeafSpec { name: format!("{p}ln1_g"), shape: vec![d], init: Init::Ones });
        spec.push(LeafSpec { name: format!("{p}ln1_b"), shape: vec![d], init: Init::Zeros });
        spec.push(normal(format!("{p}wq"), vec![d, d], std));
        spec.push(normal(format!("{p}wk"), vec![d, d], std));
        spec.push(normal(format!("{p}wv"), vec![d, d], std));
        spec.push(normal(format!("{p}wo"), vec![d, d], std_res));
        spec.push(LeafSpec { name: format!("{p}ln2_g"), shape: vec![d], init: Init::Ones });
        spec.push(LeafSpec { name: format!("{p}ln2_b"), shape: vec![d], init: Init::Zeros });
        spec.push(normal(format!("{p}w1"), vec![d, ff], std));
        spec.push(LeafSpec { name: format!("{p}b1"), shape: vec![ff], init: Init::Zeros });
        spec.push(normal(format!("{p}w2"), vec![ff, d], std_res));
        spec.push(LeafSpec { name: format!("{p}b2"), shape: vec![d], init: Init::Zeros });
    }
    spec.push(LeafSpec { name: "lnf_g".into(), shape: vec![d], init: Init::Ones });
    spec.push(LeafSpec { name: "lnf_b".into(), shape: vec![d], init: Init::Zeros });
    spec
}

/// Ordered decode-state leaf spec — mirror of python `model.state_spec`.
/// Informational for the native path (the [`crate::model::DecodeSession`]
/// keeps its own packed state); the artifact path's `StateManager` owns
/// tensors of exactly these shapes.
pub fn state_spec(cfg: &ModelConfig) -> Vec<LeafSpec> {
    let (b, h) = (cfg.decode_batch, cfg.n_heads);
    let dh = cfg.d_model / cfg.n_heads;
    let mut spec = Vec::new();
    for i in 0..cfg.n_layers {
        if cfg.attn == "softmax" {
            spec.push(LeafSpec {
                name: format!("layer{i}.kcache"),
                shape: vec![b, h, cfg.max_len, dh],
                init: Init::Zeros,
            });
            spec.push(LeafSpec {
                name: format!("layer{i}.vcache"),
                shape: vec![b, h, cfg.max_len, dh],
                init: Init::Zeros,
            });
        } else {
            let f = if is_ho(&cfg.attn) { ho_feature_dim(dh, cfg.order) } else { dh };
            spec.push(LeafSpec {
                name: format!("layer{i}.S"),
                shape: vec![b, h, f, dh],
                init: Init::Zeros,
            });
            spec.push(LeafSpec {
                name: format!("layer{i}.z"),
                shape: vec![b, h, f],
                init: Init::Zeros,
            });
        }
    }
    spec
}

/// Build a complete, manifest-free [`ModelEntry`] for a model name.
pub fn native_model_entry(name: &str) -> Result<ModelEntry> {
    let config = parse_name(name)?;
    if config.d_model % config.n_heads != 0 {
        bail!("d_model {} not divisible by n_heads {}", config.d_model, config.n_heads);
    }
    if is_ho(&config.attn) {
        // fail an absurd Taylor order here, with the computed feature
        // dim, instead of panicking later in TaylorMap construction
        let dh = config.d_model / config.n_heads;
        match taylor_feature_dim(dh, config.order) {
            Some(f) if f <= MAX_TAYLOR_FEATURES => {}
            computed => bail!(
                "model '{name}': Taylor order {} at head dim {dh} needs {} packed \
                 features per head (Σ_j C(d+j−1, j)); the cap is {MAX_TAYLOR_FEATURES}",
                config.order,
                computed.map_or("> usize::MAX".to_string(), |f| f.to_string()),
            ),
        }
    }
    let param_spec = param_spec(&config);
    let state_spec = state_spec(&config);
    let n_params = param_spec
        .iter()
        .map(|l| l.shape.iter().product::<usize>())
        .sum();
    Ok(ModelEntry {
        name: name.to_string(),
        config,
        n_params,
        param_spec,
        state_spec,
        artifacts: std::collections::HashMap::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_style_names() {
        let e = native_model_entry("ho2_small").unwrap();
        assert_eq!(e.config.d_model, 256);
        assert_eq!(e.config.attn, "ho2");
        assert_eq!(e.config.order, 2);
        assert!((e.config.alpha - 3.0).abs() < 1e-12);

        let e = native_model_entry("ho2_tiny_a1_o1").unwrap();
        assert_eq!(e.config.preset, "tiny");
        assert!((e.config.alpha - 1.0).abs() < 1e-12);
        assert_eq!(e.config.order, 1);

        assert!(native_model_entry("ho3_small").is_err());
        assert!(native_model_entry("ho2_giant").is_err());
        assert!(native_model_entry("ho2_tiny_x9").is_err());
    }

    #[test]
    fn ho_grammar_unlocks_any_order() {
        // `ho[_oR]`: R is a config value now, and `ho2` stays an alias
        let e = native_model_entry("ho_tiny_o3").unwrap();
        assert_eq!(e.config.attn, "ho");
        assert_eq!(e.config.order, 3);
        assert_eq!(e.config.d_model, 64);
        // bare `ho` keeps the paper's order-2 default
        let e = native_model_entry("ho_small").unwrap();
        assert_eq!(e.config.order, 2);
        // the alias also takes the suffix: ho2_tiny_o3 == ho_tiny_o3
        // modulo the attn spelling (both drive the same TaylorMap)
        let e = native_model_entry("ho2_tiny_o3").unwrap();
        assert_eq!(e.config.order, 3);
        // an absurd order fails with the computed feature dim, not a
        // panic or an allocation
        let err = native_model_entry("ho_tiny_o40").unwrap_err().to_string();
        assert!(err.contains("packed"), "{err}");
        assert!(native_model_entry("ho_tiny_ox").is_err());
    }

    #[test]
    fn state_dtype_suffix_sets_snapshot_default() {
        use crate::state::StateDtype;
        // bare names keep the lossless default — every existing
        // bit-exactness pin depends on it
        let e = native_model_entry("ho2_tiny").unwrap();
        assert_eq!(e.config.state_dtype, StateDtype::F64);
        // `_s{dtype}` composes with the other suffixes in any position
        let e = native_model_entry("ho2_tiny_sf16").unwrap();
        assert_eq!(e.config.state_dtype, StateDtype::F16);
        let e = native_model_entry("ho_tiny_o3_sint8").unwrap();
        assert_eq!(e.config.order, 3);
        assert_eq!(e.config.state_dtype, StateDtype::Int8);
        let e = native_model_entry("ho2_tiny_sbf16_a1").unwrap();
        assert_eq!(e.config.state_dtype, StateDtype::Bf16);
        assert!((e.config.alpha - 1.0).abs() < 1e-12);
        // the dtype never changes shapes/params — same model otherwise
        let base = native_model_entry("ho2_tiny").unwrap();
        let f16 = native_model_entry("ho2_tiny_sf16").unwrap();
        assert_eq!(base.n_params, f16.n_params);
        // unknown dtypes fail with the spelling list, not a panic
        let err = native_model_entry("ho2_tiny_sq4").unwrap_err().to_string();
        assert!(err.contains("state-dtype"), "{err}");
    }

    #[test]
    fn n_params_matches_closed_form() {
        // mirror of configs.py ModelConfig.n_params()
        for name in ["ho2_tiny", "linear_small", "softmax_base"] {
            let e = native_model_entry(name).unwrap();
            let c = &e.config;
            let (d, v, l, f) = (c.d_model, c.vocab_size, c.n_layers, c.d_ff);
            let per_block = 4 * d * d + 2 * d * f + f + d + 4 * d;
            let want = v * d + c.max_len * d + l * per_block + 2 * d;
            assert_eq!(e.n_params, want, "{name}");
            assert_eq!(e.param_elements(), e.n_params, "{name}");
        }
    }

    #[test]
    fn leaf_order_is_the_python_contract() {
        let e = native_model_entry("ho2_tiny").unwrap();
        let names: Vec<&str> = e.param_spec.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names[0], "embed");
        assert_eq!(names[1], "pos");
        assert_eq!(names[2], "blocks.0.ln1_g");
        assert_eq!(names[13], "blocks.0.b2");
        assert_eq!(names[14], "blocks.1.ln1_g");
        assert_eq!(*names.last().unwrap(), "lnf_b");
        assert_eq!(names.len(), 2 + 12 * 2 + 2);
    }

    #[test]
    fn state_spec_shapes_per_attention_kind() {
        let e = native_model_entry("ho2_tiny").unwrap();
        let dh = 64 / 2;
        let f = ho_feature_dim(dh, 2);
        assert_eq!(e.state_spec[0].shape, vec![4, 2, f, dh]);
        assert_eq!(e.state_spec[1].shape, vec![4, 2, f]);

        let e = native_model_entry("softmax_tiny").unwrap();
        assert_eq!(e.state_spec[0].shape, vec![4, 2, 128, dh]);

        let e = native_model_entry("linear_tiny").unwrap();
        assert_eq!(e.state_spec[0].shape, vec![4, 2, dh, dh]);
    }
}
