//! `DecodeSession` — one sequence's O(1)-per-token decode state over a
//! [`NativeModel`].
//!
//! The linear transformer *is* an RNN (Katharopoulos et al. 2020, lifted
//! to order 2 by the source paper): a decoding sequence needs only one
//! boxed kernel state per (layer, head) — **constant in generated
//! length** — instead of a growing KV cache.  `snapshot`/`restore`
//! serialize that state so a serving coordinator can preempt a slot and
//! resume it later (or migrate it) without replaying the prefix.

use anyhow::{bail, ensure, Result};

use crate::kernels::RecurrentAttention;
use crate::model::forward::{block_finish, block_qkv, NativeModel};
use crate::model::nn;

/// Per-sequence decode state: `n_layers · n_heads` kernel states + the
/// next position.  Create with [`DecodeSession::new`], drive with
/// [`DecodeSession::decode_step`].
pub struct DecodeSession {
    /// layer-major: `states[layer * n_heads + head]`
    states: Vec<Box<dyn RecurrentAttention + Send>>,
    n_heads: usize,
    pos: usize,
}

/// A serialized [`DecodeSession`] state (slot preemption / migration).
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pos: usize,
    state: Vec<f64>,
}

impl SessionSnapshot {
    /// Position the snapshot resumes from.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Serialized size in bytes (f64 state + position).
    pub fn bytes(&self) -> usize {
        self.state.len() * std::mem::size_of::<f64>() + std::mem::size_of::<usize>()
    }
}

impl DecodeSession {
    /// Fresh session at position 0.  Errors for `"softmax"` models —
    /// exact attention has no constant-size recurrent state (serve those
    /// through the artifact backend's KV cache).
    pub fn new(model: &NativeModel) -> Result<DecodeSession> {
        let cfg = model.config();
        let n = cfg.n_layers * cfg.n_heads;
        let mut states = Vec::with_capacity(n);
        for _ in 0..n {
            states.push(model.kernel_state()?);
        }
        Ok(DecodeSession { states, n_heads: cfg.n_heads, pos: 0 })
    }

    /// Next position to be consumed (= tokens absorbed so far).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Total f64 state elements across all (layer, head) kernels —
    /// constant in generated length, the O(1)-decode claim in one number.
    pub fn state_elements(&self) -> usize {
        self.states.iter().map(|s| s.state_elements()).sum()
    }

    /// Decode-state footprint in bytes.
    pub fn state_bytes(&self) -> usize {
        self.state_elements() * std::mem::size_of::<f64>()
    }

    /// Serialize the full session state.
    pub fn snapshot(&self) -> SessionSnapshot {
        let mut state = Vec::with_capacity(self.state_elements());
        for s in &self.states {
            s.save_state(&mut state);
        }
        SessionSnapshot { pos: self.pos, state }
    }

    /// Restore a snapshot taken from a session of the same model shape.
    pub fn restore(&mut self, snap: &SessionSnapshot) -> Result<()> {
        ensure!(
            snap.state.len() == self.state_elements(),
            "snapshot has {} state elements, session expects {} \
             (snapshot from a different model?)",
            snap.state.len(),
            self.state_elements()
        );
        let mut off = 0;
        for s in &mut self.states {
            let n = s.state_elements();
            s.load_state(&snap.state[off..off + n]);
            off += n;
        }
        self.pos = snap.pos;
        Ok(())
    }

    /// Absorb one token, return next-token logits (vocab,).  Exactly
    /// column `pos` of [`NativeModel::forward`] run on the same prefix
    /// (pinned ≤ 1e-4 in rust/tests/model_native.rs).
    pub fn decode_step(&mut self, model: &NativeModel, token: i32) -> Result<Vec<f32>> {
        let cfg = model.config();
        let (d, v, nh, ff) = (cfg.d_model, cfg.vocab_size, cfg.n_heads, cfg.d_ff);
        let dh = d / nh;
        ensure!(nh == self.n_heads, "session/model head mismatch");
        ensure!((0..v as i32).contains(&token), "token {token} out of vocab {v}");
        if self.pos >= cfg.max_len {
            bail!("context exhausted: position {} at max_len {}", self.pos, cfg.max_len);
        }

        let embed = model.embed();
        let e = &embed[token as usize * d..(token as usize + 1) * d];
        let p = &model.pos_embed()[self.pos * d..(self.pos + 1) * d];
        let mut x: Vec<f32> = e.iter().zip(p).map(|(&ev, &pv)| ev + pv).collect();

        let mut a = vec![0.0f32; d];
        for li in 0..cfg.n_layers {
            let lw = model.layer(li);
            // same pre/post-attention halves as NativeModel::forward — only
            // the attention evaluation differs (stateful step vs chunked)
            let (q, k, vv) = block_qkv(&lw, &x, 1, d);
            for hd in 0..nh {
                let st = &mut self.states[li * nh + hd];
                st.step(
                    &q[hd * dh..(hd + 1) * dh],
                    &k[hd * dh..(hd + 1) * dh],
                    &vv[hd * dh..(hd + 1) * dh],
                    &mut a[hd * dh..(hd + 1) * dh],
                );
            }
            block_finish(&lw, &mut x, &a, 1, d, ff);
        }

        let xf = nn::layernorm_affine(&x, 1, d, model.lnf_g(), model.lnf_b());
        self.pos += 1;
        Ok(nn::tied_logits(&xf, 1, d, embed, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::native_model_entry;
    use crate::params::ParamStore;
    use crate::rng::Rng;

    fn model(name: &str) -> NativeModel {
        let entry = native_model_entry(name).unwrap();
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(5));
        NativeModel::new(entry, params).unwrap()
    }

    #[test]
    fn softmax_has_no_decode_session() {
        assert!(DecodeSession::new(&model("softmax_tiny")).is_err());
    }

    #[test]
    fn context_exhaustion_is_an_error() {
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        for i in 0..m.config().max_len {
            s.decode_step(&m, (i % 256) as i32).unwrap();
        }
        assert!(s.decode_step(&m, 0).is_err());
    }

    #[test]
    fn snapshot_reports_size() {
        let m = model("ho2_tiny");
        let s = DecodeSession::new(&m).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.pos(), 0);
        assert!(snap.bytes() >= s.state_bytes());
    }

    #[test]
    fn restore_rejects_wrong_shape() {
        let m2 = model("ho2_tiny");
        let m1 = model("ho2_tiny_a3_o1"); // smaller per-head state
        let mut s2 = DecodeSession::new(&m2).unwrap();
        let s1 = DecodeSession::new(&m1).unwrap();
        assert!(s2.restore(&s1.snapshot()).is_err());
    }
}
