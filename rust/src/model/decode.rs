//! `DecodeSession` — one sequence's O(1)-per-token decode state over a
//! [`NativeModel`].
//!
//! The linear transformer *is* an RNN (Katharopoulos et al. 2020, lifted
//! to order 2 by the source paper): a decoding sequence needs only one
//! boxed kernel state per (layer, head) — **constant in generated
//! length** — instead of a growing KV cache.  `snapshot`/`restore`
//! serialize that state so a serving coordinator can preempt a slot and
//! resume it later (or migrate it) without replaying the prefix.

use anyhow::{bail, ensure, Result};

use crate::kernels::RecurrentAttention;
use crate::model::forward::{
    block_finish_into, block_qkv_into, fan_out, gather_head, scatter_head, NativeModel,
};
use crate::model::nn;
use crate::state::{SnapshotCodec, StateDtype};

/// Per-sequence decode state: `n_layers · n_heads` kernel states + the
/// next position.  Create with [`DecodeSession::new`], drive with
/// [`DecodeSession::decode_step`].
pub struct DecodeSession {
    /// layer-major: `states[layer * n_heads + head]`
    states: Vec<Box<dyn RecurrentAttention + Send>>,
    n_heads: usize,
    pos: usize,
    scratch: DecodeScratch,
    /// observability trace id of the request currently occupying this
    /// session (0 = untraced); plain metadata, never serialized into
    /// snapshots — the trace follows the request, not the slot
    trace: u64,
}

/// Reusable dense activation buffers for [`DecodeSession::absorb_chunk`].
/// Grown on demand, never shrunk, never serialized (snapshots carry only
/// kernel state): after the first chunk of a given size the whole-model
/// decode path touches the heap zero times — the model-level half of the
/// zero-alloc claim, pinned in `rust/tests/alloc_decode.rs`.  Every
/// buffer is fully overwritten by its `_into` producer before being
/// read, so dirty reuse across calls is safe.
#[derive(Debug, Default)]
struct DecodeScratch {
    /// residual stream (n, d)
    x: Vec<f32>,
    /// LayerNorm output, reused by both block halves (n, d)
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// attention output (n, d)
    a: Vec<f32>,
    /// attention projection (n, d)
    ao: Vec<f32>,
    /// FFN hidden (n, ff)
    f: Vec<f32>,
    /// FFN output (n, d)
    g: Vec<f32>,
    /// final-LayerNorm row (d)
    xf: Vec<f32>,
}

impl DecodeScratch {
    fn ensure(&mut self, n: usize, d: usize, ff: usize) {
        let nd = n * d;
        for buf in [
            &mut self.x,
            &mut self.h,
            &mut self.q,
            &mut self.k,
            &mut self.v,
            &mut self.a,
            &mut self.ao,
            &mut self.g,
        ] {
            if buf.len() < nd {
                buf.resize(nd, 0.0);
            }
        }
        if self.f.len() < n * ff {
            self.f.resize(n * ff, 0.0);
        }
        if self.xf.len() < d {
            self.xf.resize(d, 0.0);
        }
    }
}

/// A serialized [`DecodeSession`] state (slot preemption / migration /
/// the serve session cache).  The state rides as *encoded bytes* in one
/// of the [`StateDtype`] wire formats (f64 passthrough by default —
/// bit-lossless, today's park format byte for byte); restore always
/// rehydrates the full-precision f64 live state.  `Default` is the
/// empty snapshot (position 0, no state) — a placeholder, restorable
/// only into a 0-state session.
///
/// Equality compares encoded bytes, which for the f64 dtype is *bit*
/// equality of the state — stricter than the old `Vec<f64>` compare
/// (and exactly what the chunked-vs-streaming pins claim).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SessionSnapshot {
    pos: usize,
    /// Decoded (f64) element count — the shape check on restore.
    n_elems: usize,
    dtype: StateDtype,
    encoded: Vec<u8>,
}

impl SessionSnapshot {
    /// Encode a raw state vector (the layout `save_state` produces) at
    /// position `pos` into `dtype`'s wire format.
    pub fn encode(pos: usize, state: &[f64], dtype: StateDtype) -> SessionSnapshot {
        SessionSnapshot {
            pos,
            n_elems: state.len(),
            dtype,
            encoded: SnapshotCodec::new(dtype).encode(state),
        }
    }

    /// Position the snapshot resumes from.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Wire dtype the state is encoded in.
    pub fn dtype(&self) -> StateDtype {
        self.dtype
    }

    /// Decoded f64 element count.
    pub fn state_elements(&self) -> usize {
        self.n_elems
    }

    /// Resident size in bytes (encoded payload + struct header) — the
    /// unit the byte-budgeted session cache accounts in.
    pub fn bytes(&self) -> usize {
        self.encoded.len() + std::mem::size_of::<SessionSnapshot>()
    }

    /// Rehydrate the full-precision state vector.  (Infallible by
    /// construction — the payload length invariantly matches `n_elems`;
    /// the fields are private so no external code can break that.)
    pub fn decode_state(&self) -> Vec<f64> {
        SnapshotCodec::new(self.dtype)
            .decode(&self.encoded, self.n_elems)
            .expect("snapshot payload length is maintained by construction")
    }

    /// Re-encode into another dtype.  Same-dtype transcodes are a plain
    /// clone (no decode/encode round-trip); every codec is idempotent,
    /// so a lossy snapshot transcoded onward degrades no further than
    /// its first encode did.
    pub fn transcode(&self, dtype: StateDtype) -> SessionSnapshot {
        if dtype == self.dtype {
            return self.clone();
        }
        SessionSnapshot::encode(self.pos, &self.decode_state(), dtype)
    }
}

impl DecodeSession {
    /// Fresh session at position 0.  Errors for `"softmax"` models —
    /// exact attention has no constant-size recurrent state (serve those
    /// through the artifact backend's KV cache).
    pub fn new(model: &NativeModel) -> Result<DecodeSession> {
        let cfg = model.config();
        let n = cfg.n_layers * cfg.n_heads;
        let mut states = Vec::with_capacity(n);
        for _ in 0..n {
            states.push(model.kernel_state()?);
        }
        Ok(DecodeSession {
            states,
            n_heads: cfg.n_heads,
            pos: 0,
            scratch: DecodeScratch::default(),
            trace: 0,
        })
    }

    /// Next position to be consumed (= tokens absorbed so far).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Tag this session with the occupying request's trace id
    /// ([`Executor::tag_slot`](crate::model::Executor::tag_slot)).
    pub fn set_trace(&mut self, trace: u64) {
        self.trace = trace;
    }

    /// Trace id of the occupying request (0 = untraced).
    pub fn trace(&self) -> u64 {
        self.trace
    }

    /// Total f64 state elements across all (layer, head) kernels —
    /// constant in generated length, the O(1)-decode claim in one number.
    pub fn state_elements(&self) -> usize {
        self.states.iter().map(|s| s.state_elements()).sum()
    }

    /// Decode-state footprint in bytes.
    pub fn state_bytes(&self) -> usize {
        self.state_elements() * std::mem::size_of::<f64>()
    }

    /// Serialize the full session state — f64 passthrough (bit-lossless;
    /// the preemption park path depends on that).
    pub fn snapshot(&self) -> SessionSnapshot {
        self.snapshot_as(StateDtype::F64)
    }

    /// Serialize the full session state into `dtype`'s wire format in
    /// one pass (no intermediate f64 snapshot to transcode).
    pub fn snapshot_as(&self, dtype: StateDtype) -> SessionSnapshot {
        let mut state = Vec::with_capacity(self.state_elements());
        for s in &self.states {
            s.save_state(&mut state);
        }
        SessionSnapshot::encode(self.pos, &state, dtype)
    }

    /// Restore a snapshot taken from a session of the same model shape,
    /// rehydrating the f64 live state whatever the snapshot's dtype.
    pub fn restore(&mut self, snap: &SessionSnapshot) -> Result<()> {
        ensure!(
            snap.state_elements() == self.state_elements(),
            "snapshot has {} state elements, session expects {} \
             (snapshot from a different model?)",
            snap.state_elements(),
            self.state_elements()
        );
        let state = snap.decode_state();
        let mut off = 0;
        for s in &mut self.states {
            let n = s.state_elements();
            s.load_state(&state[off..off + n]);
            off += n;
        }
        self.pos = snap.pos;
        Ok(())
    }

    /// Absorb one token, return next-token logits (vocab,).  Exactly
    /// column `pos` of [`NativeModel::forward`] run on the same prefix
    /// (pinned ≤ 1e-4 in rust/tests/model_native.rs).  This is the n = 1
    /// case of [`DecodeSession::absorb_chunk`] — one transcription of
    /// the per-token math, not two that could drift apart.
    pub fn decode_step(&mut self, model: &NativeModel, token: i32) -> Result<Vec<f32>> {
        self.absorb_chunk(model, &[token])
    }

    /// [`DecodeSession::decode_step`] into a caller-owned logits buffer
    /// (`out` has length `vocab`) — together with the internal scratch
    /// this makes the per-token path allocation-free after warm-up.
    pub fn decode_step_into(
        &mut self,
        model: &NativeModel,
        token: i32,
        out: &mut [f32],
    ) -> Result<()> {
        self.absorb_chunk_into(model, &[token], out)
    }

    /// Absorb `tokens` in order and return the next-token logits at the
    /// final absorbed position — the chunked-prefill primitive.
    ///
    /// Bit-identical to calling [`DecodeSession::decode_step`] once per
    /// token (pinned in `rust/tests/serve_sched.rs`): the block runs the
    /// same per-row `block_qkv`/`step`/`block_finish` ops in the same
    /// order, only batched — so interior positions skip the final
    /// LayerNorm + tied-logits matmul their logits would have wasted,
    /// and the dense halves run over `n` rows at once instead of one.
    pub fn absorb_chunk(&mut self, model: &NativeModel, tokens: &[i32]) -> Result<Vec<f32>> {
        let v = model.config().vocab_size;
        let mut out = vec![0.0f32; v];
        self.absorb_chunk_into(model, tokens, &mut out)?;
        Ok(out)
    }

    /// [`DecodeSession::absorb_chunk`] into a caller-owned logits buffer.
    /// All dense activations come from the session's [`DecodeScratch`],
    /// so a warmed-up session allocates nothing here for `n = 1` (the
    /// decode hot path; multi-token chunks still allocate per-head
    /// gather buffers on multi-head fan-out).
    pub fn absorb_chunk_into(
        &mut self,
        model: &NativeModel,
        tokens: &[i32],
        out: &mut [f32],
    ) -> Result<()> {
        let cfg = model.config();
        let (d, v, nh, ff) = (cfg.d_model, cfg.vocab_size, cfg.n_heads, cfg.d_ff);
        let dh = d / nh;
        let n = tokens.len();
        ensure!(n > 0, "empty prefill chunk");
        ensure!(nh == self.n_heads, "session/model head mismatch");
        ensure!(out.len() == v, "logits out buffer has wrong length");
        if self.pos + n > cfg.max_len {
            bail!(
                "context exhausted: position {} + {n} tokens at max_len {}",
                self.pos,
                cfg.max_len
            );
        }

        self.scratch.ensure(n, d, ff);
        // disjoint field borrows: kernel states and activation scratch
        let Self { states: all_states, scratch, pos, .. } = self;
        let DecodeScratch { x, h, q, k, v: vv, a, ao, f, g, xf } = scratch;
        let (x, h) = (&mut x[..n * d], &mut h[..n * d]);
        let (q, k, vv) = (&mut q[..n * d], &mut k[..n * d], &mut vv[..n * d]);
        let (a, ao) = (&mut a[..n * d], &mut ao[..n * d]);
        let (f, g, xf) = (&mut f[..n * ff], &mut g[..n * d], &mut xf[..d]);

        let embed = model.embed();
        let pose = model.pos_embed();
        for (i, &t) in tokens.iter().enumerate() {
            ensure!((0..v as i32).contains(&t), "token {t} out of vocab {v}");
            let e = &embed[t as usize * d..(t as usize + 1) * d];
            let p = &pose[(*pos + i) * d..(*pos + i + 1) * d];
            for (o, (&ev, &pv)) in x[i * d..(i + 1) * d].iter_mut().zip(e.iter().zip(p)) {
                *o = ev + pv;
            }
        }

        for li in 0..cfg.n_layers {
            let lw = model.layer(li);
            block_qkv_into(&lw, x, n, d, h, q, k, vv);
            let states = &mut all_states[li * nh..(li + 1) * nh];
            if n == 1 {
                // the per-token decode hot path: head slices are already
                // contiguous in the single row — no gather/scatter, no
                // per-head buffers (kernels overwrite their out slice, so
                // the dirty scratch is safe)
                for (hd, st) in states.iter_mut().enumerate() {
                    st.step(
                        &q[hd * dh..(hd + 1) * dh],
                        &k[hd * dh..(hd + 1) * dh],
                        &vv[hd * dh..(hd + 1) * dh],
                        &mut a[hd * dh..(hd + 1) * dh],
                    );
                }
            } else {
                // stream the block through each head's state: heads are
                // independent, so they fan out like the prefill head loop
                // (serial below the same size threshold as the decode
                // batch)
                let mut work: Vec<(usize, &mut Box<dyn RecurrentAttention + Send>, Vec<f32>)> =
                    states
                        .iter_mut()
                        .enumerate()
                        .map(|(hd, st)| (hd, st, vec![0.0f32; n * dh]))
                        .collect();
                let run = |(hd, st, out): &mut (
                    usize,
                    &mut Box<dyn RecurrentAttention + Send>,
                    Vec<f32>,
                )| {
                    let qh = gather_head(q, 0, n, d, *hd, dh);
                    let kh = gather_head(k, 0, n, d, *hd, dh);
                    let vh = gather_head(vv, 0, n, d, *hd, dh);
                    for i in 0..n {
                        st.step(
                            &qh[i * dh..(i + 1) * dh],
                            &kh[i * dh..(i + 1) * dh],
                            &vh[i * dh..(i + 1) * dh],
                            &mut out[i * dh..(i + 1) * dh],
                        );
                    }
                };
                if nh < 2 || d < 128 {
                    for w in work.iter_mut() {
                        run(w);
                    }
                } else {
                    fan_out(&mut work, run);
                }
                for (hd, _, out) in &work {
                    scatter_head(a, out, 0, n, d, *hd, dh);
                }
            }
            block_finish_into(&lw, x, a, n, d, ff, ao, h, f, g);
        }
        *pos += n;

        let last = &x[(n - 1) * d..n * d];
        nn::layernorm_affine_into(last, 1, d, model.lnf_g(), model.lnf_b(), xf);
        nn::tied_logits_into(xf, 1, d, embed, v, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets::native_model_entry;
    use crate::params::ParamStore;
    use crate::rng::Rng;

    fn model(name: &str) -> NativeModel {
        let entry = native_model_entry(name).unwrap();
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(5));
        NativeModel::new(entry, params).unwrap()
    }

    #[test]
    fn softmax_has_no_decode_session() {
        assert!(DecodeSession::new(&model("softmax_tiny")).is_err());
    }

    #[test]
    fn context_exhaustion_is_an_error() {
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        for i in 0..m.config().max_len {
            s.decode_step(&m, (i % 256) as i32).unwrap();
        }
        assert!(s.decode_step(&m, 0).is_err());
    }

    #[test]
    fn snapshot_reports_size() {
        let m = model("ho2_tiny");
        let s = DecodeSession::new(&m).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.pos(), 0);
        assert!(snap.bytes() >= s.state_bytes());
    }

    #[test]
    fn absorb_chunk_is_bit_identical_to_token_steps() {
        // chunked prefill is a scheduling decision, not a numeric one:
        // any chunking of the prompt leaves state + final logits
        // bit-equal to the token-at-a-time decode path
        let m = model("ho2_tiny");
        let toks: Vec<i32> = (0..23).map(|i| (i * 13 + 7) % 256).collect();
        let mut by_step = DecodeSession::new(&m).unwrap();
        let mut last = Vec::new();
        for &t in &toks {
            last = by_step.decode_step(&m, t).unwrap();
        }
        for chunks in [vec![23], vec![16, 7], vec![1, 21, 1]] {
            let mut by_chunk = DecodeSession::new(&m).unwrap();
            let mut got = Vec::new();
            let mut off = 0;
            for c in chunks {
                got = by_chunk.absorb_chunk(&m, &toks[off..off + c]).unwrap();
                off += c;
            }
            assert_eq!(by_chunk.pos(), toks.len());
            assert_eq!(got, last, "chunked logits drifted from streaming");
            // the state itself is identical, not just the logits
            assert_eq!(by_chunk.snapshot(), by_step.snapshot());
        }
    }

    #[test]
    fn absorb_chunk_rejects_overflow_and_empty() {
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        assert!(s.absorb_chunk(&m, &[]).is_err());
        let max = m.config().max_len;
        let toks = vec![1i32; max];
        s.absorb_chunk(&m, &toks).unwrap();
        assert_eq!(s.pos(), max);
        assert!(s.absorb_chunk(&m, &[1]).is_err(), "context exhausted");
    }

    #[test]
    fn f64_park_format_round_trips_bit_exactly() {
        // the default (f64 passthrough) park format: snapshot -> restore
        // -> snapshot is the identity down to the bit, and continuation
        // from the restored state is bit-identical to never parking
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        let toks: Vec<i32> = (0..31).map(|i| (i * 7 + 3) % 256).collect();
        s.absorb_chunk(&m, &toks).unwrap();
        let park = s.snapshot();
        assert_eq!(park.dtype(), StateDtype::F64);
        let mut restored = DecodeSession::new(&m).unwrap();
        restored.restore(&park).unwrap();
        assert_eq!(restored.snapshot(), park, "f64 round-trip must be bit-lossless");
        assert_eq!(
            restored.decode_step(&m, 42).unwrap(),
            s.decode_step(&m, 42).unwrap(),
            "continuation after a lossless park must not drift"
        );
    }

    #[test]
    fn f32_compact_baseline_is_canonical_and_idempotent() {
        // the canonical compact format: encoding costs one f64->f32
        // rounding, after which restore -> re-snapshot(f32) is a fixed
        // point — and the one-pass snapshot_as agrees bit for bit with
        // transcoding today's f64 park format
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        let toks: Vec<i32> = (0..31).map(|i| (i * 11 + 1) % 256).collect();
        s.absorb_chunk(&m, &toks).unwrap();
        let compact = s.snapshot_as(StateDtype::F32);
        assert_eq!(
            s.snapshot().transcode(StateDtype::F32),
            compact,
            "direct f32 snapshot must equal the transcoded f64 park format"
        );
        let mut restored = DecodeSession::new(&m).unwrap();
        restored.restore(&compact).unwrap();
        assert_eq!(
            restored.snapshot_as(StateDtype::F32),
            compact,
            "f32 round-trip must be idempotent (bit-exact after first encode)"
        );
    }

    #[test]
    fn lossy_restore_logit_drift_is_bounded() {
        // restoring through a narrow dtype perturbs the state once; the
        // next-token logits must stay within a per-dtype envelope of the
        // lossless continuation (the model-level face of the kernel-level
        // oracle drift sweep in rust/tests/proptests.rs)
        let m = model("ho2_tiny");
        let mut s = DecodeSession::new(&m).unwrap();
        let toks: Vec<i32> = (0..48).map(|i| (i * 5 + 2) % 256).collect();
        s.absorb_chunk(&m, &toks).unwrap();
        let park = s.snapshot();
        let want = s.decode_step(&m, 9).unwrap();
        for (dtype, bound) in [
            (StateDtype::F32, 1e-2f32),
            (StateDtype::F16, 0.5),
            (StateDtype::Bf16, 2.0),
            (StateDtype::Int8, 2.0),
        ] {
            let compact = park.transcode(dtype);
            assert!(compact.bytes() < park.bytes(), "{dtype} must be denser than f64");
            let mut r = DecodeSession::new(&m).unwrap();
            r.restore(&compact).unwrap();
            let got = r.decode_step(&m, 9).unwrap();
            let err = want
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                err.is_finite() && err <= bound,
                "{dtype} restore drift {err} exceeds {bound}"
            );
        }
    }

    #[test]
    fn restore_rejects_wrong_shape() {
        let m2 = model("ho2_tiny");
        let m1 = model("ho2_tiny_a3_o1"); // smaller per-head state
        let mut s2 = DecodeSession::new(&m2).unwrap();
        let s1 = DecodeSession::new(&m1).unwrap();
        assert!(s2.restore(&s1.snapshot()).is_err());
    }
}
