//! Metrics: online statistics, latency histograms, throughput meters and
//! structured log writers (JSONL/CSV) used by the trainer, the server and
//! the benches.

use std::time::{Duration, Instant};

/// Online mean/min/max/std over f64 samples (Welford).
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

/// Must agree with [`Stats::new`]: a derived `Default` would start
/// `min`/`max` at 0.0, silently reporting `min = 0` for all-positive
/// samples.
impl Default for Stats {
    fn default() -> Self {
        Stats::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Stats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Growable latency recorder with exact percentiles.  Percentile reads
/// sort a copy of the samples; batch the reads through
/// [`Latencies::percentiles_us`] so hot paths pay for one sort, not one
/// per percentile.
///
/// An empty recorder has **no** percentiles: the reads return `None`
/// instead of a fake 0 (a 0µs p99 over zero requests used to read as
/// "infinitely fast" in bench JSON).  The serve layer now records into
/// the fixed-footprint [`crate::obs::HistoSnapshot`] (log2-bucketed,
/// mergeable, same `None`-when-empty contract); this exact recorder
/// remains for benches that want unbucketed percentiles.
#[derive(Debug, Clone, Default)]
pub struct Latencies {
    samples_us: Vec<u64>,
}

impl Latencies {
    pub fn new() -> Self {
        Latencies { samples_us: Vec::new() }
    }

    pub fn push(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn len(&self) -> usize {
        self.samples_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_us.is_empty()
    }

    /// Fold another recorder's samples into this one — aggregate
    /// percentiles across engine shards are computed over the pooled
    /// samples, not averaged per-shard quantiles (which would be wrong).
    pub fn merge(&mut self, other: &Latencies) {
        self.samples_us.extend_from_slice(&other.samples_us);
    }

    /// Nearest-rank percentile in a sorted sample: ceil(p/100·n) − 1,
    /// clamped.
    fn rank(sorted: &[u64], p: f64) -> u64 {
        let rank = ((p / 100.0 * sorted.len() as f64).ceil() as isize - 1).max(0) as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    /// Exact percentiles (each p in [0,100]) in microseconds, one sort
    /// for the whole batch.  `None` when no samples were recorded —
    /// there is no honest percentile of an empty set.
    pub fn percentiles_us(&self, ps: &[f64]) -> Option<Vec<u64>> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        Some(ps.iter().map(|&p| Self::rank(&v, p)).collect())
    }

    /// Exact percentile (p in [0,100]) in microseconds; `None` when
    /// empty.  For several reads use [`Latencies::percentiles_us`].
    pub fn percentile_us(&self, p: f64) -> Option<u64> {
        Some(self.percentiles_us(&[p])?[0])
    }

    /// Mean in microseconds; `None` when empty.
    pub fn mean_us(&self) -> Option<f64> {
        if self.samples_us.is_empty() {
            return None;
        }
        Some(self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64)
    }

    pub fn summary(&self) -> String {
        match self.percentiles_us(&[50.0, 95.0, 99.0, 100.0]) {
            None => "n=0 (no samples)".to_string(),
            Some(q) => format!(
                "n={} mean={:.0}us p50={}us p95={}us p99={}us max={}us",
                self.len(),
                self.mean_us().expect("non-empty"),
                q[0],
                q[1],
                q[2],
                q[3],
            ),
        }
    }
}

/// Items-per-second meter over a wall-clock window.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    pub items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.items as f64 / dt
        }
    }
}

/// Simple scoped timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_moments() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.n, 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentiles() {
        let mut l = Latencies::new();
        for i in 1..=100u64 {
            l.push(Duration::from_micros(i));
        }
        assert_eq!(l.percentile_us(0.0), Some(1));
        assert_eq!(l.percentile_us(50.0), Some(50));
        assert_eq!(l.percentile_us(100.0), Some(100));
        // batch reads agree with single reads (one sort either way)
        assert_eq!(
            l.percentiles_us(&[0.0, 50.0, 95.0, 100.0]),
            Some(vec![1, 50, 95, 100])
        );
    }

    #[test]
    fn empty_recorder_has_no_percentiles() {
        // regression: an empty recorder used to export percentile 0 —
        // a 0µs p99 over zero requests read as "infinitely fast"
        let empty = Latencies::new();
        assert_eq!(empty.percentiles_us(&[50.0, 99.0]), None);
        assert_eq!(empty.percentile_us(50.0), None);
        assert_eq!(empty.mean_us(), None);
        assert_eq!(empty.summary(), "n=0 (no samples)");
    }

    #[test]
    fn merge_pools_samples_for_aggregate_percentiles() {
        let mut a = Latencies::new();
        let mut b = Latencies::new();
        for i in 1..=50u64 {
            a.push(Duration::from_micros(i));
            b.push(Duration::from_micros(i + 50));
        }
        a.merge(&b);
        assert_eq!(a.len(), 100);
        assert_eq!(a.percentile_us(50.0), Some(50));
        assert_eq!(a.percentile_us(100.0), Some(100));
        a.merge(&Latencies::new());
        assert_eq!(a.len(), 100, "merging an empty recorder is a no-op");
    }

    #[test]
    fn default_stats_matches_new() {
        // regression: a derived Default used to start min/max at 0.0, so
        // all-positive samples reported min = 0
        let mut s = Stats::default();
        s.push(3.0);
        s.push(5.0);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 5.0);
        let empty = Stats::default();
        assert!(empty.min.is_infinite() && empty.min > 0.0);
        assert!(empty.max.is_infinite() && empty.max < 0.0);
    }
}
