//! SIMD ≡ scalar pins for the lane-tiled φ hot path.
//!
//! The dispatch contract (see `kernels/simd.rs` docs):
//!
//! * **States are bit-identical across ISAs** — the absorb update is
//!   elementwise multiply-then-add with FMA forbidden, so snapshots and
//!   golden pins never depend on which lane path ran.
//! * **Query-side reductions may reassociate** — outputs drift at most
//!   1e-6 relative against the always-kept [`Isa::Scalar`] reference
//!   path (which itself reproduces the pre-SIMD accumulation order bit
//!   for bit; `rust/tests/golden_order2.rs` pins that side).
//!
//! Swept across the feature-map axis (Taylor orders 0–3 with LayerNorm
//! on and off, plus the elu+1 linear baseline), the evaluation axis
//! (streaming, chunked at several chunk sizes, normalized decode
//! steps), and the backward pass.  Also pins the worker-pool
//! determinism claim: fan-out outputs are independent of thread count.

use holt::kernels::{
    chunked_attention_vjp, simd, Evaluation, Isa, NativeBackend, RecurrentAttention,
};
use holt::model::WorkerPool;
use holt::rng::Rng;

/// Every (kind, order, normalize_qk) point the sweep covers.
fn configs() -> Vec<(&'static str, usize, bool)> {
    vec![
        ("ho", 0, true),
        ("ho", 1, true),
        ("ho", 2, true),
        ("ho", 2, false),
        ("ho", 3, true),
        ("ho", 3, false),
        ("linear", 0, true),
    ]
}

fn backend(order: usize, normalize_qk: bool, isa: Isa) -> NativeBackend {
    NativeBackend { order, normalize_qk, isa: Some(isa), ..NativeBackend::paper() }
}

fn seq(seed: u64, n: usize, d: usize, dv: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec_f32(n * d, 1.0),
        rng.normal_vec_f32(n * d, 1.0),
        rng.normal_vec_f32(n * dv, 1.0),
    )
}

/// Relative closeness at the documented reassociation tolerance.
fn assert_close(got: &[f32], want: &[f32], tol: f64, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (&a, &b)) in got.iter().zip(want).enumerate() {
        let (a, b) = (a as f64, b as f64);
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "{ctx}: [{i}] got {a} want {b}"
        );
    }
}

#[test]
fn absorbed_state_bits_do_not_depend_on_isa() {
    let (n, d, dv) = (24, 6, 5);
    let (_q, k, v) = seq(901, n, d, dv);
    for (kind, order, ln) in configs() {
        let mut want: Vec<f64> = Vec::new();
        for isa in simd::available() {
            let mut st = backend(order, ln, isa).state(kind, d, dv).unwrap();
            for j in 0..n {
                st.absorb(&k[j * d..(j + 1) * d], &v[j * dv..(j + 1) * dv]);
            }
            let mut snap = Vec::new();
            st.save_state(&mut snap);
            if want.is_empty() {
                want = snap;
            } else {
                // bit-equal, not approximately equal
                assert_eq!(snap, want, "{kind} o{order} ln={ln} isa {isa:?}");
            }
        }
    }
}

#[test]
fn streaming_outputs_match_scalar_within_tolerance() {
    let (n, d, dv) = (40, 6, 6);
    let (q, k, v) = seq(902, n, d, dv);
    for (kind, order, ln) in configs() {
        let mk = |isa| NativeBackend {
            evaluation: Evaluation::Streaming,
            ..backend(order, ln, isa)
        };
        let want = mk(Isa::Scalar).forward(kind, &q, &k, &v, n, d, dv, true).unwrap();
        for isa in simd::available() {
            let got = mk(isa).forward(kind, &q, &k, &v, n, d, dv, true).unwrap();
            assert_close(&got, &want, 1e-6, &format!("{kind} o{order} ln={ln} {isa:?}"));
        }
    }
}

#[test]
fn chunked_outputs_match_scalar_across_chunk_sizes() {
    let (n, d, dv) = (40, 6, 6);
    let (q, k, v) = seq(903, n, d, dv);
    for (kind, order, ln) in configs() {
        for chunk in [1usize, 5, 16, 64] {
            let mk = |isa| NativeBackend { chunk, ..backend(order, ln, isa) };
            let want = mk(Isa::Scalar).forward(kind, &q, &k, &v, n, d, dv, true).unwrap();
            for isa in simd::available() {
                let got = mk(isa).forward(kind, &q, &k, &v, n, d, dv, true).unwrap();
                assert_close(
                    &got,
                    &want,
                    1e-6,
                    &format!("{kind} o{order} ln={ln} c{chunk} {isa:?}"),
                );
            }
        }
    }
}

#[test]
fn decode_steps_match_scalar_within_tolerance() {
    // the zero-alloc normalized `step` read, token by token
    let (n, d, dv) = (32, 6, 5);
    let (q, k, v) = seq(904, n, d, dv);
    for (kind, order, ln) in configs() {
        let mut want = backend(order, ln, Isa::Scalar).state(kind, d, dv).unwrap();
        for isa in simd::available() {
            let mut st = backend(order, ln, isa).state(kind, d, dv).unwrap();
            want.reset();
            let (mut ow, mut og) = (vec![0.0f32; dv], vec![0.0f32; dv]);
            for i in 0..n {
                let (qi, ki) = (&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d]);
                let vi = &v[i * dv..(i + 1) * dv];
                want.step(qi, ki, vi, &mut ow);
                st.step(qi, ki, vi, &mut og);
                assert_close(&og, &ow, 1e-6, &format!("{kind} o{order} ln={ln} t{i} {isa:?}"));
            }
        }
    }
}

#[test]
fn backward_grads_match_scalar_within_tolerance() {
    let (n, d, dv) = (24, 5, 4);
    let (q, k, v) = seq(905, n, d, dv);
    let go = Rng::new(906).normal_vec_f32(n * dv, 1.0);
    for (kind, order, ln) in configs() {
        let mut reference = backend(order, ln, Isa::Scalar).grad_state(kind, d, dv).unwrap();
        let (wq, wk, wv) = chunked_attention_vjp(reference.as_mut(), &q, &k, &v, n, 7, &go);
        for isa in simd::available() {
            let mut st = backend(order, ln, isa).grad_state(kind, d, dv).unwrap();
            let (gq, gk, gv) = chunked_attention_vjp(st.as_mut(), &q, &k, &v, n, 7, &go);
            let ctx = format!("{kind} o{order} ln={ln} {isa:?}");
            assert_close(&gq, &wq, 1e-6, &format!("{ctx} gq"));
            assert_close(&gk, &wk, 1e-6, &format!("{ctx} gk"));
            assert_close(&gv, &wv, 1e-6, &format!("{ctx} gv"));
        }
    }
}

#[test]
fn pool_fan_out_kernel_batches_are_thread_count_invariant() {
    // the executor's per-head fan-out shape: each item runs one head's
    // chunked forward.  Per-item work is deterministic and the isa is
    // resolved per state, so any worker count must give the same bits.
    struct Head {
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        out: Vec<f32>,
    }
    let (n, d) = (48, 8);
    let make_heads = || -> Vec<Head> {
        (0..9)
            .map(|h| {
                let (q, k, v) = seq(910 + h as u64, n, d, d);
                Head { q, k, v, out: Vec::new() }
            })
            .collect()
    };
    let be = NativeBackend::paper();
    let run = |pool: &WorkerPool| {
        let mut heads = make_heads();
        pool.fan_out(&mut heads, |head| {
            head.out = be.forward("ho", &head.q, &head.k, &head.v, n, d, d, true).unwrap();
        });
        heads.into_iter().map(|h| h.out).collect::<Vec<_>>()
    };
    let want = run(&WorkerPool::new(0));
    for workers in [1usize, 2, 8] {
        let got = run(&WorkerPool::new(workers));
        assert_eq!(got, want, "workers={workers}");
    }
}
