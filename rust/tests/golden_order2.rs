//! Golden bit-exactness: the generic `PhiState<TaylorMap>` /
//! `PhiState<EluMap>` kernels must reproduce the **pre-FeatureMap**
//! hand-specialized kernels *bit for bit* at order ≤ 2.
//!
//! The goldens are not literals — they are the pre-redesign algorithms
//! themselves: `LegacyHoState` and `LegacyLinearState` below are verbatim
//! copies of the deleted `HoState`/`LinearState` forward bodies (struct
//! layout, accumulation order, every expression) as of the commit before
//! the redesign.  Running both through the same drivers and asserting
//! `==` on the f32 outputs and the f64 states is the strongest possible
//! pin: any reassociation, reordering or coefficient slip in the generic
//! path fails the test exactly, not within a tolerance.
//!
//! (The redesign's save_state layout interleaves differently —
//! [Z | M] instead of [s0, s0v, s1, s1v, s2, s2v] — so state comparison
//! permutes the legacy vector into the new layout first.)
//!
//! The lane-tiled query paths reassociate by design, so every new-path
//! state here pins `Isa::Scalar` — the always-available reference
//! dispatch whose accumulation order *is* the legacy order.  (States are
//! bit-identical under any ISA; it's the f32 outputs that need the pin.)

use holt::kernels::{
    chunked_forward, streaming_forward, HoState, Isa, LinearState, RecurrentAttention,
};
use holt::mathref::{elu1, layernorm_noaffine, taylor_exp};
use holt::rng::Rng;

const LN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// verbatim pre-redesign order-≤2 Taylor kernel
// ---------------------------------------------------------------------------

struct LegacyHoState {
    d: usize,
    dv: usize,
    order: usize,
    scale: f64,
    normalize_qk: bool,
    s0: f64,
    s0v: Vec<f64>,
    s1: Vec<f64>,
    s1v: Vec<f64>,
    s2: Vec<f64>,
    s2v: Vec<f64>,
}

impl LegacyHoState {
    fn new(d: usize, dv: usize, order: usize, alpha: f64, normalize_qk: bool) -> LegacyHoState {
        assert!(order <= 2);
        let t = d * (d + 1) / 2;
        LegacyHoState {
            d,
            dv,
            order,
            scale: 1.0 / (alpha * (d as f64).sqrt()),
            normalize_qk,
            s0: 0.0,
            s0v: vec![0.0; dv],
            s1: vec![0.0; if order >= 1 { d } else { 0 }],
            s1v: vec![0.0; if order >= 1 { d * dv } else { 0 }],
            s2: vec![0.0; if order >= 2 { t } else { 0 }],
            s2v: vec![0.0; if order >= 2 { t * dv } else { 0 }],
        }
    }

    fn normalized(&self, row: &[f32]) -> Vec<f32> {
        let mut out = row.to_vec();
        if self.normalize_qk {
            layernorm_noaffine(&mut out, 1, self.d, LN_EPS);
        }
        out
    }

    fn query_raw_normed(&self, qn: &[f32], num: &mut [f64]) -> f64 {
        let (d, dv) = (self.d, self.dv);
        let mut den = self.s0;
        num.copy_from_slice(&self.s0v);
        let u: Vec<f64> = qn.iter().map(|&x| self.scale * x as f64).collect();
        if self.order >= 1 {
            for a in 0..d {
                let ua = u[a];
                den += ua * self.s1[a];
                let row = &self.s1v[a * dv..(a + 1) * dv];
                for (acc, &x) in num.iter_mut().zip(row) {
                    *acc += ua * x;
                }
            }
        }
        if self.order >= 2 {
            let mut p = 0;
            for a in 0..d {
                let ua = u[a];
                for b in a..d {
                    let f = if a == b { 0.5 * ua * ua } else { ua * u[b] };
                    den += f * self.s2[p];
                    let row = &self.s2v[p * dv..(p + 1) * dv];
                    for (acc, &x) in num.iter_mut().zip(row) {
                        *acc += f * x;
                    }
                    p += 1;
                }
            }
        }
        den
    }
}

impl RecurrentAttention for LegacyHoState {
    fn d(&self) -> usize {
        self.d
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn reset(&mut self) {
        self.s0 = 0.0;
        self.s0v.fill(0.0);
        self.s1.fill(0.0);
        self.s1v.fill(0.0);
        self.s2.fill(0.0);
        self.s2v.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        let kn = self.normalized(k);
        self.absorb_prepped(&kn, v);
    }

    fn absorb_prepped(&mut self, kn: &[f32], v: &[f32]) {
        let (d, dv) = (self.d, self.dv);
        self.s0 += 1.0;
        for (acc, &x) in self.s0v.iter_mut().zip(v) {
            *acc += x as f64;
        }
        if self.order >= 1 {
            for a in 0..d {
                let ka = kn[a] as f64;
                self.s1[a] += ka;
                let row = &mut self.s1v[a * dv..(a + 1) * dv];
                for (acc, &x) in row.iter_mut().zip(v) {
                    *acc += ka * x as f64;
                }
            }
        }
        if self.order >= 2 {
            let mut p = 0;
            for a in 0..d {
                let ka = kn[a] as f64;
                for b in a..d {
                    let kk = ka * kn[b] as f64;
                    self.s2[p] += kk;
                    let row = &mut self.s2v[p * dv..(p + 1) * dv];
                    for (acc, &x) in row.iter_mut().zip(v) {
                        *acc += kk * x as f64;
                    }
                    p += 1;
                }
            }
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw_normed(&self.normalized(q), num)
    }

    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw_normed(q, num)
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        self.pair_weight_prepped(&self.normalized(q), &self.normalized(k))
    }

    fn prep_rows(&self, rows: &[f32], n: usize) -> Vec<f32> {
        let mut out = rows.to_vec();
        if self.normalize_qk {
            layernorm_noaffine(&mut out, n, self.d, LN_EPS);
        }
        out
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        let mut dot = 0.0f64;
        for (&a, &b) in q.iter().zip(k) {
            dot += a as f64 * b as f64;
        }
        taylor_exp(dot * self.scale, self.order)
    }

    fn state_elements(&self) -> usize {
        1 + self.s0v.len() + self.s1.len() + self.s1v.len() + self.s2.len() + self.s2v.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.push(self.s0);
        out.extend_from_slice(&self.s0v);
        out.extend_from_slice(&self.s1);
        out.extend_from_slice(&self.s1v);
        out.extend_from_slice(&self.s2);
        out.extend_from_slice(&self.s2v);
    }

    fn load_state(&mut self, data: &[f64]) {
        let (head, rest) = data.split_at(1);
        self.s0 = head[0];
        let (a, rest) = rest.split_at(self.s0v.len());
        self.s0v.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s1.len());
        self.s1.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s1v.len());
        self.s1v.copy_from_slice(a);
        let (a, rest) = rest.split_at(self.s2.len());
        self.s2.copy_from_slice(a);
        self.s2v.copy_from_slice(rest);
    }
}

/// Permute the legacy [s0, s0v, s1, s1v, s2, s2v] state into the new
/// [Z (F) | M (F·dv)] layout: Z = [s0, s1, s2], M = [s0v, s1v, s2v].
fn legacy_to_phi_layout(st: &LegacyHoState) -> Vec<f64> {
    let mut out = Vec::with_capacity(st.state_elements());
    out.push(st.s0);
    out.extend_from_slice(&st.s1);
    out.extend_from_slice(&st.s2);
    out.extend_from_slice(&st.s0v);
    out.extend_from_slice(&st.s1v);
    out.extend_from_slice(&st.s2v);
    out
}

// ---------------------------------------------------------------------------
// verbatim pre-redesign elu+1 kernel (layout already matches [Z | M])
// ---------------------------------------------------------------------------

struct LegacyLinearState {
    d: usize,
    dv: usize,
    z: Vec<f64>,
    m: Vec<f64>,
}

impl LegacyLinearState {
    fn new(d: usize, dv: usize) -> LegacyLinearState {
        LegacyLinearState { d, dv, z: vec![0.0; d], m: vec![0.0; d * dv] }
    }

    fn query_raw_phi<F: Fn(usize) -> f32>(&self, phi: F, num: &mut [f64]) -> f64 {
        let (d, dv) = (self.d, self.dv);
        num.fill(0.0);
        let mut den = 0.0f64;
        for a in 0..d {
            let p = phi(a) as f64;
            den += p * self.z[a];
            let row = &self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in num.iter_mut().zip(row) {
                *acc += p * x;
            }
        }
        den
    }
}

impl RecurrentAttention for LegacyLinearState {
    fn d(&self) -> usize {
        self.d
    }

    fn dv(&self) -> usize {
        self.dv
    }

    fn reset(&mut self) {
        self.z.fill(0.0);
        self.m.fill(0.0);
    }

    fn absorb(&mut self, k: &[f32], v: &[f32]) {
        let kp: Vec<f32> = k.iter().map(|&x| elu1(x)).collect();
        self.absorb_prepped(&kp, v);
    }

    fn absorb_prepped(&mut self, kp: &[f32], v: &[f32]) {
        let (d, dv) = (self.d, self.dv);
        for a in 0..d {
            let phi = kp[a] as f64;
            self.z[a] += phi;
            let row = &mut self.m[a * dv..(a + 1) * dv];
            for (acc, &x) in row.iter_mut().zip(v) {
                *acc += phi * x as f64;
            }
        }
    }

    fn query_raw(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw_phi(|a| elu1(q[a]), num)
    }

    fn query_raw_prepped(&self, q: &[f32], num: &mut [f64]) -> f64 {
        self.query_raw_phi(|a| q[a], num)
    }

    fn pair_weight(&self, q: &[f32], k: &[f32]) -> f64 {
        q.iter()
            .zip(k)
            .map(|(&a, &b)| elu1(a) as f64 * elu1(b) as f64)
            .sum()
    }

    fn prep_rows(&self, rows: &[f32], _n: usize) -> Vec<f32> {
        rows.iter().map(|&x| elu1(x)).collect()
    }

    fn pair_weight_prepped(&self, q: &[f32], k: &[f32]) -> f64 {
        q.iter().zip(k).map(|(&a, &b)| a as f64 * b as f64).sum()
    }

    fn state_elements(&self) -> usize {
        self.z.len() + self.m.len()
    }

    fn save_state(&self, out: &mut Vec<f64>) {
        out.extend_from_slice(&self.z);
        out.extend_from_slice(&self.m);
    }

    fn load_state(&mut self, data: &[f64]) {
        let (z, m) = data.split_at(self.z.len());
        self.z.copy_from_slice(z);
        self.m.copy_from_slice(m);
    }
}

// ---------------------------------------------------------------------------
// the pins
// ---------------------------------------------------------------------------

fn random_qkv(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    (
        rng.normal_vec_f32(n * d, 1.0),
        rng.normal_vec_f32(n * d, 1.0),
        rng.normal_vec_f32(n * dv, 1.0),
    )
}

#[test]
fn taylor_streaming_outputs_are_bit_identical_to_legacy() {
    let mut rng = Rng::new(1001);
    for (order, alpha, normalize, causal) in [
        (2usize, 3.0, true, true),   // the paper's configuration
        (2, 3.0, true, false),
        (2, 1.0, false, true),
        (1, 3.0, true, true),
        (1, 6.0, false, false),
        (0, 3.0, true, true),
    ] {
        let (n, d, dv) = (19, 6, 5);
        let (q, k, v) = random_qkv(&mut rng, n, d, dv);
        let mut new = HoState::new(d, dv, order, alpha, normalize);
        new.set_isa(Isa::Scalar);
        let mut old = LegacyHoState::new(d, dv, order, alpha, normalize);
        let a = streaming_forward(&mut new, &q, &k, &v, n, causal);
        let b = streaming_forward(&mut old, &q, &k, &v, n, causal);
        assert_eq!(a, b, "order {order} alpha {alpha} ln {normalize} causal {causal}");
        // the states themselves are bit-identical, modulo the layout
        // permutation
        let mut sn = Vec::new();
        new.save_state(&mut sn);
        assert_eq!(sn, legacy_to_phi_layout(&old), "state, order {order}");
    }
}

#[test]
fn taylor_chunked_outputs_are_bit_identical_to_legacy() {
    // chunked_forward exercises prep_rows + query_raw_prepped +
    // pair_weight_prepped + absorb_prepped — the whole blocked surface
    let mut rng = Rng::new(1002);
    let (n, d, dv) = (23, 5, 4);
    let (q, k, v) = random_qkv(&mut rng, n, d, dv);
    for order in [0usize, 1, 2] {
        for chunk in [1usize, 3, 8, 64] {
            let mut new = HoState::new(d, dv, order, 3.0, true);
            new.set_isa(Isa::Scalar);
            let mut old = LegacyHoState::new(d, dv, order, 3.0, true);
            let a = chunked_forward(&mut new, &q, &k, &v, n, chunk, true);
            let b = chunked_forward(&mut old, &q, &k, &v, n, chunk, true);
            assert_eq!(a, b, "order {order} chunk {chunk}");
        }
    }
}

#[test]
fn taylor_decode_steps_are_bit_identical_to_legacy() {
    // the serving path: step-by-step decode, state compared each token
    let mut rng = Rng::new(1003);
    let (d, dv) = (7, 7);
    let mut new = HoState::paper(d, dv);
    new.set_isa(Isa::Scalar);
    let mut old = LegacyHoState::new(d, dv, 2, 3.0, true);
    let mut oa = vec![0.0f32; dv];
    let mut ob = vec![0.0f32; dv];
    for i in 0..30 {
        let q = rng.normal_vec_f32(d, 1.0);
        let k = rng.normal_vec_f32(d, 1.0);
        let v = rng.normal_vec_f32(dv, 1.0);
        new.step(&q, &k, &v, &mut oa);
        old.step(&q, &k, &v, &mut ob);
        assert_eq!(oa, ob, "decode step {i}");
    }
    let mut sn = Vec::new();
    new.save_state(&mut sn);
    assert_eq!(sn, legacy_to_phi_layout(&old));
}

#[test]
fn linear_outputs_and_state_are_bit_identical_to_legacy() {
    let mut rng = Rng::new(1004);
    let (n, d, dv) = (17, 6, 4);
    let (q, k, v) = random_qkv(&mut rng, n, d, dv);
    for causal in [true, false] {
        let mut new = LinearState::new(d, dv);
        new.set_isa(Isa::Scalar);
        let mut old = LegacyLinearState::new(d, dv);
        let a = streaming_forward(&mut new, &q, &k, &v, n, causal);
        let b = streaming_forward(&mut old, &q, &k, &v, n, causal);
        assert_eq!(a, b, "causal {causal}");
        let c = chunked_forward(&mut new, &q, &k, &v, n, 5, true);
        let e = chunked_forward(&mut old, &q, &k, &v, n, 5, true);
        assert_eq!(c, e);
        // elu state layout was already [Z | M]: compare directly
        let (mut sn, mut so) = (Vec::new(), Vec::new());
        new.save_state(&mut sn);
        old.save_state(&mut so);
        assert_eq!(sn, so);
    }
}

#[test]
fn pair_weights_are_bit_identical_to_legacy() {
    let mut rng = Rng::new(1005);
    let d = 9;
    let mut new = HoState::paper(d, d);
    new.set_isa(Isa::Scalar);
    let old = LegacyHoState::new(d, d, 2, 3.0, true);
    for _ in 0..25 {
        let q = rng.normal_vec_f32(d, 1.0);
        let k = rng.normal_vec_f32(d, 1.0);
        assert_eq!(new.pair_weight(&q, &k), old.pair_weight(&q, &k));
    }
}
