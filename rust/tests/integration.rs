//! Integration tests over the real artifacts (require `make artifacts`).
//!
//! These exercise the full L3 path: manifest -> PJRT compile -> execute,
//! trainer steps, checkpoint resume, decode/forward equivalence and the
//! continuous-batching engine — everything a user touches.
//!
//! Gated behind the `artifacts` feature (Cargo.toml `required-features`):
//! plain `cargo test` skips this whole target so tier-1 stays green with
//! no artifacts, no PJRT and no Python. Running it for real needs a
//! PJRT-backed `xla` crate in place of the vendored stub, plus
//! `make artifacts` — see README.md.

#![cfg(feature = "artifacts")]

use holt::checkpoint::Checkpoint;
use holt::coordinator::generation::{decode_step, CachedParams, Generator, SampleOpts};
use holt::coordinator::server;
use holt::coordinator::state::StateManager;
use holt::coordinator::trainer::{ArtifactTrainer, TrainBackend};
use holt::data;
use holt::experiments;
use holt::model::ArtifactExecutor;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{Runtime, Tensor};

// The PJRT client is deliberately !Send (Rc internally), so each test
// builds its own runtime; compiles are per-test but the tiny artifacts
// compile in well under a second.
fn runtime() -> Runtime {
    let dir = holt::default_artifacts_dir().expect("run `make artifacts` first");
    Runtime::new(&dir).expect("run `make artifacts` first")
}

#[test]
fn manifest_lists_expected_models() {
    let rt = &runtime();
    for name in ["ho2_tiny", "linear_tiny", "softmax_tiny", "ho2_small"] {
        let m = rt.manifest.model(name).unwrap();
        assert_eq!(m.param_elements(), m.n_params, "{name}");
    }
}

#[test]
fn attention_artifacts_match_rust_reference_multi_seed() {
    // property-style: the jnp and pallas artifacts must agree with the
    // independently-written rust reference for several random inputs
    let rt = &runtime();
    for seed in [1, 2, 3] {
        for art in ["attn_ho2_n256", "attn_ho2_n256_pallas", "attn_linear_n256",
                    "attn_softmax_n256"] {
            let err = experiments::crosscheck_attention(rt, art, seed, 5e-4).unwrap();
            assert!(err < 5e-4, "{art} seed {seed}: {err}");
        }
    }
}

#[test]
fn fwd_executes_and_is_deterministic() {
    let rt = &runtime();
    let mut rng = Rng::new(0);
    let m = rt.manifest.model("ho2_tiny").unwrap();
    let params = ParamStore::init(&m.param_spec, &mut rng);
    let exe = rt.load(m.artifacts.get("fwd").unwrap()).unwrap();
    let (b, t) = (m.config.train_batch, m.config.train_len);
    let toks = Tensor::i32(vec![b, t], (0..(b * t) as i32).map(|i| i % 256).collect());
    let mut inputs = params.leaves.clone();
    inputs.push(toks);
    let a = exe.run(&inputs).unwrap().remove(0);
    let b2 = exe.run(&inputs).unwrap().remove(0);
    assert_eq!(a.shape, vec![b, t, m.config.vocab_size]);
    assert_eq!(a.max_abs_diff(&b2).unwrap(), 0.0);
    assert!(a.as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn run_rejects_wrong_arity_and_shapes() {
    let rt = &runtime();
    let exe = rt.load("attn_ho2_n64").unwrap();
    // wrong arity
    assert!(exe.run(&[]).is_err());
    // wrong shape
    let bad = Tensor::f32(vec![1, 4, 32, 64], vec![0.0; 4 * 32 * 64]);
    let good = Tensor::f32(vec![1, 4, 64, 64], vec![0.0; 4 * 64 * 64]);
    assert!(exe.run(&[bad.clone(), good.clone(), good.clone()]).is_err());
    // wrong dtype
    let ints = Tensor::i32(vec![1, 4, 64, 64], vec![0; 4 * 64 * 64]);
    assert!(exe.run(&[ints, good.clone(), good.clone()]).is_err());
}

#[test]
fn trainer_reduces_loss_on_copy_task() {
    let rt = &runtime();
    let mut trainer = ArtifactTrainer::new(rt, "ho2_tiny", 7).unwrap();
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("copy", 7).unwrap();
    let mut first = None;
    let mut last = 0.0;
    for i in 0..15 {
        let batch = gen.batch(b, t);
        let s = trainer.train_step(&batch, 1e-3).unwrap();
        if i == 0 {
            first = Some(s.loss);
        }
        last = s.loss;
    }
    let first = first.unwrap();
    assert!(last < first - 0.05, "loss did not decrease: {first} -> {last}");
    assert_eq!(trainer.step, 15);
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let rt = &runtime();
    let dir = std::env::temp_dir().join("holt_it_ckpt");
    let path = dir.join("t.ckpt");

    let mut a = ArtifactTrainer::new(rt, "ho2_tiny", 3).unwrap();
    let (b, t) = a.train_shape();
    let mut gen = data::make("assoc", 3).unwrap();
    let batches: Vec<_> = (0..6).map(|_| gen.batch(b, t)).collect();
    for batch in &batches[..3] {
        a.train_step(batch, 5e-4).unwrap();
    }
    a.checkpoint().save(&path).unwrap();
    // continue original
    let mut losses_a = Vec::new();
    for batch in &batches[3..] {
        losses_a.push(a.train_step(batch, 5e-4).unwrap().loss);
    }
    // resume copy
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, 3);
    let mut b2 = ArtifactTrainer::from_checkpoint(rt, "ho2_tiny", &ck).unwrap();
    let mut losses_b = Vec::new();
    for batch in &batches[3..] {
        losses_b.push(b2.train_step(batch, 5e-4).unwrap().loss);
    }
    assert_eq!(losses_a, losses_b, "resume must be bit-exact");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn decode_matches_forward_teacher_forced() {
    // the O(1)-state decode artifact must reproduce the fwd artifact's
    // logits column by column
    let rt = &runtime();
    let m = rt.manifest.model("ho2_tiny").unwrap();
    let mut rng = Rng::new(11);
    let params = ParamStore::init(&m.param_spec, &mut rng);

    let (b, t) = (m.config.train_batch, m.config.train_len);
    let bd = m.config.decode_batch;
    let toks_vec: Vec<i32> = (0..(b * t) as i32).map(|i| (i * 37 + 11) % 256).collect();
    let mut inputs = params.leaves.clone();
    inputs.push(Tensor::i32(vec![b, t], toks_vec.clone()));
    let fwd = rt.load(m.artifacts.get("fwd").unwrap()).unwrap();
    let logits_full = fwd.run(&inputs).unwrap().remove(0);
    let v = m.config.vocab_size;
    let lf = logits_full.as_f32().unwrap();

    // drive decode over the first `bd` rows for 16 steps
    let dec = rt.load(m.artifacts.get("decode").unwrap()).unwrap();
    let cached = CachedParams::new(&params).unwrap();
    let mut sm = StateManager::new(&m.state_spec).unwrap();
    for _ in 0..bd {
        sm.alloc().unwrap();
    }
    let steps = 16;
    for pos in 0..steps {
        let feed: Vec<i32> = (0..bd).map(|r| toks_vec[r * t + pos]).collect();
        let logits = decode_step(&dec, &cached, &mut sm, &feed).unwrap();
        for r in 0..bd {
            sm.advance(r);
        }
        let dl = logits.as_f32().unwrap();
        for r in 0..bd {
            let want = &lf[(r * t + pos) * v..(r * t + pos) * v + v];
            let got = &dl[r * v..(r + 1) * v];
            let err = want
                .iter()
                .zip(got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err < 5e-3, "pos {pos} row {r}: max|diff| {err}");
        }
    }
}

#[test]
fn generator_produces_tokens() {
    let rt = &runtime();
    let m = rt.manifest.model("ho2_tiny").unwrap();
    let params = ParamStore::init(&m.param_spec, &mut Rng::new(5));
    let exec = ArtifactExecutor::new(rt, "ho2_tiny", params).unwrap();
    let mut gen = Generator::new(Box::new(exec)).unwrap();
    let mut rng = Rng::new(9);
    let opts = SampleOpts { temperature: 1.0, top_k: 0, max_tokens: 12 };
    let (ids, text) = gen.generate("ab", opts, &mut rng).unwrap();
    assert!(ids.len() <= 12);
    assert!(text.len() <= ids.len() * 4);
    // greedy decoding twice gives identical outputs
    let g2 = SampleOpts { temperature: 0.0, top_k: 0, max_tokens: 8 };
    let (a, _) = gen.generate("xy", g2, &mut Rng::new(1)).unwrap();
    let (b, _) = gen.generate("xy", g2, &mut Rng::new(2)).unwrap();
    assert_eq!(a, b, "greedy must ignore the rng");
}

#[test]
fn engine_serves_synthetic_load() {
    let rt = &runtime();
    let m = rt.manifest.model("ho2_tiny").unwrap();
    let params = ParamStore::init(&m.param_spec, &mut Rng::new(5));
    let exec = ArtifactExecutor::new(rt, "ho2_tiny", params).unwrap();
    let stats = server::run_synthetic(Box::new(exec), 9, 12, 8, 0, 42).unwrap();
    assert_eq!(stats.completed, 9);
    assert!(stats.generated_tokens > 0);
    // more requests than slots (4) forces queueing + slot reuse
    assert!(stats.engine_steps as usize >= 12 + 8);
    assert!(stats.tokens_per_sec() > 0.0);
    assert_eq!(stats.backend, "artifact");
    assert!(stats.state_bytes_per_slot > 0);
}

#[test]
fn rust_cross_entropy_matches_in_graph_loss() {
    // the rust-side loss (data::Batch::cross_entropy over fwd logits) must
    // agree with the loss the fused train artifact computes in-graph
    let rt = &runtime();
    let mut trainer = ArtifactTrainer::new(rt, "ho2_tiny", 9).unwrap();
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("charlm", 9).unwrap();
    let batch = gen.batch(b, t);
    let logits = trainer.forward(&batch).unwrap();
    let ce = batch.cross_entropy(&logits).unwrap();
    let acc = batch.accuracy(&logits).unwrap();
    let graph_loss = trainer.train_step(&batch, 0.0).unwrap().loss as f64;
    assert!(
        (ce - graph_loss).abs() < 5e-3,
        "rust ce {ce} vs in-graph {graph_loss}"
    );
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn approx_quality_orders_correctly() {
    // E1's headline: higher order => lower error vs the softmax target,
    // for every alpha
    let rt = &runtime();
    let rows = experiments::approx_quality(rt, 123).unwrap();
    assert_eq!(rows.len(), 12);
    for alpha in [1.0, 2.0, 3.0, 4.0] {
        let err = |o: usize| {
            rows.iter()
                .find(|r| r.alpha == alpha && r.order == o)
                .unwrap()
                .rel_err_vs_target
        };
        assert!(err(2) < err(1), "alpha {alpha}: order2 !< order1");
        assert!(err(1) < err(0), "alpha {alpha}: order1 !< order0");
    }
}
