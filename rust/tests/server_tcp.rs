//! End-to-end TCP serving test: spin up `serve_tcp` on a loopback port,
//! drive it with JSON-lines requests over real sockets (sequential and
//! concurrent), and validate the responses.
//!
//! Gated behind the `artifacts` feature (Cargo.toml `required-features`),
//! like rust/tests/integration.rs — plain `cargo test` skips this target.

#![cfg(feature = "artifacts")]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use holt::coordinator::server::serve_tcp;
use holt::json::{obj, Json};
use holt::model::ArtifactExecutor;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::Runtime;

const ADDR: &str = "127.0.0.1:18497";

fn request(stream: &mut TcpStream, prompt: &str, max_tokens: usize) -> Json {
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(
        stream,
        "{}",
        obj(vec![
            ("prompt", prompt.into()),
            ("max_tokens", max_tokens.into()),
            ("temperature", 0.8.into()),
        ])
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).unwrap()
}

#[test]
fn tcp_roundtrip_and_concurrent_clients() {
    // server thread owns its runtime (PJRT client is !Send)
    std::thread::spawn(|| {
        let rt = Runtime::new(&holt::default_artifacts_dir().unwrap()).unwrap();
        let m = rt.manifest.model("ho2_tiny").unwrap();
        let params = ParamStore::init(&m.param_spec, &mut Rng::new(1));
        let exec = ArtifactExecutor::new(&rt, "ho2_tiny", params).unwrap();
        serve_tcp(Box::new(exec), ADDR, 7).unwrap();
    });

    // wait for the listener (compile included), up to ~30 s
    let mut conn = None;
    for _ in 0..300 {
        match TcpStream::connect(ADDR) {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let mut conn = conn.expect("server did not come up");

    // basic roundtrip
    let resp = request(&mut conn, "hello", 8);
    assert!(resp.get("error").is_none(), "{resp}");
    let n = resp.get("n_tokens").unwrap().as_i64().unwrap();
    assert!((0..=8).contains(&n), "n_tokens {n}");
    assert!(resp.get("ttft_s").unwrap().as_f64().unwrap() >= 0.0);

    // malformed JSON gets an error line, connection stays usable
    writeln!(conn, "this is not json").unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());
    let resp = request(&mut conn, "still alive", 4);
    assert!(resp.get("n_tokens").is_some());

    // oversized request is rejected cleanly: explicit error field plus
    // the legacy ttft_s = -1 sentinel
    let resp = request(&mut conn, &"x".repeat(100), 120); // 101 + 120 > 128
    assert_eq!(resp.get("ttft_s").unwrap().as_f64().unwrap(), -1.0);
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("max_len"));

    // concurrent clients — more than the 4 decode slots
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = TcpStream::connect(ADDR).unwrap();
                let r = request(&mut c, &format!("client {i} says"), 6);
                r.get("n_tokens").unwrap().as_i64().unwrap()
            })
        })
        .collect();
    for h in handles {
        let n = h.join().unwrap();
        assert!((0..=6).contains(&n));
    }
}
