//! Native training end-to-end: AdamW actually learns, checkpoints
//! round-trip bit-exactly, and the training forward is the serving
//! forward (same arithmetic, same logits).

use holt::checkpoint::Checkpoint;
use holt::coordinator::trainer::{NativeTrainer, TrainBackend};
use holt::data;
use holt::model::grad::forward_logits;
use holt::model::presets::param_spec;
use holt::model::{native_model_entry, NativeModel};
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{ModelConfig, ModelEntry};

/// A model small enough for 50 debug-mode train steps but with the full
/// architecture (2 layers, 2 heads, real vocab so every task fits).
fn smoke_entry(attn: &str) -> ModelEntry {
    let config = ModelConfig {
        preset: "smoke".into(),
        vocab_size: holt::tokenizer::VOCAB_SIZE,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_len: 64,
        attn: attn.into(),
        order: 2,
        alpha: 3.0,
        impl_: "native".into(),
        train_batch: 4,
        train_len: 32,
        decode_batch: 2,
        state_dtype: Default::default(),
    };
    let spec = param_spec(&config);
    let n_params = spec.iter().map(|l| l.shape.iter().product::<usize>()).sum();
    ModelEntry {
        name: format!("{attn}_smoke"),
        config,
        n_params,
        param_spec: spec,
        state_spec: Vec::new(),
        artifacts: std::collections::HashMap::new(),
    }
}

#[test]
fn fifty_adamw_steps_on_copy_reduce_loss() {
    let mut trainer = NativeTrainer::from_entry(smoke_entry("ho2"), 11).unwrap();
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("copy", 11).unwrap();
    let mut losses = Vec::new();
    for i in 0..50 {
        let lr = if i < 10 { 1e-3 * (i + 1) as f32 / 10.0 } else { 1e-3 };
        losses.push(trainer.train_step(&gen.batch(b, t), lr).unwrap().loss);
    }
    assert_eq!(trainer.step, 50);
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < 0.85 * first,
        "50 AdamW steps did not reduce loss enough: {first} -> {last}"
    );
    // strictly below the start for the whole final stretch (not a lucky
    // last batch)
    for (i, &l) in losses[40..].iter().enumerate() {
        assert!(l < first, "loss regressed above start at step {}: {l}", 41 + i);
    }
}

#[test]
fn training_forward_is_the_serving_forward() {
    // grad::forward_logits and NativeModel::forward run the same ops in
    // the same order — logits must agree exactly, so a trained
    // checkpoint serves exactly what it evaluated during training
    let entry = native_model_entry("ho2_tiny").unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(3));
    let toks: Vec<i32> = (0..2 * 12).map(|i| (i * 13 % 256) as i32).collect();
    let train_logits = forward_logits(&entry.config, &params, &toks, 2, 12).unwrap();
    let model = NativeModel::new(entry, params).unwrap();
    let serve_logits = model.forward(&toks, 2, 12).unwrap();
    assert_eq!(train_logits, serve_logits);
}

#[test]
fn native_checkpoint_roundtrip_is_bit_exact() {
    let dir = std::env::temp_dir().join("holt_native_ckpt_test");
    let path = dir.join("t.ckpt");
    let entry = smoke_entry("ho2");
    let mut a = NativeTrainer::from_entry(entry.clone(), 5).unwrap();
    let (b, t) = a.train_shape();
    let mut gen = data::make("assoc", 5).unwrap();
    let batches: Vec<_> = (0..6).map(|_| gen.batch(b, t)).collect();
    for batch in &batches[..3] {
        a.train_step(batch, 5e-4).unwrap();
    }
    a.checkpoint().save(&path).unwrap();
    let mut losses_a = Vec::new();
    for batch in &batches[3..] {
        losses_a.push(a.train_step(batch, 5e-4).unwrap().loss);
    }
    // resume: from_checkpoint wants a registry name; reuse the entry
    // by constructing the trainer manually through the same path the
    // CLI uses for preset models, then replacing state — instead just
    // verify the checkpoint sections restore an identical trainer
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.step, 3);
    let mut b2 = NativeTrainer::from_entry(entry, 999).unwrap(); // different init
    b2.params = ck.section("params").unwrap().clone();
    b2.m = ck.section("m").unwrap().clone();
    b2.v = ck.section("v").unwrap().clone();
    b2.step = ck.step;
    let mut losses_b = Vec::new();
    for batch in &batches[3..] {
        losses_b.push(b2.train_step(batch, 5e-4).unwrap().loss);
    }
    assert_eq!(losses_a, losses_b, "resume must be bit-exact");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn from_checkpoint_restores_preset_models() {
    // the CLI resume path: preset model name + checkpoint sections
    let mut a = NativeTrainer::new("ho2_tiny", 4).unwrap();
    let (b, t) = a.train_shape();
    let mut gen = data::make("copy", 4).unwrap();
    a.train_step(&gen.batch(b, t), 1e-3).unwrap();
    let ck = a.checkpoint();
    let b2 = NativeTrainer::from_checkpoint("ho2_tiny", &ck).unwrap();
    assert_eq!(b2.step, 1);
    assert_eq!(b2.params.leaves, a.params.leaves);
    assert_eq!(b2.m.leaves, a.m.leaves);
    // and a wrong model rejects the checkpoint
    assert!(NativeTrainer::from_checkpoint("ho2_small", &ck).is_err());
}

#[test]
fn ablation_variants_and_baselines_train_natively() {
    // one step each across the E6 grid axes: orders (incl. the order-3
    // point the FeatureMap redesign unlocked), alphas, both baselines —
    // every kind must produce finite loss and step
    for name in ["ho2_tiny_a1_o1", "ho2_tiny_a3_o0", "ho_tiny_o3", "linear_tiny"] {
        let mut tr = NativeTrainer::new(name, 8).unwrap();
        let mut gen = data::make("copy", 8).unwrap();
        let (b, t) = tr.train_shape();
        // small batch to keep debug-mode time down
        let batch = gen.batch(b.min(2), t.min(16));
        let stats = tr.train_step(&batch, 1e-3).unwrap();
        assert!(stats.loss.is_finite(), "{name}");
        assert_eq!(stats.step, 1, "{name}");
    }
    // softmax baseline trains through the direct O(n²) backward
    let mut tr = NativeTrainer::from_entry(smoke_entry("softmax"), 8).unwrap();
    let mut gen = data::make("copy", 8).unwrap();
    let s1 = tr.train_step(&gen.batch(2, 16), 1e-3).unwrap();
    let s2 = tr.train_step(&gen.batch(2, 16), 1e-3).unwrap();
    assert!(s1.loss.is_finite() && s2.loss.is_finite());
    assert_eq!(s2.step, 2);
}

#[test]
fn loss_curves_bit_reproducible_across_workers_and_accum() {
    // the tentpole determinism claim: accumulation splits and worker
    // counts are scheduling knobs only — the whole loss curve and the
    // final parameters are bit-identical for every setting, because
    // gradients are always per-sequence units merged by a fixed-shape
    // tree reduction
    let entry = smoke_entry("ho2");
    let run = |accum: usize, workers: usize| -> (Vec<u32>, NativeTrainer) {
        let mut tr = NativeTrainer::from_entry(entry.clone(), 17).unwrap();
        tr.accum = accum;
        tr.grad_workers = workers;
        let (b, t) = tr.train_shape();
        let mut gen = data::make("assoc", 17).unwrap();
        let losses = (0..6)
            .map(|_| tr.train_step(&gen.batch(b, t), 7e-4).unwrap().loss.to_bits())
            .collect();
        (losses, tr)
    };
    let (base_losses, base_tr) = run(1, 1);
    for (accum, workers) in [(1, 2), (1, 8), (1, 0), (4, 1), (4, 2)] {
        let (losses, tr) = run(accum, workers);
        assert_eq!(
            losses, base_losses,
            "loss curve drifted at accum={accum} grad_workers={workers}"
        );
        assert_eq!(
            tr.params.leaves, base_tr.params.leaves,
            "final params drifted at accum={accum} grad_workers={workers}"
        );
    }
}

#[test]
fn eval_accuracy_runs_on_native_trainer() {
    let trainer = NativeTrainer::from_entry(smoke_entry("ho2"), 9).unwrap();
    let mut gen = data::make("copy", 9).unwrap();
    let acc = trainer.eval_accuracy(&gen.batch(2, 16)).unwrap();
    assert!((0.0..=1.0).contains(&acc), "{acc}");
    assert!(trainer.supports_eval());
}
