//! serve/ scheduler subsystem tests — the ISSUE-4 acceptance criteria,
//! end to end on the native executor with no artifacts:
//!
//!  1. chunked prefill is bit-identical to token-at-a-time decode and
//!     cuts engine steps for a P-token prompt from ~P to ~⌈P/chunk⌉
//!     (pinned on `ServeStats::engine_steps`);
//!  2. a session-cache hit restores the O(1) state, skips re-prefilling
//!     the shared history, and produces next-token logits within 1e-4 of
//!     (in fact identical to) a from-scratch full-history prefill;
//!  3. preempt → park → resume is bit-exact mid-generation;
//!  4. synthetic load with more requests than slots completes *all*
//!     requests in arrival order under the FIFO policy (the old
//!     `Vec::push`/`Vec::pop` pending queue was LIFO and starved the
//!     oldest waiters).

use std::sync::mpsc::{channel, Receiver};

use holt::coordinator::server::{Engine, ServeStats};
use holt::model::{native_model_entry, Executor, NativeExecutor};
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::serve::{Policy, Request, ServeEvent, ServeOpts};
use holt::tokenizer::BOS;

fn executor(seed: u64) -> NativeExecutor {
    let entry = native_model_entry("ho2_tiny").unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(seed));
    NativeExecutor::new(entry, params).unwrap()
}

/// A deterministic (greedy) request: temperature 0 ignores the engine
/// rng, so outputs depend only on the prompt and the weights — which is
/// what lets the preemption/session tests demand bit-exactness.
fn greedy_request(
    id: u64,
    prompt: Vec<i32>,
    max_tokens: usize,
    respond: std::sync::mpsc::Sender<ServeEvent>,
) -> Request {
    let mut r = Request::new(id, prompt, respond);
    r.max_tokens = max_tokens;
    r.temperature = 0.0;
    r.top_k = 0;
    r
}

fn prompt(len: usize, salt: i32) -> Vec<i32> {
    std::iter::once(BOS)
        .chain((0..len as i32 - 1).map(|i| (i * 7 + salt) % 256))
        .collect()
}

/// Run `requests` through a fresh engine, returning (stats, responses in
/// completion order).  All requests are queued before the engine starts,
/// so admission order is exactly arrival order.
fn run_engine(seed: u64, opts: ServeOpts, requests: Vec<Request>, erx: Receiver<ServeEvent>) -> (ServeStats, Vec<holt::serve::Response>) {
    let (tx, rx) = channel::<Request>();
    for r in requests {
        tx.send(r).unwrap();
    }
    drop(tx);
    let mut engine = Engine::with_opts(Box::new(executor(seed)), 1, opts).unwrap();
    let stats = engine.run(rx).unwrap();
    drop(engine); // all event senders inside the engine are gone
    let responses: Vec<_> = erx
        .iter()
        .filter_map(|ev| match ev {
            ServeEvent::Done(r) => Some(r),
            _ => None,
        })
        .collect();
    (stats, responses)
}

#[test]
fn absorb_slot_is_bit_identical_to_decode_steps() {
    // the executor-level chunked-prefill contract: any chunking of the
    // prompt leaves the state exactly where the token loop leaves it
    let toks = prompt(37, 3);
    let mut chunked = executor(9);
    let mut stepped = executor(9);
    let cs = chunked.alloc_slot().unwrap();
    let ss = stepped.alloc_slot().unwrap();
    let mut last_chunk = Vec::new();
    for block in toks.chunks(16) {
        last_chunk = chunked.absorb_slot(cs, block).unwrap();
    }
    let feed_len = stepped.n_slots();
    let mut last_step = Vec::new();
    let v = stepped.model().config.vocab_size;
    for &t in &toks {
        let mut feed = vec![holt::tokenizer::PAD; feed_len];
        feed[ss] = t;
        let lg = stepped.decode_step(&feed).unwrap();
        last_step = lg.as_f32().unwrap()[ss * v..(ss + 1) * v].to_vec();
    }
    assert_eq!(chunked.pos(cs), toks.len());
    assert_eq!(stepped.pos(ss), toks.len());
    assert_eq!(last_chunk, last_step, "chunked prefill drifted from the token loop");
    // and the next decode step agrees bit-for-bit too
    let mut feed = vec![holt::tokenizer::PAD; feed_len];
    feed[cs] = 42;
    let a = chunked.decode_step(&feed).unwrap();
    let mut feed = vec![holt::tokenizer::PAD; feed_len];
    feed[ss] = 42;
    let b = stepped.decode_step(&feed).unwrap();
    assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
}

#[test]
fn chunked_prefill_cuts_engine_steps_by_the_chunk_factor() {
    // P = 49 prompt tokens, chunk 16 → ⌈49/16⌉ = 4 prefill steps; the
    // first token samples in the last prefill step, so the whole request
    // fits in 4 + (max_tokens - 1) engine steps.  Token-at-a-time pays
    // one step per prompt token.
    let p = 49;
    let max_tokens = 4;
    let mk = |chunk: usize| ServeOpts { prefill_chunk: chunk, ..ServeOpts::default() };

    let (etx, erx) = channel();
    let reqs = vec![greedy_request(1, prompt(p, 5), max_tokens, etx)];
    let (on, ron) = run_engine(2, mk(16), reqs, erx);
    assert_eq!(on.completed, 1);
    assert_eq!(ron.len(), 1);
    assert_eq!(on.prefill_chunk, 16);
    assert_eq!(on.prefill_tokens, p as u64, "every prompt token absorbed chunked");
    assert!(
        on.engine_steps <= (p as u64).div_ceil(16) + max_tokens as u64 - 1,
        "chunked prefill took {} engine steps for a {p}-token prompt",
        on.engine_steps
    );

    let (etx, erx) = channel();
    let reqs = vec![greedy_request(1, prompt(p, 5), max_tokens, etx)];
    let (off, roff) = run_engine(2, mk(1), reqs, erx);
    assert_eq!(off.completed, 1);
    assert_eq!(off.prefill_chunk, 1);
    assert_eq!(off.prefill_tokens, 0, "token-at-a-time never calls absorb_slot");
    assert!(
        off.engine_steps >= p as u64,
        "token-at-a-time must pay ~P steps, took {}",
        off.engine_steps
    );
    assert!(on.engine_steps < off.engine_steps / 4);
    // scheduling must not change the output
    assert_eq!(ron[0].token_ids, roff[0].token_ids);
}

#[test]
fn session_cache_hit_skips_reprefill_and_matches_full_history() {
    // Turn 1 runs a conversation to completion under a session_id; turn 2
    // extends the history.  The cache hit must (a) restore instead of
    // re-prefilling the shared prefix and (b) generate exactly what a
    // from-scratch engine generates for the same full-history prompt.
    let base = prompt(20, 11);
    let opts = ServeOpts::default();

    // engine A: two turns through one engine (cache lives in the engine)
    let (tx, rx) = channel::<Request>();
    let (etx, erx) = channel::<ServeEvent>();
    let engine_thread = std::thread::spawn(move || {
        let mut engine = Engine::with_opts(Box::new(executor(21)), 1, opts).unwrap();
        engine.run(rx).unwrap()
    });
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("conv".into());
    tx.send(r1).unwrap();
    let done1 = loop {
        match erx.recv().unwrap() {
            ServeEvent::Done(r) => break r,
            _ => continue,
        }
    };
    assert!(done1.error.is_none());
    // follow-up = full history (prompt + completion) + new user tokens
    let mut full: Vec<i32> = base.clone();
    full.extend(&done1.token_ids);
    full.extend([65, 66, 67]);
    let mut r2 = greedy_request(2, full.clone(), 6, etx.clone());
    r2.session_id = Some("conv".into());
    tx.send(r2).unwrap();
    let done2 = loop {
        match erx.recv().unwrap() {
            ServeEvent::Done(r) => break r,
            _ => continue,
        }
    };
    drop(etx);
    drop(tx);
    let stats = engine_thread.join().unwrap();
    assert_eq!(stats.session_misses, 1, "turn 1 misses");
    assert_eq!(stats.session_hits, 1, "turn 2 restores the cached state");
    // the hit skipped the shared prefix: only the new suffix prefilled.
    // turn 1 absorbed 20 prompt + 6 generated tokens chunked? no — only
    // prompt tokens count; turn 2 chunk-prefills just the new suffix.
    let absorbed_turn1 = base.len() as u64;
    assert!(
        stats.prefill_tokens < absorbed_turn1 + full.len() as u64,
        "prefill_tokens {} implies the full history was re-absorbed",
        stats.prefill_tokens
    );

    // engine B: from scratch, no session — same full-history prompt
    let (etx2, erx2) = channel();
    let fresh = vec![greedy_request(9, full.clone(), 6, etx2)];
    let (stats_b, resp_b) = run_engine(21, ServeOpts::default(), fresh, erx2);
    assert_eq!(stats_b.session_hits, 0);
    assert_eq!(
        done2.token_ids, resp_b[0].token_ids,
        "cache-resumed generation diverged from full-history prefill"
    );
}

#[test]
fn preempt_park_resume_is_bit_exact() {
    // 6 identical greedy requests over 4 slots with a 2-token quantum:
    // slots get preempted (snapshot → park → resume) and every request
    // must still produce exactly the tokens of an uninterrupted run.
    // distinct prompts per request: byte-identical snapshots would let a
    // park/resume state mix-up between requests go undetected
    let max_tokens = 6;
    let mk_reqs = |etx: &std::sync::mpsc::Sender<ServeEvent>| -> Vec<Request> {
        (0..6)
            .map(|i| greedy_request(i, prompt(12, 2 + i as i32), max_tokens, etx.clone()))
            .collect()
    };

    let (etx, erx) = channel();
    let reqs = mk_reqs(&etx);
    drop(etx);
    let plain_opts = ServeOpts::default();
    let (plain_stats, plain) = run_engine(31, plain_opts, reqs, erx);
    assert_eq!(plain_stats.preemptions, 0);
    assert_eq!(plain.len(), 6);

    let (etx, erx) = channel();
    let reqs = mk_reqs(&etx);
    drop(etx);
    let preempt_opts = ServeOpts { preempt_tokens: 2, ..ServeOpts::default() };
    let (stats, preempted) = run_engine(31, preempt_opts, reqs, erx);
    assert!(stats.preemptions >= 1, "quantum 2 with 2 waiters must preempt");
    assert_eq!(stats.resumes, stats.preemptions, "every parked slot resumes");
    assert_eq!(stats.completed, 6);
    assert_eq!(preempted.len(), 6);

    // identical greedy prompts ⇒ identical outputs, with or without
    // preemption — the snapshot/restore cycle is bit-exact
    let by_id = |mut v: Vec<holt::serve::Response>| {
        v.sort_by_key(|r| r.id);
        v
    };
    let plain = by_id(plain);
    let preempted = by_id(preempted);
    for (a, b) in plain.iter().zip(&preempted) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.token_ids, b.token_ids, "request {} diverged under preemption", a.id);
    }
}

#[test]
fn fifo_completes_overload_in_arrival_order() {
    // 9 identical requests, 4 slots: the old Vec::pop admission was LIFO
    // and served the newest arrival first.  Under FIFO every request
    // completes, in arrival order.
    let (etx, erx) = channel();
    let reqs: Vec<Request> =
        (0..9).map(|i| greedy_request(i, prompt(10, 4), 3, etx.clone())).collect();
    drop(etx);
    let (stats, responses) = run_engine(41, ServeOpts::default(), reqs, erx);
    assert_eq!(stats.completed, 9, "every queued request completes");
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(ids, sorted, "FIFO must complete in arrival order, got {ids:?}");
}

#[test]
fn priority_admits_before_earlier_low_priority_waiters() {
    // six queued requests for 4 slots: low-priority id 10 arrives before
    // high-priority id 11, but under the priority policy id 11 is
    // admitted — and completes — first.
    let (etx, erx) = channel();
    let mut reqs: Vec<Request> =
        (0..4).map(|i| greedy_request(i, prompt(8, 6), 4, etx.clone())).collect();
    let low = greedy_request(10, prompt(8, 6), 4, etx.clone());
    let mut high = greedy_request(11, prompt(8, 6), 4, etx.clone());
    high.priority = 5;
    reqs.push(low);
    reqs.push(high);
    drop(etx);
    let opts = ServeOpts { policy: Policy::Priority, ..ServeOpts::default() };
    let (stats, responses) = run_engine(51, opts, reqs, erx);
    assert_eq!(stats.completed, 6);
    let pos = |id: u64| responses.iter().position(|r| r.id == id).unwrap();
    assert!(
        pos(11) < pos(10),
        "high priority must overtake the earlier low-priority waiter: {:?}",
        responses.iter().map(|r| r.id).collect::<Vec<_>>()
    );
}

#[test]
fn oversized_requests_error_visibly() {
    let (etx, erx) = channel();
    // ho2_tiny max_len = 128; 100-token prompt + 120 max_tokens overflows
    let reqs = vec![greedy_request(1, prompt(100, 1), 120, etx)];
    let (stats, responses) = run_engine(61, ServeOpts::default(), reqs, erx);
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.rejected, 1, "rejections are counted, not silent");
    assert_eq!(responses.len(), 1);
    let r = &responses[0];
    assert!(r.error.as_deref().unwrap_or("").contains("max_len"), "{:?}", r.error);
    assert_eq!(r.ttft_s, -1.0, "legacy sentinel preserved");
    // and the wire line is distinguishable from success
    let line = holt::serve::stream::response_json(r).to_string();
    assert!(holt::json::Json::parse(&line).unwrap().get("error").is_some());
}

#[test]
fn streaming_emits_one_delta_per_token_then_done() {
    let (etx, erx) = channel();
    let mut r = greedy_request(1, prompt(10, 8), 5, etx);
    r.stream = true;
    let (tx, rx) = channel::<Request>();
    tx.send(r).unwrap();
    drop(tx);
    let mut engine = Engine::with_opts(Box::new(executor(71)), 1, ServeOpts::default()).unwrap();
    engine.run(rx).unwrap();
    drop(engine);
    let events: Vec<ServeEvent> = erx.iter().collect();
    let done = match events.last().unwrap() {
        ServeEvent::Done(r) => r.clone(),
        _ => panic!("stream must end with the final line"),
    };
    assert!(done.error.is_none());
    let deltas: Vec<(usize, i32)> = events
        .iter()
        .filter_map(|e| match e {
            ServeEvent::Delta { index, token_id, .. } => Some((*index, *token_id)),
            _ => None,
        })
        .collect();
    assert_eq!(deltas.len(), done.token_ids.len(), "one delta per generated token");
    for (i, (idx, tok)) in deltas.iter().enumerate() {
        assert_eq!(*idx, i, "delta indices are in order");
        assert_eq!(*tok, done.token_ids[i], "delta tokens match the final response");
    }
}

#[test]
fn tcp_pipelined_requests_on_one_connection() {
    // satellite: the old handle_conn blocked on recv() after each line —
    // two JSON lines written back-to-back now batch in the engine and
    // come back as two tagged responses on the same socket.
    use std::io::{BufRead, BufReader, Write};
    const ADDR: &str = "127.0.0.1:18501";
    std::thread::spawn(|| {
        holt::coordinator::server::serve_tcp(Box::new(executor(81)), ADDR, 7).unwrap();
    });
    let mut conn = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(ADDR) {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut conn = conn.expect("server did not come up");
    // two requests written before reading anything
    writeln!(conn, "{}", r#"{"prompt": "ab", "max_tokens": 3}"#).unwrap();
    writeln!(conn, "{}", r#"{"prompt": "cd", "max_tokens": 3}"#).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ids = Vec::new();
    for _ in 0..2 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = holt::json::Json::parse(&line).unwrap();
        assert!(j.get("error").is_none(), "{line}");
        ids.push(j.get("id").unwrap().as_i64().unwrap());
    }
    ids.sort_unstable();
    assert_eq!(ids.len(), 2);
    assert_ne!(ids[0], ids[1], "both pipelined requests answered");
}

/// Receive events until the final response line.
fn recv_done(erx: &Receiver<ServeEvent>) -> holt::serve::Response {
    loop {
        match erx.recv().unwrap() {
            ServeEvent::Done(r) => break r,
            _ => continue,
        }
    }
}

#[test]
fn migrated_session_is_bit_identical_to_unmigrated_run() {
    // ISSUE-7 acceptance: a session that migrates between shards via the
    // snapshot + absorbed-token shipment must generate exactly what the
    // same two turns generate on a single unmigrated engine.
    use holt::serve::{Router, RouterOpts};

    let base = prompt(20, 13);
    let follow = [65, 66, 67];

    // baseline: both turns through one engine, cache never moves
    let (tx, rx) = channel::<Request>();
    let (etx, erx) = channel::<ServeEvent>();
    let engine_thread = std::thread::spawn(move || {
        let mut engine =
            Engine::with_opts(Box::new(executor(91)), 1, ServeOpts::default()).unwrap();
        engine.run(rx).unwrap()
    });
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("mig".into());
    tx.send(r1).unwrap();
    let base_done1 = recv_done(&erx);
    assert!(base_done1.error.is_none());
    let mut full = base.clone();
    full.extend(&base_done1.token_ids);
    full.extend(follow);
    let mut r2 = greedy_request(2, full.clone(), 6, etx.clone());
    r2.session_id = Some("mig".into());
    tx.send(r2).unwrap();
    let base_done2 = recv_done(&erx);
    assert!(base_done2.error.is_none());
    drop((tx, etx));
    let base_stats = engine_thread.join().unwrap();
    assert_eq!(base_stats.session_hits, 1);

    // sharded: turn 1 on the hash home, then a forced migration to the
    // other shard, then turn 2 — which must hit the shipped entry there.
    // Identically-seeded executors on both shards (the router's usage
    // contract); greedy sampling makes the engine seeds irrelevant.
    let execs: Vec<Box<dyn Executor + Send>> =
        vec![Box::new(executor(91)), Box::new(executor(91))];
    let mut router = Router::new(execs, 1, ServeOpts::default(), RouterOpts::default()).unwrap();
    let (etx, erx) = channel::<ServeEvent>();
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("mig".into());
    router.route(r1);
    let done1 = recv_done(&erx);
    assert!(done1.error.is_none());
    assert_eq!(done1.token_ids, base_done1.token_ids, "turn 1 diverged before migration");

    let home = router.shard_of("mig");
    let to = 1 - home;
    assert!(router.migrate("mig", to), "a finished turn's cached entry must ship");
    assert_eq!(router.shard_of("mig"), to, "ownership re-homed with the shipment");
    // single ownership: the entry left the old partition — the stats
    // probe answers after the export drained, so the gauge is current
    let stats = router.stats_json();
    let per_shard = stats.get("per_shard").unwrap().as_arr().unwrap();
    let cached = |s: usize| per_shard[s].get("sessions_cached").unwrap().as_i64().unwrap();
    assert_eq!(cached(home), 0, "migrated entry still resident on the old shard");
    assert_eq!(cached(to), 1, "migrated entry not resident on the new shard");

    let mut r2 = greedy_request(2, full.clone(), 6, etx.clone());
    r2.session_id = Some("mig".into());
    router.route(r2);
    let done2 = recv_done(&erx);
    assert!(done2.error.is_none());
    assert_eq!(
        done2.token_ids, base_done2.token_ids,
        "post-migration generation diverged from the unmigrated run"
    );

    drop(etx);
    assert_eq!(router.report().migrations, 1);
    let (per_shard, report) = router.finish().unwrap();
    assert_eq!(report.migrations, 1);
    assert_eq!(per_shard[home].migrations_out, 1);
    assert_eq!(per_shard[to].migrations_in, 1);
    assert_eq!(per_shard[to].session_hits, 1, "turn 2 restored the shipped snapshot");
    // and the hit skipped re-prefilling the shared history: across both
    // shards only turn 1's prompt plus turn 2's fresh suffix absorbed
    let absorbed: u64 = per_shard.iter().map(|s| s.prefill_tokens).sum();
    assert!(
        absorbed < (base.len() + full.len()) as u64,
        "prefill_tokens {absorbed} implies the full history was re-absorbed after migration"
    );
}

#[test]
fn trace_ids_follow_a_request_across_a_migration() {
    // ISSUE-9 acceptance: router-minted trace ids thread through
    // RouterMsg -> EngineMsg -> scheduler -> flight recorder, so one
    // `{"trace": id}` probe reconstructs a request's lifecycle — and a
    // migration's two halves land on *different* shards under one trace.
    use holt::json::Json;
    use holt::serve::{Router, RouterOpts};

    let base = prompt(20, 13);
    let execs: Vec<Box<dyn Executor + Send>> =
        vec![Box::new(executor(91)), Box::new(executor(91))];
    let mut router = Router::new(execs, 1, ServeOpts::default(), RouterOpts::default()).unwrap();
    let (etx, erx) = channel::<ServeEvent>();

    // turn 1: the router mints trace 1 (ids are sequential from 1)
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("mig".into());
    router.route(r1);
    let done1 = recv_done(&erx);
    assert!(done1.error.is_none());

    // forced cross-shard migration: mints trace 2, shared by both halves
    let home = router.shard_of("mig");
    let to = 1 - home;
    assert!(router.migrate("mig", to), "cached entry must ship");

    // turn 2 lands on the new home under trace 3
    let mut full = base.clone();
    full.extend(&done1.token_ids);
    full.extend([65, 66, 67]);
    let mut r2 = greedy_request(2, full, 6, etx.clone());
    r2.session_id = Some("mig".into());
    router.route(r2);
    assert!(recv_done(&erx).error.is_none());

    let events_of = |j: &Json| -> Vec<(String, i64)> {
        j.get("events")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|e| {
                (
                    e.get("event").and_then(Json::as_str).unwrap().to_string(),
                    e.get("shard").and_then(Json::as_i64).unwrap(),
                )
            })
            .collect()
    };

    // trace 2 = the migration: exactly export-then-import, one event per
    // shard, merged into a single ordered timeline by the probe
    let t2 = router.trace_json(2);
    assert_eq!(t2.get("found").unwrap(), &Json::Bool(true));
    let evs = events_of(&t2);
    assert_eq!(
        evs,
        vec![("migrate_out".to_string(), home as i64), ("migrate_in".to_string(), to as i64)],
        "migration trace must show the export on the source shard before \
         the import on the target shard"
    );

    // traces 1 and 3 = the two turns: admitted and finished, each wholly
    // on the shard that owned the session at the time
    for (trace, shard) in [(1u64, home as i64), (3, to as i64)] {
        let t = router.trace_json(trace);
        assert_eq!(t.get("found").unwrap(), &Json::Bool(true), "trace {trace}");
        let evs = events_of(&t);
        let names: Vec<&str> = evs.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["admit", "finish"], "trace {trace}");
        assert!(
            evs.iter().all(|&(_, s)| s == shard),
            "trace {trace} expected entirely on shard {shard}, got {evs:?}"
        );
    }

    // an unknown trace id answers explicitly, not with a fake timeline
    let none = router.trace_json(999);
    assert_eq!(none.get("found").unwrap(), &Json::Bool(false));
    assert!(none.get("events").and_then(Json::as_arr).unwrap().is_empty());

    // the metrics probe aggregates the same registry the engines record
    // into: both migration halves and all four lifecycle stages counted
    let m = router.metrics_json();
    let shard_metric = |s: usize, key: &str| -> i64 {
        m.get("per_shard").and_then(Json::as_arr).unwrap()[s]
            .get(key)
            .and_then(Json::as_i64)
            .unwrap_or_else(|| panic!("shard {s} missing metric {key}"))
    };
    assert_eq!(shard_metric(home, "migrations_out"), 1);
    assert_eq!(shard_metric(to, "migrations_in"), 1);
    assert_eq!(shard_metric(home, "completed") + shard_metric(to, "completed"), 2);

    drop(etx);
    router.finish().unwrap();
}

#[test]
fn compact_dtype_session_cache_serves_and_reports_density() {
    // ISSUE-10: a lossy --state-dtype must thread end to end — the
    // finished turn is cached f16, the follow-up hit decodes it back to
    // live f64 state and completes — and the stats record the dtype plus
    // the analytic sessions-per-GiB sweep the acceptance reads.
    use holt::state::StateDtype;

    let base = prompt(20, 17);
    let opts = ServeOpts { state_dtype: StateDtype::F16, ..ServeOpts::default() };
    let (tx, rx) = channel::<Request>();
    let (etx, erx) = channel::<ServeEvent>();
    let engine_thread = std::thread::spawn(move || {
        let mut engine = Engine::with_opts(Box::new(executor(23)), 1, opts).unwrap();
        engine.run(rx).unwrap()
    });
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("conv".into());
    tx.send(r1).unwrap();
    let done1 = recv_done(&erx);
    assert!(done1.error.is_none());
    let mut full = base.clone();
    full.extend(&done1.token_ids);
    full.extend([65, 66, 67]);
    let mut r2 = greedy_request(2, full, 6, etx.clone());
    r2.session_id = Some("conv".into());
    tx.send(r2).unwrap();
    let done2 = recv_done(&erx);
    assert!(done2.error.is_none(), "generation from a rehydrated f16 snapshot failed");
    drop((tx, etx));
    let stats = engine_thread.join().unwrap();
    assert_eq!(stats.session_hits, 1, "the f16 entry must still be a usable hit");
    assert_eq!(stats.state_dtype, "f16");
    assert!(stats.session_cache_bytes > 0);

    // the analytic footprint block: f16 fits ≥ 3x the sessions of the
    // f64 baseline in the same GiB (the ISSUE-10 acceptance ratio), and
    // the top-level sessions_per_gib matches the active dtype's entry
    let density = |dtype: &str| -> f64 {
        stats
            .state_footprint
            .get(dtype)
            .and_then(|d| d.get("density_vs_f64"))
            .and_then(|j| j.as_f64())
            .unwrap_or_else(|| panic!("state_footprint missing dtype {dtype}"))
    };
    assert!((density("f64") - 1.0).abs() < 1e-12);
    assert!(density("f16") >= 3.0, "f16 density {} below the 3x acceptance", density("f16"));
    assert!(density("int8") > density("f16"), "int8 must be densest");
    let f16_per_gib = stats
        .state_footprint
        .get("f16")
        .and_then(|d| d.get("sessions_per_gib"))
        .and_then(|j| j.as_f64())
        .unwrap();
    assert!((stats.sessions_per_gib - f16_per_gib).abs() < 1e-9);
    // park/restore timings recorded for the cache round-trip
    assert!(stats.park.count >= 1, "session retain must record a park span");
    assert!(stats.restore.count >= 1, "session hit must record a restore span");
}

#[test]
fn migrated_encoded_session_is_bit_identical_for_lossy_dtypes() {
    // the encoded-bytes bit-path: migration ships the cache entry
    // verbatim (no re-encode), so even a *lossy* dtype generates exactly
    // the same continuation whether the session stayed home or shipped —
    // the quantization happened once, at park, on both paths.
    use holt::serve::{Router, RouterOpts};
    use holt::state::StateDtype;

    let base = prompt(20, 19);
    let follow = [65, 66, 67];
    let opts = || ServeOpts { state_dtype: StateDtype::Int8, ..ServeOpts::default() };

    // baseline: both turns through one engine, entry never moves
    let (tx, rx) = channel::<Request>();
    let (etx, erx) = channel::<ServeEvent>();
    let baseline_opts = opts();
    let engine_thread = std::thread::spawn(move || {
        let mut engine = Engine::with_opts(Box::new(executor(97)), 1, baseline_opts).unwrap();
        engine.run(rx).unwrap()
    });
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("mig".into());
    tx.send(r1).unwrap();
    let base_done1 = recv_done(&erx);
    assert!(base_done1.error.is_none());
    let mut full = base.clone();
    full.extend(&base_done1.token_ids);
    full.extend(follow);
    let mut r2 = greedy_request(2, full.clone(), 6, etx.clone());
    r2.session_id = Some("mig".into());
    tx.send(r2).unwrap();
    let base_done2 = recv_done(&erx);
    assert!(base_done2.error.is_none());
    drop((tx, etx));
    let base_stats = engine_thread.join().unwrap();
    assert_eq!(base_stats.session_hits, 1);

    // sharded: same turn 1, forced migration, then turn 2 off the
    // shipped (still-int8) entry on the other shard
    let execs: Vec<Box<dyn Executor + Send>> =
        vec![Box::new(executor(97)), Box::new(executor(97))];
    let mut router = Router::new(execs, 1, opts(), RouterOpts::default()).unwrap();
    let (etx, erx) = channel::<ServeEvent>();
    let mut r1 = greedy_request(1, base.clone(), 6, etx.clone());
    r1.session_id = Some("mig".into());
    router.route(r1);
    let done1 = recv_done(&erx);
    assert_eq!(done1.token_ids, base_done1.token_ids, "turn 1 diverged before migration");

    let home = router.shard_of("mig");
    assert!(router.migrate("mig", 1 - home), "cached entry must ship");

    let mut r2 = greedy_request(2, full, 6, etx.clone());
    r2.session_id = Some("mig".into());
    router.route(r2);
    let done2 = recv_done(&erx);
    assert!(done2.error.is_none());
    assert_eq!(
        done2.token_ids, base_done2.token_ids,
        "migrated int8 snapshot decoded differently than the unmigrated one \
         (migration must ship encoded bytes verbatim)"
    );
    drop(etx);
    let (per_shard, report) = router.finish().unwrap();
    assert_eq!(report.migrations, 1);
    assert_eq!(per_shard[1 - home].session_hits, 1, "turn 2 hit the shipped entry");
}

#[test]
fn migration_of_unknown_or_inflight_session_ships_nothing() {
    use holt::serve::{Router, RouterOpts};
    let execs: Vec<Box<dyn Executor + Send>> =
        vec![Box::new(executor(95)), Box::new(executor(95))];
    let mut router = Router::new(execs, 1, ServeOpts::default(), RouterOpts::default()).unwrap();
    let home = router.shard_of("ghost");
    // unknown session: re-homes (future turns go to the target) but no
    // entry ships, and migrating to the current home is a no-op
    assert!(!router.migrate("ghost", 1 - home), "nothing cached to ship");
    assert_eq!(router.shard_of("ghost"), 1 - home);
    assert!(!router.migrate("ghost", 1 - home), "already home");
    assert_eq!(router.report().migrations, 0);
    let (_, report) = router.finish().unwrap();
    assert_eq!(report.migrations, 0);
}
