//! Zero-alloc pin for the φ hot path: after warm-up, decode steps and
//! the per-token train vjps perform **no heap traffic** — every
//! transient lives in the per-engine [`Scratch`] arena (and, for the
//! Taylor map's reverse sweep, the map-internal vjp buffers).
//!
//! A counting `#[global_allocator]` wraps `System` and tallies every
//! `alloc`/`realloc`/`alloc_zeroed` in the process.  The counter is
//! process-global, so everything runs serially inside ONE `#[test]` —
//! a second test thread would put its own allocations inside our
//! measurement window.
//!
//! Scope: the *kernel-level* hot path (`step`, `pair_weight`,
//! `query_vjp` + `absorb_vjp` — the per-token per-(layer, head) inner
//! loops) AND the *model-level* decode step: `DecodeSession` keeps a
//! per-slot activation scratch arena, so after warm-up a whole-model
//! `decode_step_into` call is allocation-free too.
//!
//! [`Scratch`]: holt::kernels::Scratch

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use holt::kernels::{AttentionGrad, EluMap, FeatureMap, PhiState, RecurrentAttention, TaylorMap};
use holt::model::{native_model_entry, DecodeSession, NativeModel};
use holt::params::ParamStore;
use holt::rng::Rng;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> usize {
    ALLOCATIONS.load(Ordering::Relaxed)
}

const WARM: usize = 3;
const MEASURED: usize = 64;

/// `step` (absorb + normalized query) plus `pair_weight`, per token.
fn decode_phase<M: FeatureMap>(mut st: PhiState<M>, label: &str) {
    let (d, dv) = (st.d(), st.dv());
    let total = WARM + MEASURED;
    let mut rng = Rng::new(41);
    let q = rng.normal_vec_f32(total * d, 1.0);
    let k = rng.normal_vec_f32(total * d, 1.0);
    let v = rng.normal_vec_f32(total * dv, 1.0);
    let mut out = vec![0.0f32; dv];
    let mut sink = 0.0f64;
    for t in 0..WARM {
        st.step(&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d], &v[t * dv..(t + 1) * dv], &mut out);
        sink += st.pair_weight(&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d]);
    }
    let before = allocations();
    for t in WARM..total {
        st.step(&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d], &v[t * dv..(t + 1) * dv], &mut out);
        sink += st.pair_weight(&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d]);
    }
    let delta = allocations() - before;
    assert!(sink.is_finite());
    assert_eq!(delta, 0, "{label}: {delta} allocations in {MEASURED} decode steps");
}

/// `query_vjp` + `absorb_vjp` — one reverse-mode token of the train step.
fn vjp_phase<M: FeatureMap>(mut st: PhiState<M>, label: &str) {
    let (d, dv) = (st.d(), st.dv());
    let total = WARM + MEASURED;
    let mut rng = Rng::new(42);
    // non-trivial history so the vjps read a dense state
    for _ in 0..4 {
        st.absorb(&rng.normal_vec_f32(d, 1.0), &rng.normal_vec_f32(dv, 1.0));
    }
    let qp = st.prep_rows(&rng.normal_vec_f32(total * d, 1.0), total);
    let kp = st.prep_rows(&rng.normal_vec_f32(total * d, 1.0), total);
    let v = rng.normal_vec_f32(total * dv, 1.0);
    let dnum: Vec<f64> = rng.normal_vec_f32(dv, 1.0).iter().map(|&x| x as f64).collect();
    let mut gstate = vec![0.0f64; st.state_elements()];
    let mut gqp = vec![0.0f64; d];
    let mut gkp = vec![0.0f64; d];
    let mut gv = vec![0.0f64; dv];
    for t in 0..WARM {
        st.query_vjp(&qp[t * d..(t + 1) * d], &dnum, 0.25, &mut gstate, &mut gqp);
        st.absorb_vjp(&kp[t * d..(t + 1) * d], &v[t * dv..(t + 1) * dv], &gstate, &mut gkp, &mut gv);
    }
    let before = allocations();
    for t in WARM..total {
        st.query_vjp(&qp[t * d..(t + 1) * d], &dnum, 0.25, &mut gstate, &mut gqp);
        st.absorb_vjp(&kp[t * d..(t + 1) * d], &v[t * dv..(t + 1) * dv], &gstate, &mut gkp, &mut gv);
    }
    let delta = allocations() - before;
    assert_eq!(delta, 0, "{label}: {delta} allocations in {MEASURED} vjp tokens");
}

/// Whole-model single-token decode through [`DecodeSession::decode_step_into`]:
/// after warm-up grows the per-slot activation scratch, a full L-layer
/// step (embed → qkv → kernel recurrence → ffn → tied logits) performs
/// no heap traffic.
fn model_decode_phase(model: &NativeModel, label: &str) {
    let v = model.config().vocab_size;
    let mut sess = DecodeSession::new(model).unwrap();
    let mut out = vec![0.0f32; v];
    for t in 0..WARM {
        sess.decode_step_into(model, (t % 200) as i32, &mut out).unwrap();
    }
    let before = allocations();
    for t in WARM..WARM + MEASURED {
        sess.decode_step_into(model, (t % 200) as i32, &mut out).unwrap();
    }
    let delta = allocations() - before;
    assert!(out.iter().all(|x| x.is_finite()));
    assert_eq!(delta, 0, "{label}: {delta} allocations in {MEASURED} whole-model decode steps");
}

/// ISSUE-9 acceptance: observability must not buy telemetry with heap
/// traffic.  A whole-model decode step instrumented the way the serve
/// engine instruments it — RAII span timer into a registry histogram,
/// counter bump, gauge write, flight-recorder event — stays
/// allocation-free after warm-up (registration done, ring at capacity).
fn instrumented_model_decode_phase(model: &NativeModel, label: &str) {
    use holt::obs::{FlightEvent, FlightRecorder, Registry};
    let v = model.config().vocab_size;
    let registry = Registry::new();
    let steps = registry.counter("engine_steps");
    let busy = registry.gauge("slots_busy");
    let step_us = registry.histo("decode_step_us");
    let mut flight = FlightRecorder::new(0, 8);
    let mut sess = DecodeSession::new(model).unwrap();
    let mut out = vec![0.0f32; v];
    // warm-up grows the activation scratch AND fills the ring to
    // capacity, so measured recording is pure pop-front/push-back
    let warm = WARM.max(flight.capacity());
    for t in 0..warm {
        let _span = step_us.span();
        sess.decode_step_into(model, (t % 200) as i32, &mut out).unwrap();
        steps.inc();
        busy.set(1.0);
        flight.record(FlightEvent::Admit, 1, t as u64);
    }
    let before = allocations();
    for t in 0..MEASURED {
        let _span = step_us.span();
        sess.decode_step_into(model, (t % 200) as i32, &mut out).unwrap();
        steps.inc();
        busy.set(1.0);
        flight.record(FlightEvent::Finish, 1, t as u64);
    }
    let delta = allocations() - before;
    assert!(out.iter().all(|x| x.is_finite()));
    assert_eq!(steps.get(), (warm + MEASURED) as u64);
    assert_eq!(step_us.count(), (warm + MEASURED) as u64, "{label}: spans not recorded");
    assert_eq!(flight.len(), flight.capacity(), "{label}: ring not at capacity");
    assert_eq!(
        delta, 0,
        "{label}: {delta} allocations in {MEASURED} instrumented decode steps"
    );
}

#[test]
fn kernel_hot_paths_allocate_nothing_after_warmup() {
    // serial phases, one test — see module docs
    decode_phase(PhiState::with_map(TaylorMap::new(8, 2, 3.0, true), 8), "taylor o2 decode");
    decode_phase(PhiState::with_map(TaylorMap::new(6, 3, 3.0, true), 6), "taylor o3 decode");
    decode_phase(PhiState::with_map(TaylorMap::new(5, 0, 3.0, false), 4), "taylor o0 decode");
    decode_phase(PhiState::with_map(EluMap::new(8), 8), "elu decode");
    vjp_phase(PhiState::with_map(TaylorMap::new(6, 2, 3.0, true), 5), "taylor o2 vjp");
    vjp_phase(PhiState::with_map(TaylorMap::new(5, 3, 3.0, true), 4), "taylor o3 vjp");
    vjp_phase(PhiState::with_map(EluMap::new(6), 5), "elu vjp");
    // model level: the per-slot scratch makes the whole decode step
    // allocation-free, not just the kernel inner loops
    let entry = native_model_entry("ho2_tiny").unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(7));
    let model = NativeModel::new(entry, params).unwrap();
    model_decode_phase(&model, "ho2_tiny whole-model decode");
    // obs layer: instrumentation adds zero heap traffic on the same path
    instrumented_model_decode_phase(&model, "ho2_tiny instrumented decode");
}
