//! Native model executor tests — the serving stack end to end with no
//! artifacts, no PJRT and no Python.
//!
//! Pins the three properties the tentpole claims:
//!  1. chunked full-sequence prefill ≡ token-by-token O(1)-state decode
//!     (logits ≤ 1e-4) across attention kinds, Taylor orders and shapes;
//!  2. decode state size constant in generated length, with
//!     snapshot/restore round-trips bit-exact (slot preemption);
//!  3. the continuous-batching engine serves synthetic load through the
//!     `Executor` trait (previously only possible with PJRT artifacts).

use holt::coordinator::generation::{Generator, SampleOpts};
use holt::coordinator::server::run_synthetic;
use holt::model::{
    native_model_entry, DecodeSession, Executor, NativeExecutor, NativeModel,
};
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::Tensor;

fn model(name: &str, seed: u64) -> NativeModel {
    let entry = native_model_entry(name).unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(seed));
    NativeModel::new(entry, params).unwrap()
}

fn executor(name: &str, seed: u64) -> NativeExecutor {
    let entry = native_model_entry(name).unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(seed));
    NativeExecutor::new(entry, params).unwrap()
}

#[test]
fn prefill_matches_decode_across_kinds_orders_and_shapes() {
    // the serving guarantee: the chunked training-form forward and the
    // O(1)-per-token decode recurrence compute the same logits
    let mut rng = Rng::new(100);
    // ho_tiny_o3: the order-3 configuration the FeatureMap redesign
    // unlocked — same generic recurrence, one more packed block
    let names = [
        "ho2_tiny",
        "ho2_tiny_a3_o1",
        "ho2_tiny_a3_o0",
        "ho2_tiny_a1_o2",
        "ho_tiny_o3",
        "linear_tiny",
    ];
    for (mi, name) in names.iter().enumerate() {
        let m = model(name, 40 + mi as u64);
        let v = m.config().vocab_size;
        for (b, t) in [(1usize, 21usize), (2, 12)] {
            let toks: Vec<i32> =
                (0..b * t).map(|_| rng.uniform_int(0, 256) as i32).collect();
            let full = m.forward(&toks, b, t).unwrap();
            for bi in 0..b {
                let mut sess = DecodeSession::new(&m).unwrap();
                for ti in 0..t {
                    let logits = sess.decode_step(&m, toks[bi * t + ti]).unwrap();
                    let want = &full[(bi * t + ti) * v..(bi * t + ti + 1) * v];
                    let err = logits
                        .iter()
                        .zip(want)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0f32, f32::max);
                    assert!(
                        err <= 1e-4,
                        "{name} (b={b}, t={t}) row {bi} pos {ti}: max|diff| {err}"
                    );
                }
            }
        }
    }
}

#[test]
fn decode_state_is_constant_in_generated_length() {
    let m = model("ho2_tiny", 1);
    let mut sess = DecodeSession::new(&m).unwrap();
    let elems = sess.state_elements();
    // packed second-order state per (layer, head): d(d+1)/2 rows, not d²
    let dh = m.config().d_model / m.config().n_heads;
    let packed = dh * (dh + 1) / 2;
    let per_head = 1 + dh + dh + dh * dh + packed + packed * dh;
    assert_eq!(elems, m.config().n_layers * m.config().n_heads * per_head);
    let mut rng = Rng::new(2);
    for _ in 0..100 {
        sess.decode_step(&m, rng.uniform_int(0, 256) as i32).unwrap();
    }
    assert_eq!(sess.state_elements(), elems, "state grew with context");
    assert_eq!(sess.snapshot().bytes(), elems * 8 + std::mem::size_of::<usize>());
}

#[test]
fn order3_decode_state_is_the_packed_cubic() {
    // the affordability claim behind order 3: per-(layer, head) state is
    // Σ_{j≤3} C(dh+j−1, j) packed features × (1 + dh) — not dh³·dh
    let m = model("ho_tiny_o3", 2);
    let sess = DecodeSession::new(&m).unwrap();
    let dh = m.config().d_model / m.config().n_heads;
    let f = 1 + dh + dh * (dh + 1) / 2 + dh * (dh + 1) * (dh + 2) / 6;
    let per_head = f * (1 + dh);
    assert_eq!(
        sess.state_elements(),
        m.config().n_layers * m.config().n_heads * per_head
    );
}

#[test]
fn snapshot_restore_roundtrip_is_bit_exact() {
    // decode N, snapshot, decode M more, restore, re-decode the same M:
    // identical logits — the slot-preemption guarantee
    let m = model("ho2_tiny", 3);
    let mut sess = DecodeSession::new(&m).unwrap();
    let mut rng = Rng::new(4);
    for _ in 0..6 {
        sess.decode_step(&m, rng.uniform_int(0, 256) as i32).unwrap();
    }
    let snap = sess.snapshot();
    assert_eq!(snap.pos(), 6);
    let cont: Vec<i32> = (0..5).map(|_| rng.uniform_int(0, 256) as i32).collect();
    let first: Vec<Vec<f32>> =
        cont.iter().map(|&t| sess.decode_step(&m, t).unwrap()).collect();
    sess.restore(&snap).unwrap();
    assert_eq!(sess.pos(), 6);
    let second: Vec<Vec<f32>> =
        cont.iter().map(|&t| sess.decode_step(&m, t).unwrap()).collect();
    assert_eq!(first, second, "restore must replay bit-exactly");
}

#[test]
fn executor_decode_matches_forward_per_slot() {
    // the batched executor surface (parallel slot loop included) agrees
    // with the single-sequence forward
    let mut exec = executor("ho2_tiny", 5);
    let t = 10;
    let mut rng = Rng::new(6);
    let n = exec.n_slots();
    let seqs: Vec<Vec<i32>> = (0..n)
        .map(|_| (0..t).map(|_| rng.uniform_int(0, 256) as i32).collect())
        .collect();
    for _ in 0..n {
        exec.alloc_slot().unwrap();
    }
    assert_eq!(exec.free_slots(), 0);
    let v = exec.model().config.vocab_size;
    for pos in 0..t {
        let feed: Vec<i32> = seqs.iter().map(|s| s[pos]).collect();
        let logits = exec.decode_step(&feed).unwrap();
        let lf = logits.as_f32().unwrap();
        for slot in 0..n {
            assert_eq!(exec.pos(slot), pos + 1);
            let toks = Tensor::i32(vec![1, pos + 1], seqs[slot][..pos + 1].to_vec());
            let full = exec.forward_logits(&toks).unwrap();
            let want = &full.as_f32().unwrap()[pos * v..(pos + 1) * v];
            let got = &lf[slot * v..(slot + 1) * v];
            let err = got
                .iter()
                .zip(want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(err <= 1e-4, "slot {slot} pos {pos}: {err}");
        }
    }
}

#[test]
fn parallel_decode_path_matches_forward_on_small_model() {
    // ho2_small crosses the d_model threshold, so 2+ active slots take
    // the scoped-thread fan-out; pin it against the sequential forward
    let mut exec = executor("ho2_small", 13);
    let a = exec.alloc_slot().unwrap();
    let b = exec.alloc_slot().unwrap();
    let t = 3;
    let seqs = [[5i32, 9, 200], [7i32, 300, 11]];
    let v = exec.model().config.vocab_size;
    let mut feed = vec![0i32; exec.n_slots()];
    let mut last = [vec![], vec![]];
    for pos in 0..t {
        feed[a] = seqs[0][pos];
        feed[b] = seqs[1][pos];
        let lg = exec.decode_step(&feed).unwrap();
        let lf = lg.as_f32().unwrap();
        last[0] = lf[a * v..(a + 1) * v].to_vec();
        last[1] = lf[b * v..(b + 1) * v].to_vec();
    }
    for (i, seq) in seqs.iter().enumerate() {
        let toks = Tensor::i32(vec![1, t], seq.to_vec());
        let full = exec.forward_logits(&toks).unwrap();
        let want = &full.as_f32().unwrap()[(t - 1) * v..t * v];
        let err = last[i]
            .iter()
            .zip(want)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(err <= 1e-4, "slot {i}: {err}");
    }
}

#[test]
fn executor_snapshot_restore_via_trait() {
    let mut exec = executor("ho2_tiny", 7);
    let slot = exec.alloc_slot().unwrap();
    let feed = vec![0i32; exec.n_slots()];
    exec.decode_step(&feed).unwrap();
    let snap = exec.snapshot_slot(slot).unwrap();
    exec.decode_step(&feed).unwrap();
    assert_eq!(exec.pos(slot), 2);
    exec.restore_slot(slot, &snap).unwrap();
    assert_eq!(exec.pos(slot), 1);
    // inactive slots have nothing to snapshot
    assert!(exec.snapshot_slot(slot + 1).is_err());
}

#[test]
fn native_engine_serves_synthetic_load_end_to_end() {
    // the acceptance criterion: a server that serves with no artifacts —
    // more requests than the 4 tiny-model slots forces queueing + reuse
    let exec = executor("ho2_tiny", 8);
    let state = exec.state_bytes_per_slot();
    assert!(state > 0);
    let stats = run_synthetic(Box::new(exec), 6, 8, 4, 0, 42).unwrap();
    assert_eq!(stats.completed, 6);
    assert!(stats.generated_tokens > 0);
    // default scheduling chunk-prefills the whole 9-token prompt (BOS+8)
    // in one engine step per request — far fewer steps than the old
    // one-prompt-token-per-step loop, but at least one step per
    // generated-token wave
    assert!(stats.engine_steps >= 2);
    assert_eq!(stats.prefill_tokens, 6 * 9, "every prompt absorbed chunked");
    assert!(stats.tokens_per_sec() > 0.0);
    assert_eq!(stats.backend, "native");
    assert_eq!(stats.model, "ho2_tiny");
    assert_eq!(stats.state_bytes_per_slot, state);
    // stats serialize for results/bench_serve.json
    let j = stats.to_json();
    assert_eq!(j.get("requests_completed").unwrap().as_i64().unwrap(), 6);
    assert!(j.get("tok_per_s").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn native_generator_is_greedy_deterministic() {
    let exec = executor("ho2_tiny", 9);
    let mut gen = Generator::new(Box::new(exec)).unwrap();
    let opts = SampleOpts { temperature: 0.0, top_k: 0, max_tokens: 6 };
    let (a, _) = gen.generate("ab", opts, &mut Rng::new(1)).unwrap();
    let (b, _) = gen.generate("ab", opts, &mut Rng::new(2)).unwrap();
    assert_eq!(a, b, "greedy must ignore the rng");
    assert!(a.len() <= 6);
    // slots are released between generations — repeated calls never leak
    for _ in 0..6 {
        gen.generate("xy", opts, &mut Rng::new(3)).unwrap();
    }
}

#[test]
fn softmax_native_is_forward_only() {
    let exec = executor("softmax_tiny", 10);
    assert!(!exec.supports_decode());
    assert_eq!(exec.state_bytes_per_slot(), 0);
    // forward/eval still works (exact O(n²) attention)
    let toks = Tensor::i32(vec![1, 8], (0..8).collect());
    let logits = exec.forward_logits(&toks).unwrap();
    assert_eq!(logits.shape, vec![1, 8, 272]);
    // but generation is a clear error, not a hang
    assert!(Generator::new(Box::new(exec)).is_err());
}

#[test]
fn native_tcp_server_roundtrip() {
    // JSON-lines over a real socket, engine on the native executor
    use std::io::{BufRead, BufReader, Write};
    const ADDR: &str = "127.0.0.1:18499";
    std::thread::spawn(|| {
        let exec = executor("ho2_tiny", 11);
        holt::coordinator::server::serve_tcp(Box::new(exec), ADDR, 7).unwrap();
    });
    let mut conn = None;
    for _ in 0..100 {
        match std::net::TcpStream::connect(ADDR) {
            Ok(c) => {
                conn = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let mut conn = conn.expect("native server did not come up");
    writeln!(conn, "{}", r#"{"prompt": "hi", "max_tokens": 4}"#).unwrap();
    let mut line = String::new();
    BufReader::new(conn.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    let resp = holt::json::Json::parse(&line).unwrap();
    assert!(resp.get("error").is_none(), "{line}");
    let n = resp.get("n_tokens").unwrap().as_i64().unwrap();
    assert!((0..=4).contains(&n), "n_tokens {n}");
}

#[test]
fn checkpoints_are_backend_portable() {
    // a checkpoint saved from native params loads back through the same
    // spec the artifact path uses (identical names/shapes/order)
    let entry = native_model_entry("ho2_tiny").unwrap();
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(12));
    let ck = holt::checkpoint::Checkpoint {
        step: 5,
        sections: vec![("params".into(), params.clone())],
    };
    let dir = std::env::temp_dir().join("holt_native_ckpt");
    let path = dir.join("m.ckpt");
    ck.save(&path).unwrap();
    let back = holt::checkpoint::Checkpoint::load(&path).unwrap();
    let p = back.section("params").unwrap().clone();
    p.check_spec(&entry.param_spec).unwrap();
    // and it drives the executor
    let exec = NativeExecutor::new(entry, p).unwrap();
    let toks = Tensor::i32(vec![1, 4], vec![1, 2, 3, 4]);
    exec.forward_logits(&toks).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
