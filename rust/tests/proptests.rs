//! Property-based tests (randomized invariants with fixed seeds; the
//! proptest crate is not in the offline vendor set, so these drive our own
//! deterministic PRNG over many cases — shrinking is traded for exact
//! reproducibility).

use holt::checkpoint::Checkpoint;
use holt::coordinator::state::StateManager;
use holt::json::Json;
use holt::kernels::{
    chunked_forward, streaming_forward, HoState, LinearState, RecurrentAttention,
};
use holt::mathref;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{Init, LeafSpec, Tensor};
use holt::tokenizer::{bpe::Bpe, ByteTokenizer};

const CASES: usize = 50;

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(0x1 + 1);
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let pick = rng.uniform_int(0, if depth == 0 { 4 } else { 6 });
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.uniform() < 0.5),
        2 => Json::Num((rng.normal() * 100.0 * 64.0).round() / 64.0),
        3 => {
            let n = rng.uniform_int(0, 12) as usize;
            Json::Str(
                (0..n)
                    .map(|_| {
                        char::from_u32(rng.uniform_int(32, 0x24f) as u32).unwrap_or('x')
                    })
                    .collect(),
            )
        }
        4 => {
            let n = rng.uniform_int(0, 4) as usize;
            Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.uniform_int(0, 4) as usize;
            Json::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_byte_tokenizer_roundtrips_any_string() {
    let mut rng = Rng::new(7);
    let tok = ByteTokenizer::new();
    for _ in 0..CASES {
        let n = rng.uniform_int(0, 64) as usize;
        let s: String = (0..n)
            .map(|_| char::from_u32(rng.uniform_int(1, 0x2ff) as u32).unwrap_or('?'))
            .collect();
        assert_eq!(tok.decode(&tok.encode(&s)), s);
    }
}

#[test]
fn prop_bpe_roundtrips_with_random_corpora() {
    let mut rng = Rng::new(9);
    for _ in 0..20 {
        let corpus: Vec<u8> = (0..200)
            .map(|_| b"abcdef "[rng.uniform_int(0, 7) as usize])
            .collect();
        let bpe = Bpe::train(&corpus, rng.uniform_int(0, 12) as usize);
        let text: Vec<u8> = (0..50)
            .map(|_| b"abcdefgh "[rng.uniform_int(0, 9) as usize])
            .collect();
        assert_eq!(bpe.decode(&bpe.encode(&text)), text);
    }
}

#[test]
fn prop_taylor_exp_bounds() {
    // exp lower/upper bound relations that the paper's figure 1 illustrates:
    // for x >= 0 every truncation underestimates exp; order2 >= order1.
    let mut rng = Rng::new(1);
    for _ in 0..1000 {
        let x = rng.uniform() * 4.0;
        let t1 = mathref::taylor_exp(x, 1);
        let t2 = mathref::taylor_exp(x, 2);
        let t3 = mathref::taylor_exp(x, 3);
        let e = x.exp();
        assert!(t1 <= t2 + 1e-12 && t2 <= t3 + 1e-12 && t3 <= e + 1e-9, "x={x}");
        // even orders are positive everywhere, also for negative x
        assert!(mathref::taylor_exp(-x, 2) > 0.0);
    }
}

#[test]
fn prop_attention_rows_convex_weights() {
    // for every kind: if all v entries are within [lo, hi], outputs are too
    // (row weights are a convex combination)
    let mut rng = Rng::new(2);
    for case in 0..12 {
        let (n, d) = (16, 8);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v: Vec<f32> = (0..n * d).map(|_| rng.uniform() as f32 * 2.0 - 1.0).collect();
        for kind in ["softmax", "ho2", "linear"] {
            let out = mathref::attention_bhnd(kind, &q, &k, &v, 1, n, d, 2, 3.0, true);
            for (i, &x) in out.iter().enumerate() {
                assert!(
                    (-1.0 - 1e-3..=1.0 + 1e-3).contains(&x),
                    "case {case} {kind} out[{i}] = {x}"
                );
            }
        }
    }
}

#[test]
fn prop_attention_permutation_equivariance_noncausal() {
    // non-causal linear/ho2 attention: permuting the key/value rows leaves
    // the outputs unchanged (sums are order-free)
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let (n, d) = (12, 8);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * d, 1.0);
        // rotate rows by 5
        let rot = |x: &[f32]| -> Vec<f32> {
            let mut y = vec![0.0; x.len()];
            for i in 0..n {
                let j = (i + 5) % n;
                y[j * d..(j + 1) * d].copy_from_slice(&x[i * d..(i + 1) * d]);
            }
            y
        };
        let (k2, v2) = (rot(&k), rot(&v));
        let a = mathref::ho_attention(&q, &k, &v, n, n, d, d, 2, 3.0, false, true);
        let b = mathref::ho_attention(&q, &k2, &v2, n, n, d, d, 2, 3.0, false, true);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

#[test]
fn prop_ho_recurrent_and_chunked_match_oracle() {
    // the paper's core identity: the factorized O(n) recurrence (both the
    // streaming decode form and the cache-blocked chunked form) computes
    // the same function as the direct O(n^2) oracle — across random
    // shapes, Taylor orders **0..=3** (order 3 = the generic FeatureMap
    // recurrence with one more packed block), alphas, causality and LN
    let mut rng = Rng::new(51);
    for case in 0..24 {
        let n = rng.uniform_int(1, 65) as usize;
        let d = rng.uniform_int(1, 17) as usize;
        let dv = rng.uniform_int(1, 17) as usize;
        let order = rng.uniform_int(0, 4) as usize;
        let alpha = [1.0, 2.0, 3.0][rng.uniform_int(0, 3) as usize];
        let causal = rng.uniform() < 0.5;
        let normalize = rng.uniform() < 0.5;
        let chunk = rng.uniform_int(1, 33) as usize;
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let oracle =
            mathref::ho_attention(&q, &k, &v, n, n, d, dv, order, alpha, causal, normalize);
        let mut st = HoState::new(d, dv, order, alpha, normalize);
        let stream = streaming_forward(&mut st, &q, &k, &v, n, causal);
        let chunked = chunked_forward(&mut st, &q, &k, &v, n, chunk, causal);
        let es = max_abs_diff(&stream, &oracle);
        let ec = max_abs_diff(&chunked, &oracle);
        assert!(
            es <= 1e-4 && ec <= 1e-4,
            "case {case} (n={n} d={d} dv={dv} order={order} alpha={alpha} causal={causal} \
             ln={normalize} chunk={chunk}): stream {es}, chunked {ec}"
        );
    }
}

#[test]
fn prop_linear_recurrent_matches_oracle() {
    // elu+1 recurrent state == direct first-order linear attention
    let mut rng = Rng::new(52);
    for case in 0..16 {
        let n = rng.uniform_int(1, 65) as usize;
        let d = rng.uniform_int(1, 17) as usize;
        let dv = rng.uniform_int(1, 17) as usize;
        let causal = rng.uniform() < 0.5;
        let chunk = rng.uniform_int(1, 33) as usize;
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        let oracle = mathref::linear_attention(&q, &k, &v, n, n, d, dv, causal);
        let mut st = LinearState::new(d, dv);
        let stream = streaming_forward(&mut st, &q, &k, &v, n, causal);
        let chunked = chunked_forward(&mut st, &q, &k, &v, n, chunk, causal);
        assert!(
            max_abs_diff(&stream, &oracle) <= 1e-4
                && max_abs_diff(&chunked, &oracle) <= 1e-4,
            "case {case} (n={n} d={d} dv={dv} causal={causal} chunk={chunk})"
        );
    }
}

#[test]
fn prop_ho_chunk_size_invariance() {
    // the chunk length is a throughput knob, never a semantics knob
    let mut rng = Rng::new(53);
    let (n, d, dv) = (37, 8, 8);
    let q = rng.normal_vec_f32(n * d, 1.0);
    let k = rng.normal_vec_f32(n * d, 1.0);
    let v = rng.normal_vec_f32(n * dv, 1.0);
    let mut st = HoState::paper(d, dv);
    let want = chunked_forward(&mut st, &q, &k, &v, n, 1, true);
    for chunk in [2, 3, 5, 8, 16, 37, 64, 1000] {
        let got = chunked_forward(&mut st, &q, &k, &v, n, chunk, true);
        let err = max_abs_diff(&want, &got);
        assert!(err <= 1e-5, "chunk {chunk}: {err}");
    }
}

#[test]
fn prop_ho_decode_steps_match_full_forward() {
    // O(1)-per-token decode must reproduce the training-time causal
    // forward column by column — the serving-path guarantee
    let mut rng = Rng::new(54);
    for _ in 0..8 {
        let n = rng.uniform_int(2, 48) as usize;
        let d = rng.uniform_int(2, 12) as usize;
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * d, 1.0);
        let full = mathref::ho_attention(&q, &k, &v, n, n, d, d, 2, 3.0, true, true);
        let mut st = HoState::paper(d, d);
        let mut out = vec![0.0f32; d];
        for i in 0..n {
            st.step(&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d], &v[i * d..(i + 1) * d], &mut out);
            let err = max_abs_diff(&out, &full[i * d..(i + 1) * d]);
            assert!(err <= 1e-4, "pos {i}: {err}");
        }
    }
}

#[test]
fn prop_rng_sample_logits_always_in_topk() {
    let mut rng = Rng::new(4);
    for _ in 0..CASES {
        let n = rng.uniform_int(2, 40) as usize;
        let k = rng.uniform_int(1, n as u64 + 1) as usize;
        let logits: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut ranked: Vec<usize> = (0..n).collect();
        ranked.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let allowed: std::collections::HashSet<usize> =
            ranked[..k].iter().copied().collect();
        for _ in 0..20 {
            let s = rng.sample_logits(&logits, 0.7, k);
            assert!(allowed.contains(&s), "sampled {s} outside top-{k}");
        }
    }
}

#[test]
fn prop_state_manager_random_alloc_release() {
    // random interleavings of alloc/release/advance preserve invariants:
    // no slot double-allocated, freed slots come back zeroed
    let spec = vec![
        LeafSpec { name: "s".into(), shape: vec![6, 3, 4], init: Init::Zeros },
        LeafSpec { name: "z".into(), shape: vec![6, 3], init: Init::Zeros },
    ];
    let mut rng = Rng::new(5);
    let mut sm = StateManager::new(&spec).unwrap();
    let mut held: Vec<usize> = Vec::new();
    for _ in 0..500 {
        match rng.uniform_int(0, 3) {
            0 => {
                if let Some(s) = sm.alloc() {
                    assert!(!held.contains(&s), "double alloc of {s}");
                    // slot must be zeroed
                    let stride: usize = 12;
                    assert!(sm.leaves[0].as_f32().unwrap()
                        [s * stride..(s + 1) * stride]
                        .iter()
                        .all(|&x| x == 0.0));
                    assert_eq!(sm.pos[s], 0);
                    held.push(s);
                }
            }
            1 => {
                if !held.is_empty() {
                    let i = rng.uniform_int(0, held.len() as u64) as usize;
                    let s = held.swap_remove(i);
                    // dirty it before release; next alloc must re-zero
                    sm.leaves[0].as_f32_mut().unwrap()[s * 12] = 1.0;
                    sm.release(s);
                }
            }
            _ => {
                for &s in &held {
                    sm.advance(s);
                }
            }
        }
        assert_eq!(sm.free_slots() + held.len(), 6);
    }
}

#[test]
fn prop_checkpoint_roundtrips_random_stores() {
    let mut rng = Rng::new(6);
    let dir = std::env::temp_dir().join("holt_prop_ckpt");
    for case in 0..10 {
        let n_leaves = rng.uniform_int(1, 6) as usize;
        let spec: Vec<LeafSpec> = (0..n_leaves)
            .map(|i| {
                let rank = rng.uniform_int(0, 4) as usize;
                let shape: Vec<usize> =
                    (0..rank).map(|_| rng.uniform_int(1, 6) as usize).collect();
                LeafSpec {
                    name: format!("leaf{i}"),
                    shape,
                    init: Init::Normal { std: 1.0 },
                }
            })
            .collect();
        let store = ParamStore::init(&spec, &mut rng);
        let ck = Checkpoint {
            step: rng.next_u64() % 10_000,
            sections: vec![("params".into(), store)],
        };
        let path = dir.join(format!("c{case}.ckpt"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.sections[0].1.leaves, ck.sections[0].1.leaves);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prop_snapshot_codec_drift_vs_oracle() {
    // ISSUE-10: park a kernel mid-sequence, round-trip the state through
    // every SnapshotCodec dtype, resume, and bound the worst-case output
    // drift against the O(n^2) oracle — across kinds (linear + ho) and
    // Taylor orders 0..=3.  f64 must stay exactly at the kernel's own
    // oracle error; each narrower dtype gets its measured, test-pinned
    // bound.  Bounds are on |output| drift, the quantity a logit
    // inherits; the model-level drift test lives in model/decode.rs.
    use holt::state::{SnapshotCodec, StateDtype};

    // the trait has no clone, so kinds are factories: every run builds
    // its kernels fresh from the same constructor arguments
    type Make = Box<dyn Fn() -> Box<dyn RecurrentAttention>>;

    // (dtype, absolute output-drift bound vs the f64-resumed run)
    let bounds = [
        (StateDtype::F64, 0.0f32),   // bit-lossless: zero drift, exactly
        (StateDtype::F32, 1e-3),
        (StateDtype::F16, 0.25),
        (StateDtype::Bf16, 1.0),
        (StateDtype::Int8, 1.0),
    ];
    let mut rng = Rng::new(0x51a7e);
    let mut worst = std::collections::HashMap::new();
    for case in 0..16 {
        let n = rng.uniform_int(8, 49) as usize;
        let cut = rng.uniform_int(4, n as u64 / 2 + 2) as usize;
        let d = rng.uniform_int(2, 13) as usize;
        let dv = rng.uniform_int(2, 13) as usize;
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * dv, 1.0);
        // kinds: linear + every Taylor order the oracle covers
        let kernels: Vec<(String, Make)> = (0..=3)
            .map(|o| {
                (
                    format!("ho_o{o}"),
                    Box::new(move || {
                        Box::new(HoState::new(d, dv, o, 3.0, true))
                            as Box<dyn RecurrentAttention>
                    }) as Make,
                )
            })
            .chain(std::iter::once((
                "linear".to_string(),
                Box::new(move || {
                    Box::new(LinearState::new(d, dv)) as Box<dyn RecurrentAttention>
                }) as Make,
            )))
            .collect();
        for (kind, make) in kernels {
            let oracle = if kind == "linear" {
                mathref::linear_attention(&q, &k, &v, n, n, d, dv, true)
            } else {
                let order: usize = kind[4..].parse().unwrap();
                mathref::ho_attention(&q, &k, &v, n, n, d, dv, order, 3.0, true, true)
            };
            // reference run: park at `cut` with the lossless passthrough
            let run = |dtype: StateDtype| -> Vec<f32> {
                let mut st = make();
                let mut out = vec![0.0f32; dv];
                let mut produced = Vec::with_capacity(n * dv);
                for i in 0..cut {
                    st.step(&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv], &mut out);
                    produced.extend_from_slice(&out);
                }
                // park: encode the live state, drop the kernel, decode
                // into a fresh one — the serve-path restore shape
                let mut state = Vec::new();
                st.save_state(&mut state);
                let codec = SnapshotCodec::new(dtype);
                let bytes = codec.encode(&state);
                assert_eq!(bytes.len(), codec.encoded_len(state.len()));
                let restored = codec.decode(&bytes, state.len()).unwrap();
                if dtype == StateDtype::F64 {
                    assert!(
                        state.iter().zip(&restored).all(|(a, b)| a.to_bits() == b.to_bits()),
                        "f64 passthrough must be bit-lossless"
                    );
                }
                let mut st = make();
                st.load_state(&restored);
                for i in cut..n {
                    st.step(&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv], &mut out);
                    produced.extend_from_slice(&out);
                }
                produced
            };
            let via_f64 = run(StateDtype::F64);
            // the f64-resumed run is itself pinned against the oracle
            assert!(
                max_abs_diff(&via_f64, &oracle) <= 1e-4,
                "case {case} {kind}: lossless park/resume broke the oracle pin"
            );
            for (dtype, bound) in bounds {
                let got = run(dtype);
                let drift = max_abs_diff(&got, &via_f64);
                assert!(
                    drift <= bound,
                    "case {case} {kind} {dtype}: park/restore drift {drift} > {bound}"
                );
                let w = worst.entry(dtype.name()).or_insert(0.0f32);
                *w = w.max(drift);
            }
        }
    }
    // the measured hierarchy: wider dtypes drift strictly less (f64
    // exactly zero), which is the whole density-vs-fidelity tradeoff
    assert_eq!(worst["f64"], 0.0);
    assert!(worst["f32"] <= worst["f16"]);
    eprintln!("worst park/restore output drift per dtype: {worst:?}");
}

#[test]
fn prop_tensor_error_metrics_consistent() {
    let mut rng = Rng::new(8);
    for _ in 0..CASES {
        let n = rng.uniform_int(1, 100) as usize;
        let a = Tensor::f32(vec![n], rng.normal_vec_f32(n, 1.0));
        // identical tensors: all error metrics are exactly zero
        assert_eq!(a.max_abs_diff(&a).unwrap(), 0.0);
        assert_eq!(a.mse(&a).unwrap(), 0.0);
        assert_eq!(a.rel_l2(&a).unwrap(), 0.0);
        // perturbation raises all of them
        let mut b = a.clone();
        b.as_f32_mut().unwrap()[0] += 1.0;
        assert!(a.max_abs_diff(&b).unwrap() >= 1.0 - 1e-6);
        assert!(a.mse(&b).unwrap() > 0.0);
        assert!(a.rel_l2(&b).unwrap() > 0.0);
    }
}

#[test]
fn prop_obs_histogram_bucket_invariants() {
    // log2 bucketing invariants over random u64s spanning the full range:
    // every value lands in exactly one bucket whose bounds contain it, and
    // the recorded quantiles bracket the observed min/max
    use holt::obs::{bucket_of, bucket_upper, HistoSnapshot, BUCKETS};
    let mut rng = Rng::new(0x0b5_1);
    for case in 0..CASES {
        let mut s = HistoSnapshot::new();
        let n = rng.uniform_int(1, 65) as usize;
        let (mut want_sum, mut want_min, mut want_max) = (0u64, u64::MAX, 0u64);
        for _ in 0..n {
            // shift by 0..=63 so the bucket checks cover every magnitude
            let raw = rng.next_u64() >> rng.uniform_int(0, 64);
            let i = bucket_of(raw);
            assert!(i < BUCKETS, "case {case}: bucket {i} out of range for {raw}");
            assert!(raw <= bucket_upper(i), "case {case}: {raw} above bucket {i} upper");
            if i > 0 {
                assert!(
                    raw > bucket_upper(i - 1),
                    "case {case}: {raw} not above bucket {} upper",
                    i - 1
                );
            }
            // record a bounded value (< 2^56) so 64 samples cannot
            // overflow the histogram's u64 running sum
            let v = raw >> 8;
            s.record(v);
            want_sum += v;
            want_min = want_min.min(v);
            want_max = want_max.max(v);
        }
        assert_eq!(s.count, n as u64, "case {case}");
        assert_eq!(s.sum, want_sum, "case {case}");
        assert_eq!((s.min, s.max), (want_min, want_max), "case {case}");
        assert_eq!(s.buckets.iter().sum::<u64>(), n as u64, "case {case}");
        // quantiles are monotone in p and clamped to the observed extremes
        assert_eq!(s.quantile(100.0), Some(want_max), "case {case}");
        let mut prev = 0u64;
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            let q = s.quantile(p).unwrap();
            assert!(
                (want_min..=want_max).contains(&q),
                "case {case} p{p}: {q} outside [{want_min}, {want_max}]"
            );
            assert!(q >= prev, "case {case} p{p}: quantile not monotone");
            prev = q;
        }
    }
}

#[test]
fn prop_obs_histogram_merge_associative_and_lossless() {
    // cross-shard aggregation contract: merge is associative and
    // commutative, and merging per-shard snapshots is indistinguishable
    // from having recorded every sample into one histogram
    use holt::obs::HistoSnapshot;
    let mut rng = Rng::new(0x0b5_2);
    for case in 0..CASES {
        let mut pooled = HistoSnapshot::new();
        let mut parts: Vec<HistoSnapshot> = Vec::new();
        for _ in 0..3 {
            let mut s = HistoSnapshot::new();
            // empty parts are legal (an idle shard merges as identity)
            let n = rng.uniform_int(0, 40) as usize;
            for _ in 0..n {
                let v = rng.next_u64() >> rng.uniform_int(16, 64);
                s.record(v);
                pooled.record(v);
            }
            parts.push(s);
        }
        let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(b);
        left.merge(c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right, "case {case}: merge not associative");
        // b ⊕ a == a ⊕ b
        let mut ba = b.clone();
        ba.merge(a);
        ba.merge(c);
        assert_eq!(left, ba, "case {case}: merge not commutative");
        assert_eq!(left, pooled, "case {case}: merged != pooled recording");
        // identity: merging an empty snapshot changes nothing
        let before = left.clone();
        left.merge(&HistoSnapshot::new());
        assert_eq!(left, before, "case {case}: empty merge not identity");
    }
}

#[test]
fn prop_affinity_single_owner_stable_and_bounded() {
    // Router affinity invariants (ISSUE-7): (1) same session_id resolves
    // to the same shard until a migration re-homes it; (2) a session is
    // owned by exactly one shard — its home is either the last rehome
    // target or, once the bounded override map evicts it, the hash home
    // (never a third shard); (3) the override map never exceeds its
    // capacity; (4) re-homing back to the hash home stores nothing.
    use holt::serve::Affinity;
    let mut rng = Rng::new(0xaff1);
    for case in 0..CASES {
        let n_shards = rng.uniform_int(1, 9) as usize;
        let cap = rng.uniform_int(1, 17) as usize;
        let mut aff = Affinity::with_capacity(n_shards, cap);
        // mirror of every rehome issued (unbounded, unlike the map)
        let mut last_target: std::collections::HashMap<String, usize> =
            std::collections::HashMap::new();
        for step in 0..60 {
            let sid = format!("s{}", rng.uniform_int(0, 24));
            let h = aff.home(&sid);
            assert!(h < n_shards, "case {case} step {step}: home out of range");
            assert_eq!(h, aff.home(&sid), "case {case} step {step}: home not stable");
            match last_target.get(&sid) {
                // owned by the last migration target — unless the bounded
                // map evicted the override, which falls back to the hash
                // home (a cache miss, never a third shard)
                Some(&t) => assert!(
                    h == t || h == aff.hash_home(&sid),
                    "case {case} step {step}: home {h} is neither the last \
                     rehome target {t} nor the hash home"
                ),
                None => assert_eq!(h, aff.hash_home(&sid), "case {case} step {step}"),
            }
            if rng.uniform() < 0.5 {
                let to = rng.uniform_int(0, n_shards as u64) as usize;
                aff.rehome(&sid, to);
                assert_eq!(aff.home(&sid), to, "case {case} step {step}: rehome not immediate");
                last_target.insert(sid, to);
            }
            assert!(aff.overrides() <= cap, "case {case} step {step}: override map unbounded");
        }
        // re-homing to the hash home erases rather than stores
        let sid = format!("fresh{case}");
        let before = aff.overrides();
        aff.rehome(&sid, aff.hash_home(&sid));
        assert_eq!(aff.overrides(), before, "case {case}: redundant override stored");
        assert_eq!(aff.home(&sid), aff.hash_home(&sid));
    }
}
