//! The one-forward training contract, proven by counting.
//!
//! `kernels::counters::attn_forwards()` reads the process-global
//! `attn_forwards` counter in the observability registry, so any two
//! concurrently running tests that touch attention would make
//! exact-delta assertions racy.  Rather than forcing a single-test
//! binary, every test here takes `LOCK` first — deltas are measured
//! only while no other test in this binary runs.  (Everything else
//! about fusion — bit-identity per kernel case — lives in
//! grad_check.rs and the kernels::grad unit tests.)

use std::sync::Mutex;

use holt::coordinator::trainer::{NativeTrainer, TrainBackend};
use holt::data;
use holt::kernels::counters;
use holt::model::grad;
use holt::model::presets::param_spec;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{ModelConfig, ModelEntry};

/// Serializes the counter-delta windows.  `unwrap_or_else(into_inner)`:
/// a poisoned lock (another test panicked) must not cascade — each test
/// re-reads the counter baseline itself.
static LOCK: Mutex<()> = Mutex::new(());

fn smoke_entry() -> ModelEntry {
    let config = ModelConfig {
        preset: "smoke".into(),
        vocab_size: holt::tokenizer::VOCAB_SIZE,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_len: 64,
        attn: "ho2".into(),
        order: 2,
        alpha: 3.0,
        impl_: "native".into(),
        train_batch: 4,
        train_len: 32,
        decode_batch: 2,
        state_dtype: Default::default(),
    };
    let spec = param_spec(&config);
    let n_params = spec.iter().map(|l| l.shape.iter().product::<usize>()).sum();
    ModelEntry {
        name: "ho2_smoke".into(),
        config,
        n_params,
        param_spec: spec,
        state_spec: Vec::new(),
        artifacts: std::collections::HashMap::new(),
    }
}

/// One attention "unit" per (sequence, layer, head).
fn units(cfg: &ModelConfig) -> u64 {
    (cfg.train_batch * cfg.n_layers * cfg.n_heads) as u64
}

#[test]
fn fused_path_runs_exactly_one_attention_forward_per_unit() {
    let _lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let entry = smoke_entry();
    let cfg = entry.config.clone();
    let batch = data::make("copy", 13).unwrap().batch(cfg.train_batch, cfg.train_len);
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(13));

    // fused loss+grad: the backward consumes the forward's tape — the
    // forward count IS the unit count
    let c0 = counters::attn_forwards();
    grad::loss_and_grad(&cfg, &params, &batch).unwrap();
    assert_eq!(
        counters::attn_forwards() - c0,
        units(&cfg),
        "fused path must run exactly one attention forward per unit"
    );
}

#[test]
fn replay_path_runs_two_attention_forwards_per_unit() {
    let _lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let entry = smoke_entry();
    let cfg = entry.config.clone();
    let batch = data::make("copy", 13).unwrap().batch(cfg.train_batch, cfg.train_len);
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(13));

    // the pre-fusion path re-runs the forward inside the vjp: twice the
    // forwards for the same numbers
    let c0 = counters::attn_forwards();
    grad::loss_and_grad_replay(&cfg, &params, &batch).unwrap();
    assert_eq!(
        counters::attn_forwards() - c0,
        2 * units(&cfg),
        "replay path must run forward + vjp re-forward per unit"
    );
}

#[test]
fn fusing_the_replay_away_is_bit_free() {
    let _lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let entry = smoke_entry();
    let cfg = entry.config.clone();
    let batch = data::make("copy", 13).unwrap().batch(cfg.train_batch, cfg.train_len);
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(13));

    let (l_fused, g_fused) = grad::loss_and_grad(&cfg, &params, &batch).unwrap();
    let (l_replay, g_replay) = grad::loss_and_grad_replay(&cfg, &params, &batch).unwrap();
    assert_eq!(l_fused.to_bits(), l_replay.to_bits(), "loss drifted");
    for ((name, a), b) in g_fused.names.iter().zip(&g_fused.leaves).zip(&g_replay.leaves) {
        assert_eq!(
            a.as_f32().unwrap(),
            b.as_f32().unwrap(),
            "gradient leaf '{name}' drifted between fused and replay"
        );
    }
}

#[test]
fn train_step_keeps_the_one_forward_contract() {
    let _lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let entry = smoke_entry();
    let cfg = entry.config.clone();
    let batch = data::make("copy", 13).unwrap().batch(cfg.train_batch, cfg.train_len);

    // a whole trainer step (accumulating, data-parallel) keeps the
    // contract: per-sequence gradients are still one forward per unit
    let mut tr = NativeTrainer::from_entry(entry, 13).unwrap();
    tr.accum = 2;
    tr.grad_workers = 2;
    let c0 = counters::attn_forwards();
    tr.train_step(&batch, 1e-3).unwrap();
    assert_eq!(
        counters::attn_forwards() - c0,
        units(&cfg),
        "train_step must run exactly one attention forward per unit"
    );
}
