//! Finite-difference gradient checks for the native training backward.
//!
//! Kernel level: `chunked_attention_vjp` / `softmax_attention_vjp` are
//! checked against central differences of *all-f64* direct oracles
//! (independently written here, LayerNorm included), for every kernel
//! kind × Taylor order 0/1/2/3 × several alphas and chunk sizes.  The
//! f64 oracle makes the FD noise floor ~1e-10, so the 1e-3 tolerance is
//! testing the derivation, not the step size.  Order 3 runs the same
//! generic `PhiState`/`TaylorMap` code as order 2 — these sweeps are
//! what certify the order-3 data point end to end.
//!
//! Model level: the full tiny-transformer `loss_and_grad` is checked
//! against numeric directional derivatives of the f32 loss along the
//! normalized analytic gradient (the standard f32 gradcheck — single
//! coordinates drown in f32 forward noise, the aligned directional
//! derivative does not).

use holt::data::Batch;
use holt::kernels::{
    chunked_attention_vjp, chunked_attention_vjp_reverse, chunked_forward,
    chunked_forward_captured, softmax_attention_vjp, NativeBackend,
};
use holt::model::grad::{forward_logits, loss_and_grad};
use holt::model::presets::param_spec;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{ModelConfig, ModelEntry, Tensor};

const LN_EPS: f64 = 1e-5;

// ---------------------------------------------------------------------------
// f64 oracles (independent of the kernel code under test)
// ---------------------------------------------------------------------------

fn taylor64(x: f64, order: usize) -> f64 {
    let mut acc = 1.0;
    let mut term = 1.0;
    for i in 1..=order {
        term *= x / i as f64;
        acc += term;
    }
    acc
}

fn ln64(rows: &[f64], d: usize) -> Vec<f64> {
    let mut out = rows.to_vec();
    for row in out.chunks_mut(d) {
        let mean = row.iter().sum::<f64>() / d as f64;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d as f64;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
    out
}

fn elu1_64(x: f64) -> f64 {
    if x > 0.0 {
        x + 1.0
    } else {
        x.exp()
    }
}

/// Direct causal oracle for kind ∈ {ho2, linear, softmax}, all f64.
#[allow(clippy::too_many_arguments)]
fn oracle(
    kind: &str,
    q: &[f64],
    k: &[f64],
    v: &[f64],
    n: usize,
    d: usize,
    dv: usize,
    order: usize,
    alpha: f64,
) -> Vec<f64> {
    let mut out = vec![0.0f64; n * dv];
    match kind {
        "ho" | "ho2" => {
            let qn = ln64(q, d);
            let kn = ln64(k, d);
            let scale = 1.0 / (alpha * (d as f64).sqrt());
            for i in 0..n {
                let mut den = 0.0;
                let mut acc = vec![0.0f64; dv];
                for j in 0..=i {
                    let dot: f64 = (0..d).map(|c| qn[i * d + c] * kn[j * d + c]).sum();
                    let w = taylor64(dot * scale, order);
                    den += w;
                    for c in 0..dv {
                        acc[c] += w * v[j * dv + c];
                    }
                }
                let den = den.max(1e-6);
                for c in 0..dv {
                    out[i * dv + c] = acc[c] / den;
                }
            }
        }
        "linear" => {
            for i in 0..n {
                let mut den = 0.0;
                let mut acc = vec![0.0f64; dv];
                for j in 0..=i {
                    let w: f64 = (0..d)
                        .map(|c| elu1_64(q[i * d + c]) * elu1_64(k[j * d + c]))
                        .sum();
                    den += w;
                    for c in 0..dv {
                        acc[c] += w * v[j * dv + c];
                    }
                }
                let den = den.max(1e-6);
                for c in 0..dv {
                    out[i * dv + c] = acc[c] / den;
                }
            }
        }
        "softmax" => {
            let scale = 1.0 / (d as f64).sqrt();
            for i in 0..n {
                let logits: Vec<f64> = (0..=i)
                    .map(|j| scale * (0..d).map(|c| q[i * d + c] * k[j * d + c]).sum::<f64>())
                    .collect();
                let maxv = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let exps: Vec<f64> = logits.iter().map(|&x| (x - maxv).exp()).collect();
                let den: f64 = exps.iter().sum();
                for (j, &e) in exps.iter().enumerate() {
                    for c in 0..dv {
                        out[i * dv + c] += (e / den) * v[j * dv + c];
                    }
                }
            }
        }
        _ => panic!("unknown kind"),
    }
    out
}

// ---------------------------------------------------------------------------
// kernel-level FD harness
// ---------------------------------------------------------------------------

struct Case {
    kind: &'static str,
    order: usize,
    alpha: f64,
    chunk: usize,
}

fn rel_l2(a: &[f32], b: &[f64]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y) * (x as f64 - y))
        .sum();
    let den: f64 = b.iter().map(|&y| y * y).sum();
    (num / den.max(1e-24)).sqrt()
}

fn check_kernel_case(case: &Case, seed: u64) {
    let (n, d, dv) = (11, 5, 4);
    let mut rng = Rng::new(seed);
    let q = rng.normal_vec_f32(n * d, 1.0);
    let k = rng.normal_vec_f32(n * d, 1.0);
    let v = rng.normal_vec_f32(n * dv, 1.0);
    let go = rng.normal_vec_f32(n * dv, 1.0);

    // analytic gradients from the implementation under test
    let (gq, gk, gv) = if case.kind == "softmax" {
        softmax_attention_vjp(&q, &k, &v, n, d, dv, true, &go)
    } else {
        let backend = NativeBackend {
            order: case.order,
            alpha: case.alpha,
            normalize_qk: true,
            chunk: case.chunk,
            evaluation: holt::kernels::Evaluation::Chunked,
            isa: None,
        };
        let mut st = backend.grad_state(case.kind, d, dv).unwrap();
        chunked_attention_vjp(st.as_mut(), &q, &k, &v, n, case.chunk, &go)
    };

    // numeric gradients from the f64 oracle: L = Σ go ⊙ oracle(q, k, v)
    let q64: Vec<f64> = q.iter().map(|&x| x as f64).collect();
    let k64: Vec<f64> = k.iter().map(|&x| x as f64).collect();
    let v64: Vec<f64> = v.iter().map(|&x| x as f64).collect();
    let loss = |q_: &[f64], k_: &[f64], v_: &[f64]| -> f64 {
        let out = oracle(case.kind, q_, k_, v_, n, d, dv, case.order, case.alpha);
        out.iter().zip(&go).map(|(&o, &c)| o * c as f64).sum()
    };
    let eps = 1e-5;
    let fd = |x: &[f64], which: usize| -> Vec<f64> {
        let mut g = vec![0.0f64; x.len()];
        for i in 0..x.len() {
            let mut xp = x.to_vec();
            let mut xm = x.to_vec();
            xp[i] += eps;
            xm[i] -= eps;
            let (lp, lm) = match which {
                0 => (loss(&xp, &k64, &v64), loss(&xm, &k64, &v64)),
                1 => (loss(&q64, &xp, &v64), loss(&q64, &xm, &v64)),
                _ => (loss(&q64, &k64, &xp), loss(&q64, &k64, &xm)),
            };
            g[i] = (lp - lm) / (2.0 * eps);
        }
        g
    };
    let label = format!(
        "{} order={} alpha={} chunk={}",
        case.kind, case.order, case.alpha, case.chunk
    );
    let eq = rel_l2(&gq, &fd(&q64, 0));
    let ek = rel_l2(&gk, &fd(&k64, 1));
    let ev = rel_l2(&gv, &fd(&v64, 2));
    assert!(eq <= 1e-3, "{label}: dq rel err {eq:.2e}");
    assert!(ek <= 1e-3, "{label}: dk rel err {ek:.2e}");
    assert!(ev <= 1e-3, "{label}: dv rel err {ev:.2e}");
}

#[test]
fn ho_kernel_gradients_match_fd_all_orders() {
    // the acceptance grid: orders 0 through 3, two alphas, chunk sizes
    // spanning pure-recurrent (1) to single-chunk (64 > n)
    let mut seed = 100;
    for order in [0, 1, 2, 3] {
        for alpha in [1.0, 3.0] {
            for chunk in [1, 3, 64] {
                check_kernel_case(&Case { kind: "ho2", order, alpha, chunk }, seed);
                seed += 1;
            }
        }
    }
}

#[test]
fn ho_kind_alias_gradients_agree() {
    // "ho" and "ho2" are the same TaylorMap — spot-check the new
    // spelling through the grad path too
    check_kernel_case(&Case { kind: "ho", order: 3, alpha: 3.0, chunk: 4 }, 400);
}

#[test]
fn linear_kernel_gradients_match_fd() {
    for (i, chunk) in [1, 4, 64].into_iter().enumerate() {
        check_kernel_case(
            &Case { kind: "linear", order: 0, alpha: 1.0, chunk },
            200 + i as u64,
        );
    }
}

#[test]
fn softmax_gradients_match_fd() {
    check_kernel_case(&Case { kind: "softmax", order: 0, alpha: 1.0, chunk: 0 }, 300);
}

// ---------------------------------------------------------------------------
// fused (capture + reverse) vs replay — bit identity
// ---------------------------------------------------------------------------

#[test]
fn fused_capture_reverse_is_bit_identical_to_replay_vjp() {
    // The one-forward training path must not change a single bit: for
    // every kernel kind × Taylor order 0-3 (+ the elu/linear kernel) ×
    // chunk size, (a) the capture forward's outputs equal the plain
    // chunked forward exactly, and (b) reverse-from-tape gradients
    // equal the wrapper's forward-then-reverse gradients exactly.
    let (n, d, dv) = (13, 5, 4);
    let mut seed = 500u64;
    for (kind, order) in
        [("ho2", 0), ("ho2", 1), ("ho2", 2), ("ho2", 3), ("linear", 0)]
    {
        for chunk in [1usize, 4, 64] {
            let mut rng = Rng::new(seed);
            seed += 1;
            let q = rng.normal_vec_f32(n * d, 1.0);
            let k = rng.normal_vec_f32(n * d, 1.0);
            let v = rng.normal_vec_f32(n * dv, 1.0);
            let go = rng.normal_vec_f32(n * dv, 1.0);
            let backend = NativeBackend {
                order,
                alpha: 3.0,
                normalize_qk: true,
                chunk,
                evaluation: holt::kernels::Evaluation::Chunked,
                isa: None,
            };
            let label = format!("{kind} order={order} chunk={chunk}");

            let mut st_fwd = backend.grad_state(kind, d, dv).unwrap();
            let plain = chunked_forward(st_fwd.as_mut(), &q, &k, &v, n, chunk, true);

            let mut st = backend.grad_state(kind, d, dv).unwrap();
            let (out, cap) = chunked_forward_captured(st.as_mut(), &q, &k, &v, n, chunk);
            assert_eq!(out, plain, "{label}: capture forward drifted");
            let (gq, gk, gv) =
                chunked_attention_vjp_reverse(st.as_mut(), &cap, &q, &k, &v, &go);

            let mut st2 = backend.grad_state(kind, d, dv).unwrap();
            let (rq, rk, rv) =
                chunked_attention_vjp(st2.as_mut(), &q, &k, &v, n, chunk, &go);
            assert_eq!(gq, rq, "{label}: dq drifted from replay");
            assert_eq!(gk, rk, "{label}: dk drifted from replay");
            assert_eq!(gv, rv, "{label}: dv drifted from replay");
        }
    }
}

// ---------------------------------------------------------------------------
// model-level directional FD
// ---------------------------------------------------------------------------

fn tiny_entry(attn: &str, order: usize) -> ModelEntry {
    let config = ModelConfig {
        preset: "fdtest".into(),
        vocab_size: 48,
        d_model: 16,
        n_heads: 2,
        n_layers: 2,
        d_ff: 32,
        max_len: 32,
        attn: attn.into(),
        order,
        alpha: 3.0,
        impl_: "native".into(),
        train_batch: 2,
        train_len: 8,
        decode_batch: 2,
        state_dtype: Default::default(),
    };
    let spec = param_spec(&config);
    let n_params = spec.iter().map(|l| l.shape.iter().product::<usize>()).sum();
    ModelEntry {
        name: format!("{attn}_fdtest_o{order}"),
        config,
        n_params,
        param_spec: spec,
        state_spec: Vec::new(),
        artifacts: std::collections::HashMap::new(),
    }
}

fn fd_batch(rng: &mut Rng, b: usize, t: usize, vocab: usize) -> Batch {
    let tokens: Vec<i32> = (0..b * t)
        .map(|_| rng.uniform_int(0, vocab as u64) as i32)
        .collect();
    let targets: Vec<i32> = (0..b * t)
        .map(|_| rng.uniform_int(0, vocab as u64) as i32)
        .collect();
    let weights: Vec<f32> = (0..b * t)
        .map(|_| if rng.uniform() > 0.3 { 1.0 } else { 0.0 })
        .collect();
    Batch {
        tokens: Tensor::i32(vec![b, t], tokens),
        targets: Tensor::i32(vec![b, t], targets),
        weights: Tensor::f32(vec![b, t], weights),
    }
}

fn batch_loss(entry: &ModelEntry, params: &ParamStore, batch: &Batch) -> f64 {
    let cfg = &entry.config;
    let (b, t) = (batch.batch_size(), batch.seq_len());
    let logits = forward_logits(cfg, params, batch.tokens.as_i32().unwrap(), b, t).unwrap();
    let targets = batch.targets.as_i32().unwrap();
    let weights = batch.weights.as_f32().unwrap();
    let v = cfg.vocab_size;
    let mut wsum = 0.0f64;
    let mut loss = 0.0f64;
    for i in 0..b * t {
        let w = weights[i] as f64;
        if w == 0.0 {
            continue;
        }
        let row = &logits[i * v..(i + 1) * v];
        let maxv = row.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x)) as f64;
        let z: f64 = row.iter().map(|&x| (x as f64 - maxv).exp()).sum();
        loss += w * (maxv + z.ln() - row[targets[i] as usize] as f64);
        wsum += w;
    }
    loss / wsum.max(1.0)
}

fn check_model_directional(attn: &str, order: usize, seed: u64) {
    let entry = tiny_entry(attn, order);
    let mut rng = Rng::new(seed);
    let params = ParamStore::init(&entry.param_spec, &mut rng);
    let batch = fd_batch(&mut rng, entry.config.train_batch, entry.config.train_len, 48);

    let (loss, grads) = loss_and_grad(&entry.config, &params, &batch).unwrap();
    let re_loss = batch_loss(&entry, &params, &batch);
    assert!(
        (loss - re_loss).abs() < 1e-6,
        "{attn} o{order}: loss_and_grad loss {loss} vs recomputed {re_loss}"
    );

    // direction u = g / ||g||; analytic directional derivative = ||g||
    let gnorm: f64 = grads
        .leaves
        .iter()
        .map(|l| l.as_f32().unwrap().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
        .sum::<f64>()
        .sqrt();
    assert!(gnorm > 1e-3, "{attn} o{order}: degenerate gradient {gnorm}");
    let eps = 1e-3;
    let perturb = |sign: f64| -> ParamStore {
        let mut p = params.clone();
        for (leaf, g) in p.leaves.iter_mut().zip(&grads.leaves) {
            let dst = leaf.as_f32_mut().unwrap();
            for (x, &gv) in dst.iter_mut().zip(g.as_f32().unwrap()) {
                *x += (sign * eps * (gv as f64) / gnorm) as f32;
            }
        }
        p
    };
    let lp = batch_loss(&entry, &perturb(1.0), &batch);
    let lm = batch_loss(&entry, &perturb(-1.0), &batch);
    let numeric = (lp - lm) / (2.0 * eps);
    let rel = (numeric - gnorm).abs() / numeric.abs().max(1e-12);
    assert!(
        rel <= 1e-3,
        "{attn} o{order}: directional derivative {numeric:.6} vs ||g|| {gnorm:.6} (rel {rel:.2e})"
    );
}

#[test]
fn model_gradients_match_directional_fd_ho2_all_orders() {
    // orders 0-3 through the full transformer backward (order 3 at the
    // fdtest head dim 8 is 165 packed features — cheap)
    for order in [0, 1, 2, 3] {
        check_model_directional("ho2", order, 7 + order as u64);
    }
}

#[test]
fn model_gradients_match_directional_fd_linear_and_softmax() {
    check_model_directional("linear", 2, 21);
    check_model_directional("softmax", 2, 22);
}
