//! E2E validation driver (experiment E3): train the same transformer with
//! the paper's ho2 attention and both baselines on a real small workload,
//! logging loss curves for EXPERIMENTS.md.
//!
//!   cargo run --release --example train_lm [-- steps task model1,model2,.. backend]
//!
//! Defaults: 300 steps of the char-LM task on ho2_small + softmax_small +
//! linear_small (~3.3M params each), on the native backend (hand-derived
//! O(n) backward — no artifacts, no Python).  Pass `artifact` as the 4th
//! argument to run through the fused PJRT train step instead.  Loss
//! histories land in results/e3_loss_<model>_<task>.jsonl, a summary
//! table on stdout.

use holt::config::TrainConfig;
use holt::coordinator::trainer::{run_training, ArtifactTrainer, NativeTrainer, TrainBackend};
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let task = args.get(1).cloned().unwrap_or_else(|| "charlm".into());
    let models: Vec<String> = args
        .get(2)
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| {
            vec!["ho2_small".into(), "softmax_small".into(), "linear_small".into()]
        });
    let backend = args.get(3).map(|s| s.as_str()).unwrap_or("native").to_string();

    let rt = if backend == "artifact" {
        Some(Runtime::new(&holt::default_artifacts_dir()?)?)
    } else {
        None
    };
    let mut summary = Vec::new();
    for model in &models {
        let cfg = TrainConfig {
            model: model.clone(),
            task: task.clone(),
            steps,
            lr: 3e-4,
            warmup: 20,
            seed: 42,
            log_every: 10,
            eval_every: 50,
            ckpt_every: steps, // final checkpoint only
            out_dir: "results".into(),
            ..Default::default()
        };
        println!("\n=== {model} [{backend}] on {task} for {steps} steps ===");
        let mut trainer: Box<dyn TrainBackend> = match &rt {
            None => Box::new(NativeTrainer::new(model, cfg.seed)?),
            Some(rt) => Box::new(ArtifactTrainer::new(rt, model, cfg.seed)?),
        };
        let t0 = std::time::Instant::now();
        let hist = run_training(trainer.as_mut(), &cfg, false)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = hist.first().map(|s| s.loss).unwrap_or(f32::NAN);
        let last10: f32 = hist.iter().rev().take(10).map(|s| s.loss).sum::<f32>()
            / 10f32.min(hist.len() as f32);
        summary.push((model.clone(), first, last10, wall));
        // rename the jsonl to the E3 naming convention
        let src = format!("results/train_{model}_{task}.jsonl");
        let dst = format!("results/e3_loss_{model}_{task}.jsonl");
        std::fs::rename(&src, &dst).ok();
    }

    println!("\n=== E3 summary ({task}, {steps} steps, {backend}) ===");
    println!("{:<16} {:>12} {:>14} {:>10}", "model", "first loss", "last-10 loss", "wall (s)");
    for (m, f, l, w) in &summary {
        println!("{m:<16} {f:>12.4} {l:>14.4} {w:>10.1}");
    }
    println!("\nloss curves: results/e3_loss_<model>_{task}.jsonl");
    Ok(())
}
