//! E2E validation driver (experiment E3): train the same transformer with
//! the paper's ho2 attention and both baselines on a real small workload,
//! logging loss curves for EXPERIMENTS.md.
//!
//!   cargo run --release --example train_lm [-- steps task model1,model2,..]
//!
//! Defaults: 300 steps of the char-LM task on ho2_small + softmax_small +
//! linear_small (~3.3M params each).  Loss histories land in
//! results/e3_loss_<model>_<task>.jsonl, a summary table on stdout.

use holt::config::TrainConfig;
use holt::coordinator::trainer::run_training;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let task = args.get(1).cloned().unwrap_or_else(|| "charlm".into());
    let models: Vec<String> = args
        .get(2)
        .map(|s| s.split(',').map(String::from).collect())
        .unwrap_or_else(|| {
            vec!["ho2_small".into(), "softmax_small".into(), "linear_small".into()]
        });

    let rt = Runtime::new(&holt::default_artifacts_dir()?)?;
    let mut summary = Vec::new();
    for model in &models {
        let cfg = TrainConfig {
            model: model.clone(),
            task: task.clone(),
            steps,
            lr: 3e-4,
            warmup: 20,
            seed: 42,
            log_every: 10,
            eval_every: 50,
            ckpt_every: steps, // final checkpoint only
            out_dir: "results".into(),
            ..Default::default()
        };
        println!("\n=== {model} on {task} for {steps} steps ===");
        let t0 = std::time::Instant::now();
        let hist = run_training(&rt, &cfg, false)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = hist.first().map(|s| s.loss).unwrap_or(f32::NAN);
        let last10: f32 = hist.iter().rev().take(10).map(|s| s.loss).sum::<f32>()
            / 10f32.min(hist.len() as f32);
        summary.push((model.clone(), first, last10, wall));
        // rename the jsonl to the E3 naming convention
        let src = format!("results/train_{model}_{task}.jsonl");
        let dst = format!("results/e3_loss_{model}_{task}.jsonl");
        std::fs::rename(&src, &dst).ok();
    }

    println!("\n=== E3 summary ({task}, {steps} steps) ===");
    println!("{:<16} {:>12} {:>14} {:>10}", "model", "first loss", "last-10 loss", "wall (s)");
    for (m, f, l, w) in &summary {
        println!("{m:<16} {f:>12.4} {l:>14.4} {w:>10.1}");
    }
    println!("\nloss curves: results/e3_loss_<model>_{task}.jsonl");
    Ok(())
}
