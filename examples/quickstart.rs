//! Quickstart — the paper end to end with **zero setup**: no PJRT
//! artifacts, no Python, nothing but `cargo run`.
//!
//!   cargo run --release --example quickstart
//!
//! Steps:
//!  1. cross-check the native O(n) kernels (streaming decode form and
//!     cache-blocked chunked form) against the independent O(n²) oracle,
//!  2. show the O(1)-per-token decode claim: per-token latency and state
//!     size flat in context length, while the quadratic oracle grows,
//!  3. E1 headline on random data: order-2 beats order-1 beats order-0
//!     at every alpha,
//!  4. point at the optional PJRT artifact path.

use std::time::Instant;

use holt::experiments;
use holt::kernels::{HoState, NativeBackend, RecurrentAttention};
use holt::mathref;
use holt::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== HOLT quickstart (native O(n) kernels, no artifacts) ==\n");

    println!("[1/3] native kernels vs independent O(n^2) oracle");
    for kind in ["ho", "linear"] {
        let err = experiments::crosscheck_native(kind, 0, 1e-4)?;
        let scope = if kind == "ho" { "orders 0-3, " } else { "" };
        println!(
            "  {kind:<8} {scope}streaming + chunked, causal + non-causal   \
             max|diff| = {err:.2e}  OK"
        );
    }

    println!("\n[2/3] O(1)-per-token decode: cost flat in context length");
    let (d, dv) = (64, 64);
    let mut rng = Rng::new(7);
    let mut state = HoState::paper(d, dv);
    let mut out = vec![0.0f32; dv];
    println!(
        "  recurrent state: {} f64 = {:.1} KiB, independent of context",
        state.state_elements(),
        state.state_elements() as f64 * 8.0 / 1024.0
    );
    println!(
        "  {:>10} {:>16} {:>22}",
        "context", "native us/tok", "oracle us/tok (~ctx)"
    );
    for ctx in [256usize, 1024, 4096] {
        // native: decode `ctx` tokens through the recurrence, report the
        // cost of the *last* 64 (i.e. at full context depth)
        let q = rng.normal_vec_f32(ctx * d, 1.0);
        let k = rng.normal_vec_f32(ctx * d, 1.0);
        let v = rng.normal_vec_f32(ctx * dv, 1.0);
        state.reset();
        for i in 0..ctx - 64 {
            state.step(&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv], &mut out);
        }
        let t0 = Instant::now();
        for i in ctx - 64..ctx {
            state.step(&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d], &v[i * dv..(i + 1) * dv], &mut out);
        }
        let native_us = t0.elapsed().as_secs_f64() * 1e6 / 64.0;
        // oracle: one more token costs a fresh pass over the whole prefix
        let t0 = Instant::now();
        let _ = std::hint::black_box(mathref::ho_attention(
            &q[(ctx - 1) * d..ctx * d],
            &k,
            &v,
            1,
            ctx,
            d,
            dv,
            2,
            3.0,
            false,
            true,
        ));
        let oracle_us = t0.elapsed().as_secs_f64() * 1e6;
        println!("  {ctx:>10} {native_us:>16.1} {oracle_us:>22.1}");
    }

    println!("\n[3/3] E1 — Taylor-order ablation on random data (paper section 3)");
    let rows = experiments::approx_quality_native(0, 256, 64)?;
    println!("  {:>6} {:>6} {:>14}", "alpha", "order", "rel_l2_error");
    for r in rows.iter().filter(|r| r.alpha == 3.0) {
        println!("  {:>6} {:>6} {:>14.4}", r.alpha, r.order, r.rel_err_vs_target);
    }

    // and the batched entry point the benches use
    let be = NativeBackend::paper();
    let (bh, n) = (4, 128);
    let q = rng.normal_vec_f32(bh * n * 32, 1.0);
    let k = rng.normal_vec_f32(bh * n * 32, 1.0);
    let v = rng.normal_vec_f32(bh * n * 32, 1.0);
    let o = be.attention_bhnd("ho2", &q, &k, &v, bh, n, 32, true)?;
    println!("\n  NativeBackend::attention_bhnd: (bh={bh}, n={n}, d=32) -> {} outputs", o.len());

    println!(
        "\nquickstart OK — native path only. For the PJRT artifact path\n\
         (AOT-lowered jax model, training + serving coordinator) see README.md;\n\
         `holt --help` lists the full CLI."
    );
    Ok(())
}
