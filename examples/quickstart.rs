//! Quickstart: load the AOT artifacts, prove the three-layer stack
//! composes, and run the paper's attention end to end.
//!
//!   cargo run --release --example quickstart
//!
//! Steps:
//!  1. open the PJRT runtime over `artifacts/` (built by `make artifacts`),
//!  2. cross-check the Pallas-kernel artifact (L1) and the fused-jnp
//!     artifact (L2) against an independent pure-rust oracle (L3),
//!  3. run a fresh tiny model forward and one training step,
//!  4. print the E1 headline: order-2 beats order-1 beats order-0.

use holt::coordinator::trainer::Trainer;
use holt::data;
use holt::experiments;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&holt::default_artifacts_dir())?;
    println!("== HOLT quickstart (platform: {}) ==\n", rt.platform());

    println!("[1/3] artifact cross-checks vs pure-rust reference");
    for art in ["attn_ho2_n256", "attn_ho2_n256_pallas"] {
        let err = experiments::crosscheck_attention(&rt, art, 0, 5e-4)?;
        println!("  {art:<28} max|diff| = {err:.2e}  OK");
    }

    println!("\n[2/3] fresh ho2_tiny model: forward + one train step");
    let mut trainer = Trainer::new(&rt, "ho2_tiny", 42)?;
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("copy", 42)?;
    let batch = gen.batch(b, t);
    let logits = trainer.forward(&batch)?;
    println!("  forward: logits {:?}", logits.shape);
    let s = trainer.train_step(&batch, 3e-4)?;
    println!("  train:   loss {:.4} in {:.0} ms", s.loss, s.step_time_s * 1e3);

    println!("\n[3/3] E1 — Taylor-order ablation on random data (paper section 3)");
    let rows = experiments::approx_quality(&rt, 0)?;
    println!("  {:>6} {:>6} {:>14}", "alpha", "order", "rel_l2_error");
    for r in rows.iter().filter(|r| r.alpha == 3.0) {
        println!("  {:>6} {:>6} {:>14.4}", r.alpha, r.order, r.rel_err_vs_target);
    }
    println!("\nquickstart OK — see `holt --help` for the full CLI");
    Ok(())
}
