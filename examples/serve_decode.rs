//! Serving demo: continuous batching with O(1)-per-sequence state, pure
//! Rust — the paper's "transformers are RNNs" serving story with **zero
//! setup** (no artifacts, no PJRT, no Python).
//!
//!   cargo run --release --example serve_decode [-- n_requests max_tokens]
//!
//! Drives the same synthetic load (corpus prompts, staggered arrivals)
//! through `ho2_tiny` and `linear_tiny` native engines and prints
//! throughput, TTFT and per-request latency, plus the per-slot state
//! footprint.  (The softmax baseline has no constant-size recurrent
//! state — its decode needs the artifact backend's KV cache, which is the
//! comparison's whole point.)

use holt::coordinator::server::run_synthetic_opts;
use holt::model::{native_model_entry, Executor, NativeExecutor};
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::serve::ServeOpts;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_tokens: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    println!("== continuous-batching serve demo (native backend) ==");
    println!("load: {n_requests} requests, 24-byte prompts, {max_tokens} max tokens\n");

    for model in ["ho2_tiny", "linear_tiny"] {
        let mk = || -> anyhow::Result<NativeExecutor> {
            let entry = native_model_entry(model)?;
            let params = ParamStore::init(&entry.param_spec, &mut Rng::new(1));
            Ok(NativeExecutor::new(entry, params)?)
        };
        let exec = mk()?;
        let state = exec.state_bytes_per_slot();
        let stats =
            run_synthetic_opts(Box::new(exec), n_requests, 24, max_tokens, 2, 7, ServeOpts::default())?;
        // the same load with prompts streamed one token per engine step —
        // what serving cost before the chunked-prefill scheduler
        let tat = run_synthetic_opts(
            Box::new(mk()?),
            n_requests,
            24,
            max_tokens,
            2,
            7,
            ServeOpts { prefill_chunk: 1, ..ServeOpts::default() },
        )?;
        println!("--- {model} ---");
        println!(
            "  state/slot: {state} bytes ({:.1} KiB)  (constant in context length)",
            state as f64 / 1024.0
        );
        println!("  {}", stats.report().replace('\n', "\n  "));
        println!(
            "  vs token-at-a-time prefill: {:.1} tok/s over {} engine steps\n",
            tat.tokens_per_sec(),
            tat.engine_steps
        );
    }
    println!(
        "note: tiny random-weight models on CPU — compare shapes, not absolutes.\n\
         softmax has no O(1) recurrent state; serve it via --backend artifact."
    );
    Ok(())
}
