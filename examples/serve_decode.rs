//! Serving demo: continuous batching over the O(1)-state decode artifact,
//! with the softmax KV-cache model as the baseline — the paper's
//! "transformers are RNNs" serving story, measured.
//!
//!   cargo run --release --example serve_decode [-- n_requests max_tokens]
//!
//! Drives the same synthetic load (corpus prompts, staggered arrivals)
//! through `ho2_tiny` and `softmax_tiny` engines and prints throughput,
//! TTFT and per-request latency, plus the per-slot state footprint.

use holt::coordinator::server::run_synthetic;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_tokens: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let rt = Runtime::new(&holt::default_artifacts_dir()?)?;
    println!("== continuous-batching serve demo ==");
    println!("load: {n_requests} requests, 24-byte prompts, {max_tokens} max tokens\n");

    for model in ["ho2_tiny", "linear_tiny", "softmax_tiny"] {
        let entry = rt.manifest.model(model)?;
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(1));
        let state_per_slot: usize = entry
            .state_spec
            .iter()
            .map(|s| s.shape[1..].iter().product::<usize>())
            .sum();
        let stats =
            run_synthetic(&rt, model, params, n_requests, 24, max_tokens, 2, 7)?;
        println!("--- {model} ---");
        println!(
            "  state/slot: {state_per_slot} f32 ({:.1} KiB){}",
            state_per_slot as f64 * 4.0 / 1024.0,
            if entry.config.attn == "softmax" {
                format!("  (KV cache, grows with ctx {})", entry.config.max_len)
            } else {
                "  (constant in context length)".to_string()
            }
        );
        println!("  {}\n", stats.report().replace('\n', "\n  "));
    }
    println!("note: tiny models on CPU PJRT — compare shapes, not absolutes.");
    Ok(())
}
