//! E1 — approximation quality of the Taylor-expanded attention, the
//! experiment the paper describes as "tested on random data" (section 2).
//!
//!   cargo run --release --example approx_quality [-- seeds]
//!
//! Runs the grid over several random q/k/v draws and reports mean
//! relative-L2 error of every (alpha, order) point against (a) its own
//! alpha-rescaled LN-softmax target and (b) standard softmax attention.
//! Writes results/e1_approx.csv.
//!
//! Uses the `approx_n256` artifact (256 tokens, 4 heads, d=64) when an
//! artifacts directory exists, else falls back to the native O(n)
//! kernels over a single (256, 64) head — same grid and same qualitative
//! ordering, but single-head, so the absolute numbers differ.

use holt::experiments;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let seeds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);
    let rt = match holt::default_artifacts_dir().and_then(|d| Runtime::new(&d)) {
        Ok(rt) => Some(rt),
        Err(e) => {
            println!("(no artifact runtime: {e}\n -> using the native O(n) kernels)\n");
            None
        }
    };

    // average over seeds
    let mut acc: Vec<experiments::ApproxRow> = Vec::new();
    for seed in 0..seeds as u64 {
        let rows = match &rt {
            Some(rt) => experiments::approx_quality(rt, seed)?,
            None => experiments::approx_quality_native(seed, 256, 64)?,
        };
        if acc.is_empty() {
            acc = rows;
        } else {
            for (a, r) in acc.iter_mut().zip(rows) {
                a.rel_err_vs_target += r.rel_err_vs_target;
                a.rel_err_vs_std += r.rel_err_vs_std;
            }
        }
    }
    for a in &mut acc {
        a.rel_err_vs_target /= seeds as f64;
        a.rel_err_vs_std /= seeds as f64;
    }

    println!("E1 — approximation quality, mean over {seeds} random draws");
    if rt.is_some() {
        println!("(256 tokens, 4 heads, d=64; non-causal; LN + alpha rescaling as paper §3)\n");
    } else {
        println!("(native kernels: 256 tokens, 1 head, d=64; non-causal; LN + alpha rescaling)\n");
    }
    println!(
        "{:>6} {:>6} {:>18} {:>18}",
        "alpha", "order", "rel_err_vs_target", "rel_err_vs_std"
    );
    let mut last_alpha = f64::NAN;
    for r in &acc {
        if r.alpha != last_alpha && !last_alpha.is_nan() {
            println!();
        }
        last_alpha = r.alpha;
        println!(
            "{:>6} {:>6} {:>18.4} {:>18.4}",
            r.alpha, r.order, r.rel_err_vs_target, r.rel_err_vs_std
        );
    }

    let csv = experiments::approx_rows_csv(&acc);
    let path =
        experiments::write_results(std::path::Path::new("results"), "e1_approx.csv", &csv)?;
    println!("\nwrote {path:?}");
    println!(
        "\nreading: higher order => lower error at every alpha (the paper's claim —\n\
         the native grid adds order 3, the point the paper never ran);\n\
         larger alpha => smaller logits => better Taylor fit, at the cost of a\n\
         flatter attention distribution (err_vs_std grows with alpha)."
    );
    Ok(())
}
