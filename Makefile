# Build-time entry points. Only the artifact path needs python/jax;
# tier-1 (`cargo build --release && cargo test -q`) never touches this.

.PHONY: artifacts tier1

# AOT-lower the jax model + attention kernels to HLO-text artifacts
# under ./artifacts (manifest.json + *.hlo). Requires python3 + jax.
artifacts:
	python3 python/compile/aot.py --out artifacts

tier1:
	cargo build --release && cargo test -q
