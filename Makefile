# Build-time entry points. Only the artifact path needs python/jax;
# tier-1 (`cargo build --release && cargo test -q`) never touches this.

.PHONY: artifacts tier1 train-smoke train-bench serve-smoke serve-sharded-smoke bench-kernels state-smoke

# AOT-lower the jax model + attention kernels to HLO-text artifacts
# under ./artifacts (manifest.json + *.hlo). Requires python3 + jax.
artifacts:
	python3 python/compile/aot.py --out artifacts

tier1:
	cargo build --release && cargo test -q

# native training smoke (no artifacts): 40 AdamW steps through the
# hand-derived backward must drop the loss to <= 85% of its start
train-smoke:
	cargo run --release -- train --backend native --model ho2_tiny \
	  --task copy --steps 40 --log-every 10 --eval-every 0 --min-loss-ratio 0.85

# train throughput bench: per-attention AdamW steps, the long-context
# (4k-32k token) fused-vs-replay backward comparison and grad-worker
# scaling; writes results/bench_train.json (one object: steps /
# long_context / worker_scaling)
train-bench:
	cargo bench --bench train_throughput -- tiny
	@cat results/bench_train.json

# kernel cost-model bench: scaling sweep + feature-map sweep with the
# scalar-vs-SIMD tok/s comparison; writes results/bench_kernels.json
# (HOLT_SIMD=scalar|unrolled|avx2 overrides the detected lane path)
bench-kernels:
	cargo bench --bench native_scaling -- 512
	@cat results/bench_kernels.json

# serve-scheduler smoke (no artifacts): synthetic overload through the
# fair-share policy with preemption and 2-turn session reuse; writes the
# chunked-vs-token-at-a-time comparison to results/bench_serve.json
serve-smoke:
	cargo run --release -- serve --backend native --model ho2_tiny \
	  --synthetic --requests 12 --prompt-len 24 --max-tokens 8 \
	  --policy fair --preempt-tokens 4 --turns 2 \
	  --metrics-log results/serve_metrics.jsonl

# compact-state smoke (no artifacts): serve with f16 session snapshots
# under a 4 MiB/shard byte budget (bench_serve.json reports state_dtype,
# sessions_per_gib and the park/restore histograms), then train a few
# steps with checkpointing on and verify the container-v2 file loads
# through the zero-copy mmap reader by resuming from it
state-smoke:
	cargo run --release -- serve --backend native --model ho2_tiny \
	  --synthetic --requests 12 --prompt-len 24 --max-tokens 8 \
	  --policy fair --turns 2 --state-dtype f16 --session-cache-mb 4
	grep -q '"sessions_per_gib"' results/bench_serve.json
	grep -q '"state_dtype"' results/bench_serve.json
	cargo run --release -- train --backend native --model ho2_tiny \
	  --task copy --steps 8 --log-every 4 --eval-every 0 \
	  --ckpt-every 4 --out results/state-smoke
	cargo run --release -- ckpt-info \
	  --ckpt results/state-smoke/ho2_tiny_copy.ckpt | grep 'container v2'
	cargo run --release -- train --backend native --model ho2_tiny \
	  --task copy --steps 4 --log-every 2 --eval-every 0 \
	  --resume results/state-smoke/ho2_tiny_copy.ckpt --out results/state-smoke

# multi-shard overload bench: Zipf session reuse over 4 engine shards
# behind the session router (snapshot migration + load shedding); writes
# the shard_overload record (per-shard + aggregate p50/p95/p99, tok/s,
# migrations, rejections, N-vs-1 speedup) to results/bench_serve.json
serve-sharded-smoke:
	cargo run --release -- serve --backend native --model ho2_tiny \
	  --synthetic --shards 4 --requests 48 --sessions 12 \
	  --prompt-len 16 --max-tokens 8 --policy fair
