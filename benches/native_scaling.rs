//! E2, native edition — the complexity claim measured with zero setup:
//! wall-clock of the native O(n) kernels vs the direct O(n²) oracle as
//! sequence length doubles.  No artifacts, no PJRT, no Python.
//!
//!   cargo bench --bench native_scaling [-- max_n]
//!
//! Single head, d = 64, causal.  Reports ms/call and the per-doubling
//! growth ratio: the recurrent forms settle at ~2x per doubling (linear),
//! the oracle at ~4x (quadratic).  The oracle column stops early — that
//! is the point.  Writes results/native_scaling.csv.

use holt::bench::{bench_budget, BenchResult};
use holt::kernels::{Evaluation, NativeBackend};
use holt::mathref;
use holt::rng::Rng;

fn main() -> anyhow::Result<()> {
    let max_n: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let d = 64usize;
    // beyond this the quadratic oracle dominates total bench time
    let oracle_cap = 1024.min(max_n);
    let ns: Vec<usize> = [128, 256, 512, 1024, 2048, 4096]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();

    let streaming = NativeBackend { evaluation: Evaluation::Streaming, ..NativeBackend::paper() };
    let chunked = NativeBackend::paper(); // chunked evaluation, chunk = 64

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut table: Vec<(usize, [f64; 4])> = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(n as u64);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * d, 1.0);
        let mut ms = [f64::NAN; 4];

        let r = bench_budget(&format!("ho2_streaming_n{n}"), 0.3, || {
            std::hint::black_box(
                streaming.forward("ho2", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[0] = r.mean_s * 1e3;
        rows.push(r);

        let r = bench_budget(&format!("ho2_chunked_n{n}"), 0.3, || {
            std::hint::black_box(
                chunked.forward("ho2", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[1] = r.mean_s * 1e3;
        rows.push(r);

        let r = bench_budget(&format!("linear_streaming_n{n}"), 0.3, || {
            std::hint::black_box(
                streaming.forward("linear", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[2] = r.mean_s * 1e3;
        rows.push(r);

        if n <= oracle_cap {
            let r = bench_budget(&format!("ho2_oracle_n2_n{n}"), 0.3, || {
                std::hint::black_box(mathref::ho_attention(
                    &q, &k, &v, n, n, d, d, 2, 3.0, true, true,
                ));
            });
            println!("{}", r.report());
            ms[3] = r.mean_s * 1e3;
            rows.push(r);
        }
        table.push((n, ms));
    }

    println!("\nnative scaling — wall-clock per call (ms) and growth per doubling");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>7} {:>7} {:>7} {:>7}",
        "n", "ho2 stream", "ho2 chunk", "linear", "oracle n^2", "st x", "ch x", "lin x", "or x"
    );
    for (i, (n, ms)) in table.iter().enumerate() {
        let ratio = |k: usize| {
            if i == 0 || table[i - 1].1[k].is_nan() || ms[k].is_nan() {
                "-".to_string()
            } else {
                format!("{:.2}", ms[k] / table[i - 1].1[k])
            }
        };
        let cell = |k: usize| {
            if ms[k].is_nan() {
                "-".to_string()
            } else {
                format!("{:.3}", ms[k])
            }
        };
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>12} {:>7} {:>7} {:>7} {:>7}",
            n, cell(0), cell(1), cell(2), cell(3), ratio(0), ratio(1), ratio(2), ratio(3)
        );
    }

    holt::bench::write_csv(std::path::Path::new("results/native_scaling.csv"), &rows)?;
    holt::bench::write_json(std::path::Path::new("results/bench_scaling.json"), &rows)?;
    println!("\nwrote results/native_scaling.csv + results/bench_scaling.json");
    println!(
        "expected shape: the three recurrent columns -> ~2x per doubling (O(n));\n\
         the oracle -> ~4x (O(n^2)). ho2 carries a (1+d+d(d+1)/2)-feature state\n\
         vs linear's d, so it sits a constant factor above linear at equal slope."
    );
    Ok(())
}
