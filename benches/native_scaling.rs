//! E2, native edition — the complexity claim measured with zero setup:
//! wall-clock of the native O(n) kernels vs the direct O(n²) oracle as
//! sequence length doubles.  No artifacts, no PJRT, no Python.
//!
//!   cargo bench --bench native_scaling [-- max_n]
//!
//! Single head, d = 64, causal.  Reports ms/call and the per-doubling
//! growth ratio: the recurrent forms settle at ~2x per doubling (linear),
//! the oracle at ~4x (quadratic).  The oracle column stops early — that
//! is the point.  Writes results/native_scaling.csv.
//!
//! A second sweep walks the FeatureMap axis — Taylor order ∈ {1, 2, 3}
//! plus the elu+1 linear baseline at one (n, d) point — and records the
//! cost model of the order knob: state bytes per head-slot
//! (feature_dim·(1+dv)·8) against decode-shaped tok/s for the streaming
//! and chunked evaluations.  Written to results/bench_kernels.json and
//! published as a CI artifact.

use holt::bench::{bench_budget, BenchResult};
use holt::json::{obj, Json};
use holt::kernels::{simd, Evaluation, Isa, NativeBackend, RecurrentAttention};
use holt::mathref;
use holt::rng::Rng;

fn main() -> anyhow::Result<()> {
    let max_n: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let d = 64usize;
    // beyond this the quadratic oracle dominates total bench time
    let oracle_cap = 1024.min(max_n);
    let ns: Vec<usize> = [128, 256, 512, 1024, 2048, 4096]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();

    let streaming = NativeBackend { evaluation: Evaluation::Streaming, ..NativeBackend::paper() };
    let chunked = NativeBackend::paper(); // chunked evaluation, chunk = 64

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut table: Vec<(usize, [f64; 4])> = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(n as u64);
        let q = rng.normal_vec_f32(n * d, 1.0);
        let k = rng.normal_vec_f32(n * d, 1.0);
        let v = rng.normal_vec_f32(n * d, 1.0);
        let mut ms = [f64::NAN; 4];

        let r = bench_budget(&format!("ho2_streaming_n{n}"), 0.3, || {
            std::hint::black_box(
                streaming.forward("ho2", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[0] = r.mean_s * 1e3;
        rows.push(r);

        let r = bench_budget(&format!("ho2_chunked_n{n}"), 0.3, || {
            std::hint::black_box(
                chunked.forward("ho2", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[1] = r.mean_s * 1e3;
        rows.push(r);

        let r = bench_budget(&format!("linear_streaming_n{n}"), 0.3, || {
            std::hint::black_box(
                streaming.forward("linear", &q, &k, &v, n, d, d, true).unwrap(),
            );
        });
        println!("{}", r.report());
        ms[2] = r.mean_s * 1e3;
        rows.push(r);

        if n <= oracle_cap {
            let r = bench_budget(&format!("ho2_oracle_n2_n{n}"), 0.3, || {
                std::hint::black_box(mathref::ho_attention(
                    &q, &k, &v, n, n, d, d, 2, 3.0, true, true,
                ));
            });
            println!("{}", r.report());
            ms[3] = r.mean_s * 1e3;
            rows.push(r);
        }
        table.push((n, ms));
    }

    println!("\nnative scaling — wall-clock per call (ms) and growth per doubling");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>7} {:>7} {:>7} {:>7}",
        "n", "ho2 stream", "ho2 chunk", "linear", "oracle n^2", "st x", "ch x", "lin x", "or x"
    );
    for (i, (n, ms)) in table.iter().enumerate() {
        let ratio = |k: usize| {
            if i == 0 || table[i - 1].1[k].is_nan() || ms[k].is_nan() {
                "-".to_string()
            } else {
                format!("{:.2}", ms[k] / table[i - 1].1[k])
            }
        };
        let cell = |k: usize| {
            if ms[k].is_nan() {
                "-".to_string()
            } else {
                format!("{:.3}", ms[k])
            }
        };
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>12} {:>7} {:>7} {:>7} {:>7}",
            n, cell(0), cell(1), cell(2), cell(3), ratio(0), ratio(1), ratio(2), ratio(3)
        );
    }

    holt::bench::write_csv(std::path::Path::new("results/native_scaling.csv"), &rows)?;
    holt::bench::write_json(std::path::Path::new("results/bench_scaling.json"), &rows)?;
    println!("\nwrote results/native_scaling.csv + results/bench_scaling.json");
    println!(
        "expected shape: the three recurrent columns -> ~2x per doubling (O(n));\n\
         the oracle -> ~4x (O(n^2)). ho2 carries a (1+d+d(d+1)/2)-feature state\n\
         vs linear's d, so it sits a constant factor above linear at equal slope."
    );

    // ---- FeatureMap sweep: the cost model of the Taylor-order knob ----
    // one serving-relevant head shape; order 3 at d = 32 is 6 545 packed
    // features per head (the affordable point the redesign unlocked)
    let (kn, kd) = (512.min(max_n).max(128), 32usize);
    let mut krng = Rng::new(7);
    let kq = krng.normal_vec_f32(kn * kd, 1.0);
    let kk = krng.normal_vec_f32(kn * kd, 1.0);
    let kv = krng.normal_vec_f32(kn * kd, 1.0);
    let mut kernel_rows: Vec<Json> = Vec::new();
    let active_isa = format!("{:?}", simd::active());
    println!("\nfeature-map sweep — n = {kn}, d = dv = {kd}, active isa = {active_isa}");
    println!(
        "{:>10} {:>6} {:>16} {:>14} {:>14} {:>8} {:>8}",
        "kernel", "order", "state KiB/head", "stream tok/s", "chunked tok/s", "st simdx", "ch simdx"
    );
    let configs: Vec<(&str, usize)> =
        vec![("ho", 1), ("ho", 2), ("ho", 3), ("linear", 0)];
    for (kind, order) in configs {
        // isa: None → the runtime-detected lane path; Some(Scalar) pins
        // the always-kept reference path the speedup is measured against
        let streaming =
            NativeBackend { evaluation: Evaluation::Streaming, order, ..NativeBackend::paper() };
        let chunked = NativeBackend { order, ..NativeBackend::paper() };
        let scalar_streaming = NativeBackend { isa: Some(Isa::Scalar), ..streaming.clone() };
        let scalar_chunked = NativeBackend { isa: Some(Isa::Scalar), ..chunked.clone() };
        let state_bytes = streaming.state(kind, kd, kd)?.state_elements() * 8;
        let label = if kind == "ho" { format!("ho_o{order}") } else { kind.to_string() };
        let rs = bench_budget(&format!("{label}_stream_n{kn}"), 0.3, || {
            std::hint::black_box(streaming.forward(kind, &kq, &kk, &kv, kn, kd, kd, true).unwrap());
        });
        let rc = bench_budget(&format!("{label}_chunked_n{kn}"), 0.3, || {
            std::hint::black_box(chunked.forward(kind, &kq, &kk, &kv, kn, kd, kd, true).unwrap());
        });
        let rss = bench_budget(&format!("{label}_stream_scalar_n{kn}"), 0.3, || {
            std::hint::black_box(
                scalar_streaming.forward(kind, &kq, &kk, &kv, kn, kd, kd, true).unwrap(),
            );
        });
        let rcs = bench_budget(&format!("{label}_chunked_scalar_n{kn}"), 0.3, || {
            std::hint::black_box(
                scalar_chunked.forward(kind, &kq, &kk, &kv, kn, kd, kd, true).unwrap(),
            );
        });
        let stream_tok_s = kn as f64 / rs.mean_s;
        let chunked_tok_s = kn as f64 / rc.mean_s;
        let scalar_stream_tok_s = kn as f64 / rss.mean_s;
        let scalar_chunked_tok_s = kn as f64 / rcs.mean_s;
        let speedup_stream = stream_tok_s / scalar_stream_tok_s;
        let speedup_chunked = chunked_tok_s / scalar_chunked_tok_s;
        println!(
            "{:>10} {:>6} {:>16.1} {:>14.0} {:>14.0} {:>8.2} {:>8.2}",
            label,
            order,
            state_bytes as f64 / 1024.0,
            stream_tok_s,
            chunked_tok_s,
            speedup_stream,
            speedup_chunked
        );
        kernel_rows.push(obj(vec![
            ("kernel", label.as_str().into()),
            ("kind", kind.into()),
            ("order", order.into()),
            ("n", kn.into()),
            ("d", kd.into()),
            ("state_bytes_per_head_slot", state_bytes.into()),
            ("streaming_tok_per_s", stream_tok_s.into()),
            ("chunked_tok_per_s", chunked_tok_s.into()),
            ("scalar_streaming_tok_per_s", scalar_stream_tok_s.into()),
            ("scalar_chunked_tok_per_s", scalar_chunked_tok_s.into()),
            ("simd_speedup_streaming", speedup_stream.into()),
            ("simd_speedup_chunked", speedup_chunked.into()),
        ]));
    }
    let record = obj(vec![
        ("active_isa", active_isa.as_str().into()),
        ("feature_map_sweep", Json::Arr(kernel_rows)),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/bench_kernels.json", format!("{record}\n"))?;
    println!("wrote results/bench_kernels.json");
    Ok(())
}
