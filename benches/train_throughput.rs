//! E5 — train-step throughput by attention type: tokens/sec through one
//! full AdamW step (forward + hand-derived backward + optimizer on the
//! native path; the fused artifact on the PJRT path).
//!
//!   cargo bench --bench train_throughput [-- preset] [-- --artifact]
//!                                        [-- --skip-long]
//!
//! Three record groups land in results/bench_train.json (one object):
//!
//! * `steps` — whole AdamW steps per attention kind (the original E5).
//! * `long_context` — the one-forward payoff: `loss_and_grad` (fused
//!   capture + reverse) vs `loss_and_grad_replay` (the pre-fusion
//!   forward-then-replay vjp) on 4k–32k-token sequences, reported as
//!   `fused_speedup_vs_replay`.  Skippable with `--skip-long`.
//! * `worker_scaling` — data-parallel gradient tok/s at 4k context for
//!   `grad_workers` in {1, 2, whole pool}.
//!
//! The native case needs nothing (no artifacts, no Python); pass
//! `--artifact` to additionally bench the fused PJRT step (skipped with
//! a note when artifacts are unavailable).  CSV lands in
//! results/e5_train_throughput.csv.

use holt::bench::{bench, write_csv, BenchResult};
use holt::coordinator::trainer::{ArtifactTrainer, NativeTrainer, TrainBackend};
use holt::data;
use holt::json::{obj, Json};
use holt::model::grad;
use holt::model::presets::param_spec;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::{ModelConfig, ModelEntry, Runtime};

fn bench_backend(
    trainer: &mut dyn TrainBackend,
    label: &str,
    rows: &mut Vec<BenchResult>,
    json_rows: &mut Vec<Json>,
) -> anyhow::Result<()> {
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("charlm", 1)?;
    let batch = gen.batch(b, t);
    let tokens = (b * t) as f64;
    let r = bench(label, 1, 5, || {
        trainer.train_step(&batch, 3e-4).unwrap();
    });
    let tok_per_s = tokens / r.mean_s;
    println!("{}   ({:.0} tok/s, batch {}x{})", r.report(), tok_per_s, b, t);
    json_rows.push(obj(vec![
        ("name", r.name.as_str().into()),
        ("mean_ms", (r.mean_s * 1e3).into()),
        ("std_ms", (r.std_s * 1e3).into()),
        ("min_ms", (r.min_s * 1e3).into()),
        ("iters", r.iters.into()),
        ("tok_per_s", tok_per_s.into()),
        ("batch", b.into()),
        ("seq_len", t.into()),
    ]));
    rows.push(r);
    Ok(())
}

/// A 2-layer, 2-head ho2 model sized so long sequences fit: the point
/// is the n-scaling of the backward, not model capacity.
fn long_entry(batch: usize, t: usize) -> ModelEntry {
    let config = ModelConfig {
        preset: "bench_long".into(),
        vocab_size: holt::tokenizer::VOCAB_SIZE,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_len: t,
        attn: "ho2".into(),
        order: 2,
        alpha: 3.0,
        impl_: "native".into(),
        train_batch: batch,
        train_len: t,
        decode_batch: 1,
    };
    let spec = param_spec(&config);
    let n_params = spec.iter().map(|l| l.shape.iter().product::<usize>()).sum();
    ModelEntry {
        name: format!("ho2_bench_long_{t}"),
        config,
        n_params,
        param_spec: spec,
        state_spec: Vec::new(),
        artifacts: std::collections::HashMap::new(),
    }
}

/// Fused (one-forward) vs replay backward at long context.
fn bench_long_context(json_rows: &mut Vec<Json>) -> anyhow::Result<()> {
    println!("\nlong context — fused capture+reverse vs forward+replay vjp");
    for (task, t) in [("copy", 4096usize), ("assoc", 4096), ("copy", 32768)] {
        let entry = long_entry(1, t);
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(2));
        let batch = data::make(task, 2)?.batch(1, t);
        let cfg = &entry.config;
        let fused = bench(&format!("fused_{task}_{t}"), 1, 2, || {
            grad::loss_and_grad(cfg, &params, &batch).unwrap();
        });
        let replay = bench(&format!("replay_{task}_{t}"), 1, 2, || {
            grad::loss_and_grad_replay(cfg, &params, &batch).unwrap();
        });
        let speedup = replay.mean_s / fused.mean_s;
        let tok_per_s = t as f64 / fused.mean_s;
        println!(
            "  {task} n={t}: fused {:.0} ms, replay {:.0} ms — {speedup:.2}x ({tok_per_s:.0} tok/s)",
            fused.mean_s * 1e3,
            replay.mean_s * 1e3,
        );
        json_rows.push(obj(vec![
            ("task", task.into()),
            ("seq_len", t.into()),
            ("fused_ms", (fused.mean_s * 1e3).into()),
            ("replay_ms", (replay.mean_s * 1e3).into()),
            ("fused_speedup_vs_replay", speedup.into()),
            ("tok_per_s", tok_per_s.into()),
        ]));
    }
    Ok(())
}

/// Data-parallel gradient scaling: same 4-sequence batch at 4k context,
/// different worker caps (the gradient is bit-identical across them —
/// this measures wall clock only).
fn bench_worker_scaling(json_rows: &mut Vec<Json>) -> anyhow::Result<()> {
    println!("\nworker scaling — data-parallel per-sequence gradients, copy n=4096");
    let (b, t) = (4usize, 4096usize);
    let entry = long_entry(b, t);
    let params = ParamStore::init(&entry.param_spec, &mut Rng::new(3));
    let batch = data::make("copy", 3)?.batch(b, t);
    let cfg = &entry.config;
    for workers in [1usize, 2, 0] {
        let r = bench(&format!("grad_workers_{workers}"), 1, 2, || {
            grad::loss_and_grad_accum(cfg, &params, &batch, 1, workers).unwrap();
        });
        let tok_per_s = (b * t) as f64 / r.mean_s;
        let label = if workers == 0 { "pool".into() } else { workers.to_string() };
        println!("  grad_workers={label}: {:.0} ms ({tok_per_s:.0} tok/s)", r.mean_s * 1e3);
        json_rows.push(obj(vec![
            ("grad_workers", workers.into()),
            ("batch", b.into()),
            ("seq_len", t.into()),
            ("mean_ms", (r.mean_s * 1e3).into()),
            ("tok_per_s", tok_per_s.into()),
        ]));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "tiny".into());
    let with_artifact = args.iter().any(|a| a == "--artifact");
    let skip_long = args.iter().any(|a| a == "--skip-long");

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();

    println!("E5 — train-step throughput ({preset} preset)\n");
    for attn in ["softmax", "linear", "ho2"] {
        let model = format!("{attn}_{preset}");
        let mut trainer = NativeTrainer::new(&model, 1)?;
        bench_backend(&mut trainer, &format!("native_train_{model}"), &mut rows, &mut json_rows)?;
    }

    if with_artifact {
        match holt::default_artifacts_dir().and_then(|d| Runtime::new(&d)) {
            Ok(rt) => {
                for attn in ["softmax", "linear", "ho2"] {
                    let model = format!("{attn}_{preset}");
                    // a single missing/stale artifact must not discard
                    // the native results already collected
                    match ArtifactTrainer::new(&rt, &model, 1) {
                        Ok(mut trainer) => bench_backend(
                            &mut trainer,
                            &format!("artifact_train_{model}"),
                            &mut rows,
                            &mut json_rows,
                        )?,
                        Err(e) => println!("(artifact {model} skipped: {e})"),
                    }
                }
            }
            Err(e) => println!("(artifact path skipped: {e})"),
        }
    }

    let mut long_rows: Vec<Json> = Vec::new();
    let mut scale_rows: Vec<Json> = Vec::new();
    if skip_long {
        println!("\n(long-context + worker-scaling sweeps skipped: --skip-long)");
    } else {
        bench_long_context(&mut long_rows)?;
        bench_worker_scaling(&mut scale_rows)?;
    }

    std::fs::create_dir_all("results")?;
    let doc = obj(vec![
        ("steps", Json::Arr(json_rows)),
        ("long_context", Json::Arr(long_rows)),
        ("worker_scaling", Json::Arr(scale_rows)),
    ]);
    std::fs::write("results/bench_train.json", format!("{doc}\n"))?;
    write_csv(std::path::Path::new("results/e5_train_throughput.csv"), &rows)?;
    println!("\nwrote results/bench_train.json and results/e5_train_throughput.csv");
    Ok(())
}
