//! E5 — train-step throughput by attention type: tokens/sec through one
//! full AdamW step (forward + hand-derived backward + optimizer on the
//! native path; the fused artifact on the PJRT path).
//!
//!   cargo bench --bench train_throughput [-- preset] [-- --artifact]
//!
//! The native case needs nothing (no artifacts, no Python) and writes
//! results/bench_train.json next to the serve/scaling bench artifacts;
//! pass `--artifact` to additionally bench the fused PJRT step (skipped
//! with a note when artifacts are unavailable).  CSV lands in
//! results/e5_train_throughput.csv.

use holt::bench::{bench, write_csv, BenchResult};
use holt::coordinator::trainer::{ArtifactTrainer, NativeTrainer, TrainBackend};
use holt::data;
use holt::json::{obj, Json};
use holt::runtime::Runtime;

fn bench_backend(
    trainer: &mut dyn TrainBackend,
    label: &str,
    rows: &mut Vec<BenchResult>,
    json_rows: &mut Vec<Json>,
) -> anyhow::Result<()> {
    let (b, t) = trainer.train_shape();
    let mut gen = data::make("charlm", 1)?;
    let batch = gen.batch(b, t);
    let tokens = (b * t) as f64;
    let r = bench(label, 1, 5, || {
        trainer.train_step(&batch, 3e-4).unwrap();
    });
    let tok_per_s = tokens / r.mean_s;
    println!("{}   ({:.0} tok/s, batch {}x{})", r.report(), tok_per_s, b, t);
    json_rows.push(obj(vec![
        ("name", r.name.as_str().into()),
        ("mean_ms", (r.mean_s * 1e3).into()),
        ("std_ms", (r.std_s * 1e3).into()),
        ("min_ms", (r.min_s * 1e3).into()),
        ("iters", r.iters.into()),
        ("tok_per_s", tok_per_s.into()),
        ("batch", b.into()),
        ("seq_len", t.into()),
    ]));
    rows.push(r);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "tiny".into());
    let with_artifact = args.iter().any(|a| a == "--artifact");

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();

    println!("E5 — train-step throughput ({preset} preset)\n");
    for attn in ["softmax", "linear", "ho2"] {
        let model = format!("{attn}_{preset}");
        let mut trainer = NativeTrainer::new(&model, 1)?;
        bench_backend(&mut trainer, &format!("native_train_{model}"), &mut rows, &mut json_rows)?;
    }

    if with_artifact {
        match holt::default_artifacts_dir().and_then(|d| Runtime::new(&d)) {
            Ok(rt) => {
                for attn in ["softmax", "linear", "ho2"] {
                    let model = format!("{attn}_{preset}");
                    // a single missing/stale artifact must not discard
                    // the native results already collected
                    match ArtifactTrainer::new(&rt, &model, 1) {
                        Ok(mut trainer) => bench_backend(
                            &mut trainer,
                            &format!("artifact_train_{model}"),
                            &mut rows,
                            &mut json_rows,
                        )?,
                        Err(e) => println!("(artifact {model} skipped: {e})"),
                    }
                }
            }
            Err(e) => println!("(artifact path skipped: {e})"),
        }
    }

    std::fs::create_dir_all("results")?;
    std::fs::write(
        "results/bench_train.json",
        format!("{}\n", Json::Arr(json_rows)),
    )?;
    write_csv(std::path::Path::new("results/e5_train_throughput.csv"), &rows)?;
    println!("\nwrote results/bench_train.json and results/e5_train_throughput.csv");
    Ok(())
}
