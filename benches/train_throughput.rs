//! E5 — train-step throughput by attention type (tokens/sec through the
//! fused AdamW artifact, the whole L3 hot path included).
//!
//!   cargo bench --bench train_throughput [-- preset]
//!
//! Writes results/e5_train_throughput.csv.

use holt::bench::{bench, write_csv, BenchResult};
use holt::coordinator::trainer::Trainer;
use holt::data;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let preset = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "tiny".into());
    let rt = Runtime::new(&holt::default_artifacts_dir()?)?;
    let mut rows: Vec<BenchResult> = Vec::new();

    println!("E5 — fused train-step throughput ({preset} preset)\n");
    for attn in ["softmax", "linear", "ho2"] {
        let model = format!("{attn}_{preset}");
        let mut trainer = Trainer::new(&rt, &model, 1)?;
        let (b, t) = trainer.train_shape();
        let mut gen = data::make("charlm", 1)?;
        let batch = gen.batch(b, t);
        let tokens = (b * t) as f64;
        let r = bench(&model, 2, 8, || {
            trainer.train_step(&batch, 3e-4).unwrap();
        });
        println!(
            "{}   ({:.0} tok/s, batch {}x{})",
            r.report(),
            tokens / r.mean_s,
            b,
            t
        );
        rows.push(r);
    }
    write_csv(std::path::Path::new("results/e5_train_throughput.csv"), &rows)?;
    println!("\nwrote results/e5_train_throughput.csv");
    Ok(())
}
