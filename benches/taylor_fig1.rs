//! Figure 1 — the paper's only figure: exp(x) against its Taylor
//! truncations of order 1, 2, 3 on [-3, 3].
//!
//!   cargo bench --bench taylor_fig1
//!
//! Writes results/fig1_taylor.csv (plot-ready) and verifies the visual
//! claims the paper makes about the figure: near-0 fidelity, rapid
//! divergence away from 0, even orders overshooting for x < 0 and odd
//! orders undershooting.

use holt::experiments::{fig1_taylor_csv, write_results};
use holt::mathref::taylor_exp;

fn main() -> anyhow::Result<()> {
    let csv = fig1_taylor_csv(121);
    let path = write_results(std::path::Path::new("results"), "fig1_taylor.csv", &csv)?;

    // the figure's qualitative content, as assertions
    // (1) near zero all orders are good
    for x in [-0.25, 0.0, 0.25] {
        for o in [1, 2, 3] {
            assert!((taylor_exp(x, o) - x.exp()).abs() < 0.05, "near-zero fit");
        }
    }
    // (2) far from zero the approximation is "quickly very wrong" (paper)
    assert!((taylor_exp(3.0, 2) - 3f64.exp()).abs() > 10.0);
    // (3) even order overestimates for negative x, odd underestimates
    assert!(taylor_exp(-2.0, 2) > (-2f64).exp());
    assert!(taylor_exp(-2.0, 3) < (-2f64).exp());

    println!("fig1: wrote {path:?}");
    println!("{:>6} {:>10} {:>10} {:>10} {:>10}", "x", "exp", "o1", "o2", "o3");
    for x in [-3.0f64, -1.5, 0.0, 1.5, 3.0] {
        println!(
            "{:>6.1} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            x,
            x.exp(),
            taylor_exp(x, 1),
            taylor_exp(x, 2),
            taylor_exp(x, 3)
        );
    }
    println!("\nfigure-1 invariants verified (near-0 fit, divergence, parity bias)");
    Ok(())
}
