//! E2 — the paper's headline complexity claim: attention cost vs sequence
//! length.  softmax is O(n^2 d); the factorized order-2 attention is
//! O(n d_v d^2); elu+1 linear attention is O(n d_v d).
//!
//!   cargo bench --bench attention_scaling [-- max_n]
//!
//! Executes the AOT attention artifacts (batch 1, 4 heads, d=64, causal)
//! for n in {64..4096} and reports ms/call plus the per-doubling growth
//! ratio — ~4x for the quadratic baseline vs ~2x for the linear methods
//! at large n.  Writes results/e2_scaling.csv.

use holt::bench::{bench_budget, BenchResult};
use holt::rng::Rng;
use holt::runtime::{Runtime, Tensor};

fn main() -> anyhow::Result<()> {
    let max_n: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let rt = Runtime::new(&holt::default_artifacts_dir()?)?;
    let ns: Vec<usize> = [64, 128, 256, 512, 1024, 2048, 4096]
        .into_iter()
        .filter(|&n| n <= max_n)
        .collect();
    let kinds = ["softmax", "linear", "ho2"];

    let mut rows: Vec<BenchResult> = Vec::new();
    let mut table: Vec<(usize, [f64; 3])> = Vec::new();
    for &n in &ns {
        let mut ms = [0.0; 3];
        for (ki, kind) in kinds.iter().enumerate() {
            let name = format!("attn_{kind}_n{n}");
            let exe = rt.load(&name)?;
            let shape = exe.artifact.inputs[0].shape.clone();
            let count: usize = shape.iter().product();
            let mut rng = Rng::new(n as u64);
            let q = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));
            let k = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));
            let v = Tensor::f32(shape.clone(), rng.normal_vec_f32(count, 1.0));
            let r = bench_budget(&name, 0.4, || {
                std::hint::black_box(exe.run(&[q.clone(), k.clone(), v.clone()]).unwrap());
            });
            println!("{}", r.report());
            ms[ki] = r.mean_s * 1e3;
            rows.push(r);
        }
        table.push((n, ms));
    }

    println!("\nE2 — wall-clock per call (ms) and growth per doubling");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>8} {:>8} {:>8}",
        "n", "softmax", "linear", "ho2", "sm x", "lin x", "ho2 x"
    );
    for (i, (n, ms)) in table.iter().enumerate() {
        let ratio = |k: usize| {
            if i == 0 {
                "-".to_string()
            } else {
                format!("{:.2}", ms[k] / table[i - 1].1[k])
            }
        };
        println!(
            "{:>6} {:>12.3} {:>12.3} {:>12.3} {:>8} {:>8} {:>8}",
            n, ms[0], ms[1], ms[2], ratio(0), ratio(1), ratio(2)
        );
    }

    holt::bench::write_csv(std::path::Path::new("results/e2_scaling.csv"), &rows)?;
    println!("\nwrote results/e2_scaling.csv");
    println!(
        "expected shape: softmax ratio -> ~4x/doubling at large n (O(n^2));\n\
         linear + ho2 -> ~2x (O(n)); ho2 sits ~d/1 above linear in absolute\n\
         cost (feature dim 1+d+d^2 vs d) but keeps the same slope."
    );
    Ok(())
}
