//! E4 — autoregressive decoding: per-token latency vs context depth, and
//! per-slot state size.  The paper's RNN formulation gives O(1) state and
//! flat per-token cost; the softmax baseline drags a KV cache that grows
//! with context (and does O(ctx) work per token).
//!
//!   cargo bench --bench decode_latency [-- tokens_per_phase]
//!
//! Writes results/e4_decode.csv (model, ctx_bucket, us/token, state KiB).

use holt::bench::write_csv;
use holt::bench::BenchResult;
use holt::coordinator::generation::{decode_step, CachedParams};
use holt::coordinator::state::StateManager;
use holt::params::ParamStore;
use holt::rng::Rng;
use holt::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let per_phase: usize = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let rt = Runtime::new(&holt::default_artifacts_dir()?)?;
    let mut rows: Vec<BenchResult> = Vec::new();

    println!("E4 — per-token decode latency vs context depth (tiny preset)\n");
    println!(
        "{:<14} {:>10} {:>14} {:>12}",
        "model", "ctx", "us/token", "state KiB"
    );
    for attn in ["ho2", "linear", "softmax"] {
        let model = format!("{attn}_tiny");
        let entry = rt.manifest.model(&model)?.clone();
        let exe = rt.load(entry.artifacts.get("decode").unwrap())?;
        let params = ParamStore::init(&entry.param_spec, &mut Rng::new(2));
        let cached = CachedParams::new(&params)?;
        let mut sm = StateManager::new(&entry.state_spec)?;
        let b = sm.n_slots();
        for _ in 0..b {
            sm.alloc();
        }
        let state_kib = sm.state_elements_per_slot() as f64 * 4.0 / 1024.0;
        let max_ctx = entry.config.max_len - 1;

        // decode continuously; bucket timings by context depth
        let mut rng = Rng::new(3);
        let mut ctx = 0usize;
        while ctx + per_phase <= max_ctx.min(ctx + per_phase) && ctx < max_ctx {
            let phase_end = (ctx + per_phase).min(max_ctx);
            let t0 = std::time::Instant::now();
            let mut steps = 0;
            while ctx < phase_end {
                let feed: Vec<i32> =
                    (0..b).map(|_| rng.uniform_int(0, 256) as i32).collect();
                std::hint::black_box(decode_step(&exe, &cached, &mut sm, &feed)?);
                for s in 0..b {
                    sm.advance(s);
                }
                ctx += 1;
                steps += 1;
            }
            let per_token_us =
                t0.elapsed().as_secs_f64() * 1e6 / (steps as f64 * b as f64);
            println!(
                "{:<14} {:>10} {:>14.1} {:>12.1}",
                model, ctx, per_token_us, state_kib
            );
            rows.push(BenchResult {
                name: format!("{model}_ctx{ctx}"),
                iters: steps * b,
                mean_s: per_token_us / 1e6,
                std_s: 0.0,
                min_s: per_token_us / 1e6,
            });
        }
        println!();
    }
    write_csv(std::path::Path::new("results/e4_decode.csv"), &rows)?;
    println!("wrote results/e4_decode.csv");
    println!(
        "expected shape: ho2/linear flat in ctx with constant state;\n\
         softmax per-token cost grows with ctx and its cache is max_len-sized."
    );
    Ok(())
}
